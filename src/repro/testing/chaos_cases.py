import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

"""Chaos test cases: seeded fault injection vs. the recovery ladder.

``python -m repro.testing.chaos_cases <case>`` prints one JSON dict; the
pytest wrappers (tests/test_chaos.py) assert on it. Every case arms one
fault class (``repro.core.faults``) on an 8-shard world and checks three
things against the fault-free oracle run:

* the query still completes — through the documented recovery rung for
  that failure class (XLA oracle, monolithic AllToAll, safe capacity,
  recompile, quarantine + degraded re-execute);
* the recovered result is BIT-IDENTICAL to the fault-free result (data
  is integer-valued float32 so kernel and oracle paths agree exactly);
* the recovery counters in ``ctx.cache_stats()`` record exactly what
  happened (which rung, how many fires, no unbounded retries).
"""
import json
import sys

import numpy as np


def _ctx(faults=None, retry=None):
    from repro.core import faults as FLT
    from repro.core.context import DistContext
    return DistContext(faults=faults,
                       retry_policy=retry or FLT.RetryPolicy())


def _orders(n_per_shard=400, keys=57, seed=11):
    from repro.core.table import Table
    rng = np.random.default_rng(seed)
    n = n_per_shard * 8
    return Table.from_arrays({
        "k": rng.integers(0, keys, n).astype(np.int32),
        "d0": rng.integers(-50, 50, n).astype(np.float32),
        "d1": rng.integers(0, 1000, n).astype(np.int32)})


def _rows(dt):
    return sorted(dt.to_table().to_rows())


def _bitwise(a, b):
    from repro.testing.compare import tables_bitwise_equal
    return tables_bitwise_equal(a.to_table(), b.to_table())


def case_shuffle_recovery():
    """shuffle.chunk faults on staged AND ring shuffles: a raised chunk
    degrades to the monolithic AllToAll rung; a garbled chunk is caught
    by result validation and quarantined into a degraded re-execute.
    Either way the result is bit-identical to the fault-free shuffle."""
    from repro.core import faults as FLT

    t = _orders()
    out = {}
    for mode_name, kw in (("staged", {"stages": 3}),
                          ("ring", {"shuffle_mode": "ring"})):
        ctx0 = _ctx()
        ref, _ = ctx0.partition_by(ctx0.scatter(t), "k",
                                   bucket_capacity=1024, **kw)
        ref_rows = _rows(ref)
        for fmode in ("raise", "garble"):
            ctx = _ctx(faults=[FLT.FaultPlan("shuffle.chunk", mode=fmode,
                                             nth=1)])
            got, _ = ctx.partition_by(ctx.scatter(t), "k",
                                      bucket_capacity=1024, **kw)
            cs = ctx.cache_stats()
            tag = f"{mode_name}_{fmode}"
            out[f"{tag}_identical"] = _rows(got) == ref_rows
            out[f"{tag}_fires"] = cs["fault_fires"]
            out[f"{tag}_degraded_shuffle"] = cs["degraded_shuffle"]
            out[f"{tag}_quarantines"] = cs["quarantines"]
            out[f"{tag}_failed"] = cs["failed_queries"]
    out["all_identical"] = all(v for k, v in out.items()
                               if k.endswith("_identical"))
    return out


def case_kernel_recovery():
    """kernel.dispatch faults on a distributed GroupBy: a raising kernel
    degrades to the XLA oracle rung at dispatch; a NaN-poisoned kernel
    output is caught by validation at finalize and quarantined into a
    fully degraded re-execute. Bit-identical both ways (integer-valued
    float32 keeps kernel and oracle sums exactly equal)."""
    from repro.core import faults as FLT

    t = _orders()
    ctx0 = _ctx()
    ref, _ = ctx0.groupby(ctx0.scatter(t), "k",
                          (("d0", "sum"), ("d0", "count")))
    ref_rows = _rows(ref)
    # nan poison needs a FLOAT kernel output (an int aggregate raises
    # instead — NaN isn't expressible there), so it gets its own query
    ctx0b = _ctx()
    nan_ref, _ = ctx0b.groupby(ctx0b.scatter(t), "k", (("d0", "sum"),))
    nan_ref_rows = _rows(nan_ref)
    out = {}
    for fmode, rung_counter, aggs, want in (
            ("raise", "degraded_kernel",
             (("d0", "sum"), ("d0", "count")), ref_rows),
            ("nan", "quarantines", (("d0", "sum"),), nan_ref_rows)):
        ctx = _ctx(faults=[FLT.FaultPlan("kernel.dispatch", mode=fmode,
                                         nth=1)])
        got, _ = ctx.groupby(ctx.scatter(t), "k", aggs)
        cs = ctx.cache_stats()
        out[f"{fmode}_identical"] = _rows(got) == want
        out[f"{fmode}_fires"] = cs["fault_fires"]
        out[f"{fmode}_rung"] = cs[rung_counter]
        out[f"{fmode}_failed"] = cs["failed_queries"]
    # persistent fault: every kernel dispatch raises, forever — the
    # oracle rung must still recover within the bounded ladder
    ctx = _ctx(faults=[FLT.FaultPlan("kernel.dispatch", probability=1.0,
                                     max_fires=10_000)],
               retry=FLT.RetryPolicy(max_attempts=3))
    got, _ = ctx.groupby(ctx.scatter(t), "k",
                         (("d0", "sum"), ("d0", "count")))
    cs = ctx.cache_stats()
    out["persistent_identical"] = _rows(got) == ref_rows
    out["persistent_degraded"] = cs["degraded_kernel"]
    out["persistent_failed"] = cs["failed_queries"]
    return out


def case_stats_overflow_recovery():
    """stats.estimate fault: the sizing budget is derated 64x under an
    analyzed (cost-sized) plan, forcing real bucket overflow — recovered
    by the safe-capacity rung (overflow_retries), result bit-identical
    to the un-derated run, and the plan key is remembered as bad so the
    SECOND submit goes straight to the safe plan (no second retry)."""
    from repro.core import faults as FLT

    t = _orders(keys=97)
    ctx0 = _ctx()
    ref, _ = ctx0.groupby(ctx0.analyze(ctx0.scatter(t)), "k",
                          (("d0", "sum"),), strategy="shuffle")
    ref_rows = _rows(ref)
    ctx = _ctx(faults=[FLT.FaultPlan("stats.estimate", probability=1.0,
                                     max_fires=10_000, factor=64.0)])
    dt = ctx.analyze(ctx.scatter(t))
    got, _ = ctx.groupby(dt, "k", (("d0", "sum"),), strategy="shuffle")
    first = ctx.cache_stats()
    got2, _ = ctx.groupby(dt, "k", (("d0", "sum"),), strategy="shuffle")
    second = ctx.cache_stats()
    return {"identical": _rows(got) == ref_rows,
            "identical_second": _rows(got2) == ref_rows,
            "overflow_retries": first["overflow_retries"],
            "second_submit_retries": second["overflow_retries"]
            - first["overflow_retries"],
            "fires": first["fault_fires"] > 0,
            "failed": second["failed_queries"]}


def case_cache_and_compile():
    """cache.admission + compile faults. A spurious miss/evict recovers
    by natural recompile (results identical, recompile counter records
    it). A corrupt cached executable raises at dispatch; the ladder
    invalidates the entry and retries with a fresh compile."""
    from repro.core import faults as FLT

    t = _orders()
    ctx0 = _ctx()
    ref, _ = ctx0.groupby(ctx0.scatter(t), "k", (("d0", "sum"),))
    ref_rows = _rows(ref)
    out = {}
    for fmode in ("miss", "evict"):
        ctx = _ctx(faults=[FLT.FaultPlan("cache.admission", mode=fmode,
                                         nth=2)])  # warm hit is call 2
        dt = ctx.scatter(t)
        a, _ = ctx.groupby(dt, "k", (("d0", "sum"),))
        b, _ = ctx.groupby(dt, "k", (("d0", "sum"),))
        cs = ctx.cache_stats()
        out[f"{fmode}_identical"] = _rows(a) == ref_rows \
            and _rows(b) == ref_rows
        out[f"{fmode}_fires"] = cs["fault_fires"]
        out[f"{fmode}_recompiles"] = cs["recompiles"]
        out[f"{fmode}_failed"] = cs["failed_queries"]
    ctx = _ctx(faults=[FLT.FaultPlan("compile", nth=1)])
    dt = ctx.scatter(t)
    a, _ = ctx.groupby(dt, "k", (("d0", "sum"),))
    b, _ = ctx.groupby(dt, "k", (("d0", "sum"),))  # fires on the warm hit
    cs = ctx.cache_stats()
    out["compile_identical"] = _rows(a) == ref_rows \
        and _rows(b) == ref_rows
    out["compile_retries"] = cs["compile_retries"]
    out["compile_failed"] = cs["failed_queries"]
    return out


def case_serving_survival():
    """A ServingSession open loop survives faults injected mid-workload:
    a kernel fault degrades one query to the oracle rung, a broken query
    builder resolves its future exceptionally — and in BOTH cases every
    other query completes bit-identical to the fault-free loop, the
    session and plan cache stay healthy, and the report surfaces the
    failure/recovery counters."""
    from repro.core import faults as FLT
    from repro.core.serving import ServingSession

    t = _orders(keys=64)
    workload = [
        ("gb", lambda s: s.frame("orders")
            .groupby("k", (("d0", "sum"), ("d0", "count")))),
        ("sel", lambda s: s.frame("orders")
            .select(lambda c: c["d0"] > 0.0, key=("pos",))
            .groupby("k", (("d0", "sum"),))),
        ("sort", lambda s: s.frame("orders").sort("k").limit(16)),
    ]

    def loop(ctx, wl):
        sess = ServingSession(ctx, max_in_flight=4)
        sess.register("orders", t)
        return sess.run_open_loop(wl, num_clients=3, queries_per_client=2,
                                  mode="async")

    ref_rep, ref_res = loop(_ctx(), workload)

    # kernel fault fires once mid-loop -> one query degrades, all succeed
    ctx1 = _ctx(faults=[FLT.FaultPlan("kernel.dispatch", probability=1.0,
                                      max_fires=1)])
    rep1, res1 = loop(ctx1, workload)
    identical1 = all(a is not None and _bitwise(a, b)
                     for a, b in zip(res1, ref_res))

    # a broken builder -> exactly that query fails, the loop keeps going
    def boom(_s):
        raise ValueError("client bug")
    wl2 = list(workload) + [("boom", boom)]
    rep2, res2 = loop(_ctx(), wl2)
    ok2 = [r is not None for r in res2]
    return {
        "fault_all_succeeded": identical1,
        "fault_failed": rep1.failed,
        "fault_degraded": rep1.degraded + rep1.quarantines,
        "fault_retries_bounded": rep1.retries + rep1.degraded
        + rep1.quarantines <= rep1.num_queries,
        "boom_failed": rep2.failed,
        "boom_failed_labels": sorted({lbl for lbl, _ in rep2.errors}),
        "boom_succeeded": sum(ok2),
        "boom_queries": rep2.num_queries,
        "ref_failed": ref_rep.failed,
    }


CASES = {k[5:]: v for k, v in list(globals().items())
         if k.startswith("case_")}


def main():
    case = sys.argv[1]
    out = CASES[case]()
    print("JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
