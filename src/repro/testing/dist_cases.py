import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

"""Distributed test cases, run in a subprocess with 8 host devices.

``python -m repro.testing.dist_cases <case>`` prints one JSON dict; the
pytest wrappers (tests/test_dist.py) assert on it. Keeping the 8-device
world in a child process leaves the main test session single-device.
"""
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np


def _ctx(axis="shuffle"):
    from repro.core.context import DistContext
    return DistContext(axis_name=axis)


def case_join_union_sort():
    from collections import Counter

    from repro.core.table import Table
    from repro.data.synthetic import random_table, zipf_table

    ctx = _ctx()
    a = random_table(3000, key_range=300, seed=1)
    b = zipf_table(3000, key_range=300, seed=2)
    da = ctx.scatter(a, local_capacity=512)
    db = ctx.scatter(b, local_capacity=512)

    out = {}
    # join (both algorithms) vs counting oracle
    ca = Counter(np.asarray(a.columns["k"]).tolist())
    cb = Counter(np.asarray(b.columns["k"]).tolist())
    expect = sum(ca[k] * cb.get(k, 0) for k in ca)
    for algo in ("hash", "sort"):
        j, (sl, sr) = ctx.join(da, db, "k", algorithm=algo,
                               bucket_capacity=640)
        out[f"join_{algo}_rows"] = int(j.global_rows())
        out[f"join_{algo}_overflow"] = int(np.asarray(sl.overflow).sum()
                                           + np.asarray(sr.overflow).sum())
    out["join_expect"] = int(expect)

    # union vs set oracle
    u, _ = ctx.union(ctx.project(da, ["k"]), ctx.project(db, ["k"]),
                     bucket_capacity=640)
    su = set(np.asarray(a.columns["k"]).tolist()) | \
        set(np.asarray(b.columns["k"]).tolist())
    out["union_rows"] = int(u.global_rows())
    out["union_expect"] = len(su)

    # distributed sort: globally non-decreasing
    s, _ = ctx.sort(da, "k", bucket_capacity=2048)
    ks = s.to_table().to_numpy()["k"].astype(np.int64)
    out["sort_rows"] = len(ks)
    out["sort_ok"] = bool(np.all(np.diff(ks) >= 0)) and len(ks) == 3000
    return out


def case_intersect_difference():
    from repro.core.table import Table

    ctx = _ctx()
    rng = np.random.default_rng(5)
    a = Table.from_arrays({"k": rng.integers(0, 60, 400).astype(np.int32)})
    b = Table.from_arrays({"k": rng.integers(30, 90, 400).astype(np.int32)})
    da, db = ctx.scatter(a, local_capacity=128), \
        ctx.scatter(b, local_capacity=128)
    sa = set(np.asarray(a.columns["k"]).tolist())
    sb = set(np.asarray(b.columns["k"]).tolist())
    i, _ = ctx.intersect(da, db, bucket_capacity=256)
    d, _ = ctx.difference(da, db, bucket_capacity=256)
    got_i = sorted(i.to_table().to_numpy()["k"].tolist())
    got_d = sorted(d.to_table().to_numpy()["k"].tolist())
    return {"intersect_ok": got_i == sorted(sa & sb),
            "difference_ok": got_d == sorted(sa ^ sb)}


def case_groupby():
    """Both dist_groupby strategies == local groupby on the gathered table
    (itself oracle-verified in tests/test_groupby.py), and two-phase
    shuffles strictly fewer rows on low-cardinality keys."""
    from repro.core import ops_agg as A
    from repro.core.table import Table
    from repro.data.synthetic import zipf_table

    ctx = _ctx()
    key_range = 48
    parts = [zipf_table(600, key_range=key_range, seed=11, shard=i)
             for i in range(ctx.num_shards)]
    dt = ctx.from_local_parts(parts)
    aggs = (("d0", "sum"), ("d0", "count"), ("d0", "min"), ("d0", "max"),
            ("d0", "mean"), ("d0", "var"), ("d0", "first"), ("d1", "sum"))

    # reference: local groupby over the global concatenation in shard order
    cols = {k: np.concatenate([p.to_numpy()[k] for p in parts])
            for k in parts[0].column_names}
    ref_t = A.groupby(Table.from_arrays(cols), "k", aggs)
    ref = ref_t.to_numpy()

    out = {"groups_expect": int(ref_t.row_count)}
    received = {}
    for strat, cb in (("shuffle", 1024), ("two_phase", 64)):
        g, (st,) = ctx.groupby(dt, "k", aggs, strategy=strat,
                               bucket_capacity=cb)
        d = g.to_table().to_numpy()
        order = np.argsort(d["k"])
        ok = bool(np.array_equal(d["k"][order], ref["k"]))
        exact = ("d0_count",)
        for name in ref:
            got = d[name][order]
            if name in exact or not np.issubdtype(got.dtype, np.floating):
                ok &= bool(np.array_equal(got, ref[name]))
            else:
                ok &= bool(np.allclose(got, ref[name], atol=1e-4, rtol=1e-4))
        out[f"{strat}_ok"] = ok
        out[f"{strat}_overflow"] = int(np.asarray(st.overflow).sum())
        received[strat] = int(np.asarray(st.received).sum())
        out[f"{strat}_received"] = received[strat]
    out["two_phase_fewer_rows"] = received["two_phase"] < received["shuffle"]
    return out


def case_plan_fused():
    """Fused LazyFrame chain == eager op-by-op on 8 shards, with strictly
    fewer AllToAlls (pushdown + elision), including the co-partitioned
    join fast path."""
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards

    def int_table(n, kr, seed):
        rng = np.random.default_rng(seed)
        return Table.from_arrays({
            "k": rng.integers(0, kr, n).astype(np.int32),
            "d0": rng.integers(-40, 40, n).astype(np.float32),
            "d1": rng.integers(-40, 40, n).astype(np.float32)})

    cap, kr = 600, 2400  # sparse join: no truncation on either path
    orders = ctx.from_local_parts([int_table(cap, kr, 100 + i)
                                   for i in range(p)])
    users = ctx.from_local_parts([int_table(cap, kr, 200 + i)
                                  for i in range(p)])
    dims, _ = ctx.partition_by(ctx.scatter(Table.from_arrays({
        "k": np.arange(kr, dtype=np.int32),
        "dval": (np.arange(kr) % 31).astype(np.float32)})), "k")
    aggs = (("d0", "sum"), ("d0", "mean"), ("d0", "count"), ("d0_r", "max"))
    gb_bucket = 2 * cap  # eager re-shuffles are all self-sends: one bucket

    erep: list = []
    j, (sl, sr) = ctx.join(orders, users, "k", report=erep)
    s = ctx.select(j, lambda c: c["d0"] > 0.0, key="pos", report=erep)
    g, (sg,) = ctx.groupby(s, "k", aggs, strategy="shuffle",
                           bucket_capacity=gb_bucket, report=erep)
    e_out, (s3l, s3r) = ctx.join(g, dims, "k", bucket_capacity=gb_bucket,
                                 report=erep)
    eager_overflow = sum(int(np.asarray(x.overflow).sum())
                         for x in (sl, sr, sg, s3l, s3r))

    fused = (ctx.frame(orders).join(ctx.frame(users), "k")
             .select(lambda c: c["d0"] > 0.0, key="pos")
             .groupby("k", aggs, strategy="shuffle",
                      bucket_capacity=gb_bucket)
             .join(ctx.frame(dims), "k", bucket_capacity=gb_bucket))
    frep = fused.plan_report()
    f_out, f_stats = fused.collect_with_stats()
    fused_overflow = sum(int(np.asarray(x.overflow).sum()) for x in f_stats)

    from repro.testing.compare import tables_bitwise_equal
    identical = tables_bitwise_equal(e_out, f_out)
    return {
        "identical": identical,
        "rows": int(f_out.global_rows()),
        "eager_overflow": eager_overflow,
        "fused_overflow": fused_overflow,
        "eager_alltoall": sum(not r["elided"] for r in erep),
        "fused_alltoall": sum(not r["elided"] for r in frep),
        "eager_wire": sum(r["wire_bytes"] for r in erep),
        "fused_wire": sum(r["wire_bytes"] for r in frep),
    }


def case_sort_chain():
    """Range-partition provenance: fused sort->join (sort-merge) keeps the
    sorted side in place and range-aligns the other side — exactly one
    fewer AllToAll than eager, identical row multiset — and the range tag
    survives the join so a chained groupby elides its shuffle too."""
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards

    def int_table(n, kr, seed):
        rng = np.random.default_rng(seed)
        return Table.from_arrays({
            "k": rng.integers(0, kr, n).astype(np.int32),
            "d0": rng.integers(-40, 40, n).astype(np.float32)})

    cap, kr = 500, 4000  # sparse join: no truncation on either path
    orders = ctx.from_local_parts([int_table(cap, kr, 300 + i)
                                   for i in range(p)])
    users = ctx.from_local_parts([int_table(cap, kr, 400 + i)
                                  for i in range(p)])
    bucket = 2 * cap

    erep: list = []
    s_e, (st_s,) = ctx.sort(orders, "k", bucket_capacity=bucket, report=erep)
    e_out, (sl, sr) = ctx.join(s_e, users, "k", algorithm="sort",
                               bucket_capacity=bucket, report=erep)
    eager_overflow = sum(int(np.asarray(x.overflow).sum())
                         for x in (st_s, sl, sr))

    fused = (ctx.frame(orders).sort("k", bucket_capacity=bucket)
             .join(ctx.frame(users), "k", algorithm="sort",
                   bucket_capacity=bucket))
    frep = fused.plan_report()
    f_out, f_stats = fused.collect_with_stats()
    fused_overflow = sum(int(np.asarray(x.overflow).sum()) for x in f_stats)

    from repro.testing.compare import tables_bitwise_equal
    out = {
        "identical": tables_bitwise_equal(e_out, f_out),
        "rows": int(f_out.global_rows()),
        "eager_overflow": eager_overflow,
        "fused_overflow": fused_overflow,
        "eager_alltoall": sum(not r["elided"] for r in erep),
        "fused_alltoall": sum(not r["elided"] for r in frep),
    }

    # eager provenance: ctx.sort's RangePartitioning tag rides the frame()
    # boundary, so the downstream groupby elides its shuffle entirely
    gb = ctx.frame(s_e).groupby("k", (("d0", "sum"), ("d0", "count")))
    gb_rep = gb.plan_report()
    g_f = gb.collect()
    g_e, _ = ctx.groupby(s_e, "k", (("d0", "sum"), ("d0", "count")))
    out["groupby_elided"] = all(r["elided"] for r in gb_rep)
    out["groupby_identical"] = tables_bitwise_equal(g_e, g_f)
    return out


def case_sort_align_skew():
    """Regression: the range-align join must survive probe-side key skew
    with DEFAULT bucket sizing. Every probe row here targets a single
    anchor range; hash-sized buckets (~2*cap/p per destination) would
    silently drop most of them pre-join, diverging from eager."""
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    rng = np.random.default_rng(23)
    anchor = ctx.from_local_parts([Table.from_arrays({
        "k": rng.integers(0, 1_000_000, 400).astype(np.int32),
        "d0": rng.integers(-9, 9, 400).astype(np.float32)})
        for _ in range(p)])
    probe = ctx.from_local_parts([Table.from_arrays({
        "k": rng.integers(600_000, 600_100, 300).astype(np.int32),
        "d0": rng.integers(-9, 9, 300).astype(np.float32)})
        for _ in range(p)])

    s, _ = ctx.sort(anchor, "k")
    eager, _ = ctx.join(s, probe, "k")
    fused = ctx.frame(anchor).sort("k").join(ctx.frame(probe), "k")
    f_out, f_stats = fused.collect_with_stats()

    from repro.testing.compare import tables_bitwise_equal
    return {
        "identical": tables_bitwise_equal(eager, f_out),
        "fused_overflow": sum(int(np.asarray(x.overflow).sum())
                              for x in f_stats),
        "rows": int(f_out.global_rows()),
    }


def case_global_limit():
    """Global limit == the local oracle: head-n of the shard-order
    concatenation on unordered plans, the true top-n (bit-identical) after
    sort — never the per-shard heads."""
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    rng = np.random.default_rng(17)
    n_per = 200
    # unique keys: the global top-n is a unique row set, so the oracle
    # comparison is bit-exact even through the distributed sort
    keys = rng.permutation(p * n_per).astype(np.int32)
    d0 = rng.integers(-99, 99, p * n_per).astype(np.float32)
    parts = [Table.from_arrays({"k": keys[i * n_per:(i + 1) * n_per],
                                "d0": d0[i * n_per:(i + 1) * n_per]})
             for i in range(p)]
    dt = ctx.from_local_parts(parts)

    out = {"ok": True, "checked": []}
    for n in (0, 1, 7, 64, n_per + 3, p * n_per, p * n_per + 50):
        got = ctx.limit(dt, n).to_table().to_numpy()
        expect = min(n, p * n_per)
        head_ok = (len(got["k"]) == expect
                   and np.array_equal(got["k"], keys[:expect])
                   and np.array_equal(got["d0"], d0[:expect]))

        topn = (ctx.frame(dt).sort("k").limit(n).collect()
                .to_table().to_numpy())
        order = np.argsort(keys, kind="stable")
        top_ok = (np.array_equal(topn["k"], keys[order][:expect])
                  and np.array_equal(topn["d0"], d0[order][:expect]))
        out["ok"] = out["ok"] and head_ok and top_ok
        out["checked"].append([n, bool(head_ok), bool(top_ok)])

    # the limit node must be attributed in the wire accounting at 0 bytes
    rep = ctx.frame(dt).sort("k").limit(9).plan_report()
    lim = [r for r in rep if r["op"] == "limit"]
    out["limit_reported_zero"] = (len(lim) == 1
                                  and lim[0]["wire_bytes"] == 0)
    return out


def case_overflow_retry():
    """The cost model's overflow-safe contract: a skewed repartition whose
    stats-sized first-pass bucket overflows (every row shares one key, so
    one destination absorbs everything the Poisson sizing spread over p)
    must recompile ONCE at conservative capacities and still match the
    local oracle bit-for-bit — never return the truncated result."""
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    n_per = 400
    parts = [Table.from_arrays({
        "k": np.zeros(n_per, np.int32),  # ONE key: maximal placement skew
        "d0": np.arange(i * n_per, (i + 1) * n_per).astype(np.float32)})
        for i in range(p)]
    dt = ctx.analyze(ctx.from_local_parts(parts))
    assert dt.stats is not None and dt.stats.col("k").ndv <= 2.0

    out, (st,) = ctx.partition_by(dt, "k")
    got = out.to_table().to_numpy()
    # oracle: all rows land on hash(0)'s shard, ordered by source shard
    # then original row order == the input's global concatenation order
    want_d0 = np.concatenate([np.asarray(t.columns["d0"]) for t in parts])
    retries_first = ctx.overflow_retries
    # a failed-estimate output must carry no propagated stats (downstream
    # stages fall back to conservative sizing, no cascade)
    stats_dropped = out.stats is None
    # the same plan again: known-bad key goes STRAIGHT to the safe plan —
    # one conservative execution, no doomed sized run, no new retry
    out2, (st2,) = ctx.partition_by(dt, "k")
    got2 = out2.to_table().to_numpy()
    return {
        "retries": retries_first,
        "retries_after_repeat": ctx.overflow_retries,
        "stats_dropped": stats_dropped,
        "rows": int(out.global_rows()),
        "rows_expect": p * n_per,
        "final_overflow": int(np.asarray(st.overflow).sum()
                              + np.asarray(st2.overflow).sum()),
        "identical": bool(np.array_equal(got["d0"], want_d0)
                          and np.array_equal(got["k"],
                                             np.zeros(p * n_per, np.int32))
                          and np.array_equal(got2["d0"], want_d0)),
    }


def case_cost_groupby():
    """Cost-model strategy choice + capacity right-sizing on 8 shards:
    the optimizer must pick two_phase at low key cardinality and raw
    shuffle at high cardinality, ship strictly fewer dense wire bytes
    than the fixed-slack no-stats baseline at BOTH ends, and stay
    bit-identical to the eager result (integer-valued float payloads)."""
    from repro.core import plan as PL
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    rows_per = 600
    aggs = (("d0", "sum"), ("d0", "count"), ("d0", "min"))

    def run(key_range):
        parts = [Table.from_arrays({
            "k": np.random.default_rng(500 + key_range + i)
            .integers(0, key_range, rows_per).astype(np.int32),
            "d0": np.random.default_rng(900 + i)
            .integers(-40, 40, rows_per).astype(np.float32)},
            capacity=2 * rows_per)  # half-full: stats know what slack can't
            for i in range(p)]
        raw = ctx.from_local_parts(parts)
        analyzed = ctx.analyze(raw)
        base = ctx.frame(raw).groupby("k", aggs)      # no stats: fallback
        cost = ctx.frame(analyzed).groupby("k", aggs)  # stats: cost model
        strategy = cost.optimized().strategy
        base_wire = sum(r["wire_bytes"] for r in base.plan_report())
        cost_wire = sum(r["wire_bytes"] for r in cost.plan_report())
        eager, _ = ctx.groupby(raw, "k", aggs)
        got, stats = cost.collect_with_stats()
        from repro.testing.compare import tables_bitwise_equal
        return {
            "strategy": strategy,
            "base_wire": base_wire, "cost_wire": cost_wire,
            "identical": tables_bitwise_equal(eager, got),
            "overflow": sum(int(np.asarray(s.overflow).sum())
                            for s in stats),
        }

    out = {"low": run(32), "high": run(rows_per * p * 4),
           "retries": ctx.overflow_retries}
    return out


def case_window_chain():
    """Window functions over a sorted frame: the fused sort -> window ->
    select chain must run the window with ZERO AllToAlls (the sort's range
    placement satisfies it; cross-shard group carries ride a p-sized
    boundary all_gather), stay bit-identical to the single-host oracle
    for all 8 window functions, and strictly undercut the naive lowering
    (window pays its own range shuffle) on wire bytes."""
    from repro.core import ops_agg as A
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    rng = np.random.default_rng(31)
    n_per = 300
    n = p * n_per
    # FEW groups so nearly every group spans several shards (the carry
    # fold does real work); unique order values keep every function —
    # including cumsum/lag — deterministic, hence bit-comparable
    k = rng.integers(0, 5, n).astype(np.int32)
    o = rng.permutation(n).astype(np.int32)
    d0 = rng.integers(-30, 30, n).astype(np.float32)
    parts = [Table.from_arrays({
        "k": k[i * n_per:(i + 1) * n_per],
        "o": o[i * n_per:(i + 1) * n_per],
        "d0": d0[i * n_per:(i + 1) * n_per]}) for i in range(p)]
    dt = ctx.from_local_parts(parts)
    funcs = ["rank", "dense_rank", "row_number", ("lag", "d0"),
             ("lead", "d0"), ("cumsum", "d0"), ("cummax", "d0"),
             ("running_mean", "d0")]
    pairs = A.normalize_funcs(funcs)

    # single-host oracle (pure numpy, tests/oracle.py semantics inlined
    # via the local operator, itself oracle-verified in tests/test_window)
    local = A.window(Table.from_arrays({"k": k, "o": o, "d0": d0}), "k",
                     funcs, order_by="o").to_numpy()

    # naive lowering: the window node pays its own range partition
    naive = ctx.frame(dt).window("k", funcs, order_by="o")
    nrep = naive.plan_report()
    n_out, n_stats = naive.collect_with_stats()
    got_naive = n_out.to_table().to_numpy()

    # pre-sorted lowering: fused sort -> window -> select
    fused = (ctx.frame(dt).sort(["k", "o"]).window("k", funcs, order_by="o")
             .select(lambda c: c["rank"] <= 9, key="top9"))
    frep = fused.plan_report()
    f_out, f_stats = fused.collect_with_stats()
    got = f_out.to_table().to_numpy()

    ok = True
    for name in local:
        ok &= bool(np.array_equal(got_naive[name], local[name]))
    sel = local["rank"] <= 9
    for name in local:
        ok &= bool(np.array_equal(got[name], local[name][sel]))

    win_rep = [r for r in frep if r["op"] == "window"]
    return {
        "identical": ok,
        "rows": int(f_out.global_rows()),
        "rows_expect": int(sel.sum()),
        "naive_overflow": sum(int(np.asarray(s.overflow).sum())
                              for s in n_stats),
        "fused_overflow": sum(int(np.asarray(s.overflow).sum())
                              for s in f_stats),
        "window_elided": len(win_rep) == 1 and win_rep[0]["elided"]
        and win_rep[0]["wire_bytes"] == 0,
        "naive_window_alltoall": sum(not r["elided"] for r in nrep),
        "fused_alltoall": sum(not r["elided"] for r in frep),
        "naive_wire": sum(r["wire_bytes"] for r in nrep),
        "fused_window_wire": sum(r["wire_bytes"] for r in frep
                                 if r["op"] == "window"),
    }


def case_window_thin_shards():
    """Adversarial carry stitching: a group split across shards whose
    per-shard portions are SMALLER than the lag/lead offset (the boundary
    buffers must merge across several shards), plus an empty middle shard.
    The input is hand-tagged range-partitioned so the crafted placement is
    preserved (shuffle elided) — the carry fold sees exactly these cuts."""
    import dataclasses

    from repro.core import ops_agg as A
    from repro.core.repartition import (RangePartitioning,
                                        fresh_range_fingerprint)
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    sizes = [6, 1, 2, 0, 1, 6, 1, 3]
    group = [0, 0, 0, 0, 0, 0, 1, 1]  # group id per shard (contiguous)
    assert p == len(sizes), (p, len(sizes))  # the cuts are crafted for 8
    n = sum(sizes)
    cap = 8
    o_all = np.arange(n, dtype=np.int32)
    d_all = (np.arange(n, dtype=np.int32) * 3 - 7).astype(np.float32)
    k_all = np.concatenate([np.full(s, g, np.int32)
                            for s, g in zip(sizes, group)])
    parts, off = [], 0
    for i in range(p):
        s = sizes[i]
        parts.append(Table.from_arrays(
            {"k": np.pad(k_all[off:off + s], (0, cap - s)),
             "o": np.pad(o_all[off:off + s], (0, cap - s)),
             "d0": np.pad(d_all[off:off + s], (0, cap - s))},
            row_count=s))
        off += s
    dt = dataclasses.replace(
        ctx.from_local_parts(parts),
        partitioning=RangePartitioning(("k", "o"), p,
                                       fresh_range_fingerprint()))
    funcs = ["rank", "dense_rank", "row_number", ("lag", "d0", 4),
             ("lead", "d0", 4), ("cumsum", "d0"), ("cummax", "d0"),
             ("running_mean", "d0")]
    fr = ctx.frame(dt).window("k", funcs, order_by="o")
    rep = fr.plan_report()
    got = fr.collect().to_table().to_numpy()
    local = A.window(Table.from_arrays(
        {"k": k_all, "o": o_all, "d0": d_all}), "k", funcs,
        order_by="o").to_numpy()
    ok = all(bool(np.array_equal(got[name], local[name])) for name in local)
    return {"identical": ok, "rows": int(len(got["k"])), "rows_expect": n,
            "window_elided": all(r["elided"] for r in rep
                                 if r["op"] == "window")}


def case_sort_multikey():
    """Multi-key distributed sort: global lexicographic order across shards,
    row multiset preserved."""
    from repro.core.table import Table

    ctx = _ctx()
    rng = np.random.default_rng(13)
    parts = [Table.from_arrays({
        "k": rng.integers(0, 40, 700).astype(np.int32),   # heavy ties
        "d0": rng.integers(-1000, 1000, 700).astype(np.int32),
        "d1": rng.standard_normal(700).astype(np.float32)})
        for _ in range(ctx.num_shards)]
    dt = ctx.from_local_parts(parts)
    s, (st,) = ctx.sort(dt, ["k", "d0"], bucket_capacity=4096)
    d = s.to_table().to_numpy()
    pairs = list(zip(d["k"].tolist(), d["d0"].tolist()))
    in_rows = sorted(
        (int(k), int(v)) for t in parts
        for k, v in zip(t.to_numpy()["k"], t.to_numpy()["d0"]))
    return {
        "rows": len(pairs),
        "rows_expect": len(in_rows),
        "order_ok": all(x <= y for x, y in zip(pairs, pairs[1:])),
        "multiset_ok": sorted(pairs) == in_rows,
        "overflow": int(np.asarray(st.overflow).sum()),
    }


def case_moe_ep():
    """EP shard_map dispatch == single-device dispatch (same weights)."""
    from repro.models.common import ModelConfig
    from repro.models.moe import init_moe, moe_fwd
    from repro.models.common import ShardingRules

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(arch="m", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      moe_num_experts=8, moe_top_k=2, moe_num_shared=1,
                      moe_d_ff=48, moe_capacity_factor=8.0)
    rules = ShardingRules(dict(mesh.shape), False)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, rules)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)

    y_local, aux_l = moe_fwd(p, x, cfg, rules, None)
    with mesh:
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_fwd(p, x, cfg, rules, mesh))(p, x)
    err = float(jnp.max(jnp.abs(y_local - y_ep)))
    # EP computes the load-balance aux per seq-shard then pmeans it — a
    # deliberate approximation of the global statistic (what distributed
    # MoEs ship). With 8 tokens/shard it is noisy: check it is a sane
    # positive value near the uniform-routing expectation (1.0).
    return {"moe_ep_err": err,
            "moe_dropped_local": float(aux_l["moe_dropped"]),
            "aux_close": 0.5 < float(aux_ep["moe_aux"]) < 3.0
            and float(aux_l["moe_aux"]) > 0}


def case_moe_decode_psum():
    """Decode-path (psum) MoE == local MoE for S == 1."""
    from repro.models.common import ModelConfig, ShardingRules
    from repro.models.moe import init_moe, moe_fwd

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(arch="m", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      moe_num_experts=8, moe_top_k=2, moe_num_shared=0,
                      moe_d_ff=48, moe_capacity_factor=8.0)
    rules = ShardingRules(dict(mesh.shape), False)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, rules)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 32), jnp.float32)
    y_local, _ = moe_fwd(p, x, cfg, rules, None)
    with mesh:
        y_ep, _ = jax.jit(lambda p, x: moe_fwd(p, x, cfg, rules, mesh))(p, x)
    return {"moe_decode_err": float(jnp.max(jnp.abs(y_local - y_ep)))}


def case_flash_decode_shard():
    """Seq-sharded flash decode == plain decode attention."""
    from repro.models import layers as NN
    from repro.models.common import ModelConfig

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(arch="d", family="dense", num_layers=1, d_model=64,
                      num_heads=8, num_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=8, decode_seq_shard=True)
    rng = np.random.default_rng(0)
    B, S_max = 4, 64
    cache = {"k": jnp.asarray(rng.standard_normal((B, S_max, 2, 8)),
                              jnp.float32),
             "v": jnp.asarray(rng.standard_normal((B, S_max, 2, 8)),
                              jnp.float32)}
    p, _ = NN.init_attention(jax.random.PRNGKey(0), cfg,
                             __import__("repro.models.common",
                                        fromlist=["ShardingRules"])
                             .ShardingRules(dict(mesh.shape), False))
    x = jnp.asarray(rng.standard_normal((B, 1, 64)), jnp.float32)
    pos = jnp.asarray(17, jnp.int32)
    sin_cos = NN.rope_tables(jnp.arange(1) + 17, cfg.hd, 1e4)
    with mesh:
        y_shard, _ = jax.jit(lambda p, x, c: NN.attention_fwd(
            p, x, cfg, mode="decode", rope=sin_cos, cache=c, pos=pos,
            mesh=mesh))(p, x, cache)
    y_plain, _ = NN.attention_fwd(p, x, cfg, mode="decode", rope=sin_cos,
                                  cache=cache, pos=pos, mesh=None)
    return {"flash_decode_err": float(jnp.max(jnp.abs(y_shard - y_plain)))}


def case_compress_pod():
    """int8 error-feedback pod gradients: quantized mean close to exact,
    error feedback reduces bias across steps."""
    from repro.models.common import ModelConfig
    from repro.models.factory import build_model
    from repro.train.optimizer import OptConfig
    from repro.train.steps import (init_train_state, make_train_step,
                                   train_state_specs)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig(arch="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                      head_dim=8, remat="none")
    model = build_model(cfg, mesh)
    ocfg = OptConfig(lr=1e-2, warmup_steps=2, total_steps=20)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, 128, (8, 16)), jnp.int32),
             "weight": jnp.ones((8,), jnp.float32)}
    with mesh:
        st_c = init_train_state(model, jax.random.PRNGKey(0),
                                compress_pod=True, n_pods=2)
        step_c = jax.jit(make_train_step(model, ocfg, compress_pod=True))
        st_e = init_train_state(model, jax.random.PRNGKey(0))
        step_e = jax.jit(make_train_step(model, ocfg))
        for i in range(3):
            st_c, mc = step_c(st_c, batch)
            st_e, me = step_e(st_e, batch)
    # compressed training should track exact training closely
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(st_c.params),
                             jax.tree.leaves(st_e.params))]
    return {"pod_compress_max_param_diff": max(diffs),
            "loss_close": abs(float(mc["loss"]) - float(me["loss"])) < 0.2}


def case_elastic_restore():
    """Save on a (4,2) mesh, restore on (2,4) and (8,) — loss identical."""
    import tempfile

    from repro.models.common import ModelConfig
    from repro.models.factory import build_model
    from repro.train import checkpoint as ckpt
    from repro.train.steps import init_train_state, train_state_specs

    cfg = ModelConfig(arch="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                      head_dim=8, remat="none")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, 128, (8, 16)), jnp.int32),
             "weight": jnp.ones((8,), jnp.float32)}

    losses = {}
    d = tempfile.mkdtemp()
    state0 = None
    for name, shape, axes in [("a", (4, 2), ("data", "model")),
                              ("b", (2, 4), ("data", "model")),
                              ("c", (8, 1), ("data", "model"))]:
        mesh = jax.make_mesh(shape, axes)
        model = build_model(cfg, mesh)
        with mesh:
            if state0 is None:
                state = init_train_state(model, jax.random.PRNGKey(0))
                ckpt.save(d, 1, state)
                state0 = True
            from repro.train.steps import train_state_specs as tss
            like = jax.eval_shape(
                lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
            state, step = ckpt.CheckpointManager(d).resume(
                like, mesh=mesh, specs=tss(model))
            loss, _ = jax.jit(model.loss_fn)(state.params, batch)
        losses[name] = float(loss)
    vals = list(losses.values())
    # different mesh shapes change bf16 reduction order: allow ~1e-3
    return {"elastic_losses": vals,
            "elastic_ok": max(vals) - min(vals) < 2e-3}


def case_serving_async():
    """Concurrent-query serving on 8 shards: N interleaved clients driving
    collect_async through a shared ServingSession must produce per-query
    results bit-identical to sequential collects, with ZERO compiles on
    the warm cache (including the inline keyless lambda — code-identity
    keys keep a re-created predicate hot), and out-of-order future
    resolution must not perturb anything."""
    from repro.core.serving import ServingSession
    from repro.core.table import Table
    from repro.testing.compare import tables_bitwise_equal

    ctx = _ctx()
    p = ctx.num_shards
    rng = np.random.default_rng(71)
    n = 500 * p
    orders = Table.from_arrays({
        "k": rng.integers(0, 64, n).astype(np.int32),
        "d0": rng.integers(-50, 50, n).astype(np.float32)})
    dims = Table.from_arrays({
        "k": np.arange(64, dtype=np.int32),
        "w": rng.integers(0, 9, 64).astype(np.float32)})
    sess = ServingSession(ctx, max_in_flight=6)
    sess.register("orders", orders, analyze=True)
    sess.register("dims", dims, analyze=True)
    workload = [
        ("gb", lambda s: s.frame("orders")
            .groupby("k", (("d0", "sum"), ("d0", "count")))),
        ("topn", lambda s: s.frame("orders").sort("k").limit(16)),
        ("sel", lambda s: s.frame("orders")
            .select(lambda c: c["d0"] > 0.0)
            .groupby("k", (("d0", "mean"),))),
        ("join", lambda s: s.frame("orders").join(s.frame("dims"), "k")
            .groupby("k", (("w", "sum"),))),
    ]
    seq_rep, seq_res = sess.run_open_loop(
        workload, num_clients=3, queries_per_client=2, mode="sequential")
    asy_rep, asy_res = sess.run_open_loop(
        workload, num_clients=3, queries_per_client=2, mode="async")
    identical = all(tables_bitwise_equal(a.to_table(), b.to_table())
                    for a, b in zip(asy_res, seq_res))

    # out-of-order resolution: submit every shape, resolve in REVERSE
    pre = ctx.cache_stats()
    base = [sess.submit(b).result() for _, b in workload]
    futs = [sess.submit(b) for _, b in workload]
    rev = [f.result() for f in reversed(futs)][::-1]
    rev_ok = all(tables_bitwise_equal(a.to_table(), b.to_table())
                 for a, b in zip(rev, base))
    return {
        "identical": identical,
        "reverse_resolution_ok": rev_ok,
        "cold_compiles": seq_rep.compiles,
        "warm_compiles": asy_rep.compiles + (
            ctx.cache_stats()["misses"] - pre["misses"]),
        "warm_recompiles": asy_rep.recompiles,
        "queries_per_mode": seq_rep.num_queries,
        "seq_qps": seq_rep.qps, "async_qps": asy_rep.qps,
        "p50_ms": asy_rep.p50_ms, "p99_ms": asy_rep.p99_ms,
    }


def case_async_overflow_deferred():
    """The deferred-verification contract on the async path: a cost-sized
    plan with a WRONG estimate (single-key skew, same setup as
    case_overflow_retry) dispatches with no host sync — the overflow is
    only discovered at ``future.result()``, which runs EXACTLY ONE
    safe-capacity retry and returns oracle-exact rows. A repeat submit of
    the known-bad plan goes straight to the safe executable (no new
    retry), and both the sized and safe executables sit in the plan cache
    under distinct key namespaces."""
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards
    n_per = 400
    parts = [Table.from_arrays({
        "k": np.zeros(n_per, np.int32),  # ONE key: maximal placement skew
        "d0": np.arange(i * n_per, (i + 1) * n_per).astype(np.float32)})
        for i in range(p)]
    dt = ctx.analyze(ctx.from_local_parts(parts))
    assert dt.stats is not None and dt.stats.col("k").ndv <= 2.0

    fut = ctx.frame(dt).partition_by("k").collect_async()
    # dispatch must NOT have verified anything: the wrong estimate is
    # still unknown to the host, the future unresolved
    deferred = (ctx.overflow_retries == 0) and not fut.done
    out = fut.result()  # <- verification: discovers overflow, retries safe
    got = out.to_table().to_numpy()
    want_d0 = np.concatenate([np.asarray(t.columns["d0"]) for t in parts])
    retries_first = ctx.overflow_retries
    again = fut.result()  # resolved future: same object, no re-execution
    idempotent = again is out

    # repeat submit: the known-bad key routes straight to the safe plan
    out2 = ctx.frame(dt).partition_by("k").collect_async().result()
    got2 = out2.to_table().to_numpy()
    namespaces = sorted({k[0][0] for k in ctx.plan_cache.keys()})
    return {
        "deferred": deferred,
        "retries": retries_first,
        "retries_after_repeat": ctx.overflow_retries,
        "idempotent": idempotent,
        "stats_dropped": out.stats is None,
        "rows": int(out.global_rows()),
        "rows_expect": p * n_per,
        "identical": bool(
            np.array_equal(got["d0"], want_d0)
            and np.array_equal(got["k"], np.zeros(p * n_per, np.int32))
            and np.array_equal(got2["d0"], want_d0)),
        "cache_namespaces": namespaces,
    }


def case_staged_shuffle():
    """Staged / ring shuffles vs the monolithic exchange, under skew.

    Bit-identity is the whole contract: identical rows (sorted-multiset
    bit compare), identical overflow with an undersized bucket, identical
    wire-byte accounting in the report — only the collective decomposition
    differs. Also regression-covers the empty-table edge (capacity-0
    shards through a staged shuffle).
    """
    from repro.core.table import Table
    from repro.testing.compare import tables_bitwise_equal

    ctx = _ctx()
    p = ctx.num_shards
    rng = np.random.default_rng(11)
    n_per = 300
    # heavy skew: ~half the rows share one key -> one destination bucket
    # overflows at bucket_capacity=64 (300 rows/shard, ~150 to one shard)
    k = np.where(rng.random(p * n_per) < 0.5, 0,
                 rng.integers(0, 997, p * n_per)).astype(np.int32)
    host = Table.from_arrays({"k": k,
                              "v": rng.random(p * n_per).astype(np.float32)})
    dt = ctx.scatter(host, local_capacity=n_per)

    results, reports = {}, {}
    for name, kw in (("mono", dict(stages=1)),
                     ("staged", dict(stages=3)),
                     ("ring", dict(shuffle_mode="ring"))):
        rep = []
        out, (st,) = ctx.partition_by(dt, "k", bucket_capacity=64,
                                      report=rep, **kw)
        results[name] = (out, int(np.asarray(st.overflow).sum()),
                         int(out.global_rows()))
        reports[name] = rep[0]

    mono, staged, ring = (results[n] for n in ("mono", "staged", "ring"))
    # empty table (capacity-0 shards) through a staged shuffle: the
    # pack_by_partition n==0 guard and the c==0 gather guard
    empty = ctx.from_local_parts(
        [Table.empty({"k": jnp.int32}, 0)] * p)
    eout, (est_,) = ctx.partition_by(empty, "k", bucket_capacity=4, stages=2)

    return {
        "overflow_mono": mono[1],
        "overflow_positive": mono[1] > 0,
        "overflow_identical": mono[1] == staged[1] == ring[1],
        "rows_identical": mono[2] == staged[2] == ring[2],
        "staged_bitwise_equal": tables_bitwise_equal(mono[0], staged[0]),
        "ring_bitwise_equal": tables_bitwise_equal(mono[0], ring[0]),
        "wire_bytes_identical": len({reports[n]["wire_bytes"]
                                     for n in reports}) == 1,
        "stages_reported": [reports[n]["stages"]
                            for n in ("mono", "staged", "ring")],
        "modes_reported": [reports[n]["mode"]
                           for n in ("mono", "staged", "ring")],
        "empty_rows": int(eout.global_rows()),
        "empty_overflow": int(np.asarray(est_.overflow).sum()),
    }


def case_verify_audit():
    """``verify.audit_collectives`` on 8 shards: the static per-record
    accounting derived from ``plan_report`` must equal the collective
    counts in the actually-traced fused jaxpr, across every distributed
    operator family — hash-shuffled groupby chain, sort->join range
    alignment (sort-merge fast path), sort->window boundary carries,
    staged and ring explicit repartitions, and a global limit."""
    from repro.core import verify as V
    from repro.core.table import Table

    ctx = _ctx()
    p = ctx.num_shards

    def int_table(n, kr, seed):
        rng = np.random.default_rng(seed)
        return Table.from_arrays({
            "k": rng.integers(0, kr, n).astype(np.int32),
            "d0": rng.integers(-40, 40, n).astype(np.float32),
            "d1": rng.integers(-40, 40, n).astype(np.float32)})

    cap, kr = 200, 800
    orders = ctx.from_local_parts([int_table(cap, kr, 500 + i)
                                   for i in range(p)])
    users = ctx.from_local_parts([int_table(cap, kr, 600 + i)
                                  for i in range(p)])
    bucket = 2 * cap

    pipelines = {
        "groupby_chain": (
            ctx.frame(orders).join(ctx.frame(users), "k",
                                   bucket_capacity=bucket,
                                   out_capacity=4 * cap)
            .select(lambda c: c["d0"] > 0.0, key="pos")
            .groupby("k", (("d0", "sum"), ("d0", "count")),
                     strategy="shuffle", bucket_capacity=bucket)),
        "sort_join_align": (
            ctx.frame(orders).sort("k", bucket_capacity=bucket)
            .join(ctx.frame(users), "k", algorithm="sort",
                  bucket_capacity=bucket, out_capacity=4 * cap)),
        "sort_window": (
            ctx.frame(orders).sort(("k", "d1"), bucket_capacity=bucket)
            .window(("k",), (("rank", None, 0), ("cumsum", "d0", 0)),
                    order_by=("d1",), bucket_capacity=bucket)),
        "staged_shuffle": (
            ctx.frame(orders).partition_by("k", bucket_capacity=bucket,
                                           stages=3)),
        "ring_shuffle": (
            ctx.frame(orders).partition_by("k", bucket_capacity=bucket,
                                           shuffle_mode="ring")),
        "sorted_limit": (
            ctx.frame(orders).sort("k", bucket_capacity=bucket).limit(17)),
    }

    out = {}
    for name, fr in pipelines.items():
        audit = V.audit_collectives(fr, strict=False)
        out[name] = {"matched": audit["matched"],
                     "expected": audit["expected"],
                     "actual": audit["actual"]}
    out["all_matched"] = all(v["matched"] for v in out.values())
    return out


CASES = {k[5:]: v for k, v in list(globals().items())
         if k.startswith("case_")}


def main():
    case = sys.argv[1]
    out = CASES[case]()
    print("JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
