"""Exact result comparison for relational outputs (tests + benches).

Row order is not part of any operator's contract across execution
strategies (fused vs eager, shuffle vs two-phase), so equality is defined
on the SORTED row multiset over all columns — robust to duplicate keys,
exact on every dtype (a float bit-difference fails the check).
"""
from __future__ import annotations


def table_rows(t):
    """(sorted column names, row tuples sorted lexicographically)."""
    d = t.to_table().to_numpy() if hasattr(t, "to_table") else t.to_numpy()
    names = sorted(d)
    rows = sorted(zip(*(d[n].tolist() for n in names))) if names else []
    return names, rows


def tables_bitwise_equal(a, b) -> bool:
    """True iff both results hold the same columns and the identical row
    multiset, compared bit-exactly. Accepts DistTable or Table."""
    na, ra = table_rows(a)
    nb, rb = table_rows(b)
    return na == nb and ra == rb
