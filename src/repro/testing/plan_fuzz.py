"""Random-plan correctness fuzzer: verifier-clean + bit-identical to eager.

Generates arbitrary well-typed LazyFrame chains over every plan-node type
(Select/Project/Limit/Repartition/Join/GroupBy/Sort/Window/SetOp/Distinct)
and checks, per plan:

1. the optimizer's output passes every ``repro.core.verify`` rule
   (``REPRO_VERIFY_PLANS`` also makes ``optimize()`` raise on violations);
2. ``canonical_key`` is defined and stable for the optimized plan;
3. the FUSED result (``optimize=True`` — pushdowns, elisions, cost sizing,
   staged shuffles all active) is bit-identical, as a sorted row multiset,
   to the EAGER oracle (the same logical plan with ``optimize=False``).

Bit-identity across different shuffle routes requires numeric discipline,
which the generator enforces by construction: integer columns are exact
(i32 wraps mod 2^32, associatively) and float columns carry an
(integer-valued, |value| bound) tag — order-sensitive float reductions
(sum/mean/var/cumsum/running_mean) are only generated where every
intermediate stays exactly representable in f32 (< 2^24), so any shard
cut or partial-aggregation order yields the same bits. Join partners are
unique-key dimension tables (row counts never grow), every shuffle gets
an explicit overflow-proof bucket unless the cost model is being
exercised (analyzed inputs + cost-sized capacities: a wrong estimate
triggers the safe-capacity retry, never wrong results), and order-
sensitive ops (limit; window/sort determinism) ride a tracked unique key.

Deterministic per (seed, index): the same seed always builds the same
data and the same plans. CLI (the CI ``plan-fuzz`` leg)::

    PYTHONPATH=src python -m repro.testing.plan_fuzz \
        --plans 200 --seed 20260807 --devices 8
"""
from __future__ import annotations

import os
import random
import sys

F32_EXACT = 1 << 24  # integers exactly representable in float32
MAX_ROWS = 1024      # global row bound the generator never exceeds
BUCKET = 1024        # >= any per-source-shard row count: never overflows
JOIN_OUT = 2048      # >= any per-shard join output under MAX_ROWS

_AGG_OPS = ("sum", "count", "min", "max", "mean", "var")  # no "first":
# first is placement-order-dependent, the one agg eager and fused may
# legitimately disagree on


class _Col:
    """Fuzzer-side column tag: dtype kind plus the float-exactness state
    the generator consults before emitting an order-sensitive reduction.

    ``kind``: "i" (int32) or "f" (float32). ``exact``: every value is an
    integer (always True for "i"). ``bound``: abs-value bound for floats
    (meaningless for ints — i32 wraps associatively, so int reductions
    are bit-deterministic at ANY magnitude)."""

    __slots__ = ("kind", "exact", "bound")

    def __init__(self, kind: str, exact: bool = True, bound: int = 0):
        self.kind, self.exact, self.bound = kind, exact, bound

    def sum_ok(self) -> bool:
        return self.kind == "i" or (self.exact
                                    and self.bound * MAX_ROWS < F32_EXACT)

    def var_ok(self) -> bool:
        return self.kind == "i" or (self.exact
                                    and self.bound * self.bound * MAX_ROWS
                                    < F32_EXACT)


class _Frame:
    """A LazyFrame plus the metadata the generator steers by."""

    def __init__(self, frame, cols: dict, unique: tuple, ordered: bool):
        self.frame = frame
        self.cols = cols          # name -> _Col, in schema order
        self.unique = unique      # column tuple that is a row key
        self.ordered = ordered    # shard-order == a deterministic total
        #                           order (sort/window by a unique suffix)
        self.ops: list[str] = []  # trace for failure reports


def make_inputs(ctx, data_seed: int, *, analyze: bool):
    """Three base DistTables: two fact tables sharing one schema (set-op
    operands) and a unique-key dimension table (join partner — joining a
    unique key never grows row counts, so capacities stay bounded)."""
    import numpy as np

    from repro.core.table import Table

    rng = np.random.default_rng(data_seed)
    p = ctx.num_shards
    rows, kr = max(8, 384 // p), 64

    def fact(seed_off):
        ids = rng.permutation(p * rows).astype(np.int32) + seed_off
        parts = []
        for i in range(p):
            s = slice(i * rows, (i + 1) * rows)
            parts.append(Table.from_arrays({
                "id": ids[s],
                "k": rng.integers(0, kr, rows).astype(np.int32),
                "g": rng.integers(0, 6, rows).astype(np.int32),
                "v": rng.integers(-40, 40, rows).astype(np.int32),
                "w": rng.integers(-25, 25, rows).astype(np.float32),
            }))
        return ctx.from_local_parts(parts)

    def dims():
        keys = rng.permutation(kr).astype(np.int32)
        per = kr // p
        parts = []
        for i in range(p):
            ks = keys[i * per:(i + 1) * per]
            parts.append(Table.from_arrays({
                "k": ks,
                "dv": rng.integers(-40, 40, per).astype(np.int32),
                "dw": rng.integers(-25, 25, per).astype(np.float32),
            }))
        return ctx.from_local_parts(parts)

    tabs = [fact(0), dims(), fact(10_000)]
    if analyze:
        tabs = [ctx.analyze(t) for t in tabs]
    return tabs


_FACT_COLS = {"id": ("i", 20_000), "k": ("i", 64), "g": ("i", 6),
              "v": ("i", 40), "w": ("f", 25)}
_DIM_COLS = {"k": ("i", 64), "dv": ("i", 40), "dw": ("f", 25)}


def _fresh(cols_spec):
    return {n: _Col(k, True, b) for n, (k, b) in cols_spec.items()}


def random_frame(ctx, inputs, r: random.Random, *, max_ops: int = 6,
                 cost_sized: bool = False) -> _Frame:
    """One random well-typed chain over the base tables. ``cost_sized``
    leaves shuffle capacities to the optimizer's cost model (requires
    analyzed inputs) instead of the explicit overflow-proof buckets."""
    fact, dims, fact2 = inputs
    st = _Frame(ctx.frame(fact), _fresh(_FACT_COLS), ("id",), False)

    def bucket():
        # cost-sized plans may under-estimate; the safe-capacity retry
        # guarantees correctness. Explicit plans can never overflow.
        return None if cost_sized and r.random() < 0.6 else BUCKET

    def op_select():
        name = r.choice(list(st.cols))
        c = st.cols[name]
        if c.kind == "i":
            m, rem = r.randint(2, 5), 0
            rem = r.randrange(m)
            st.frame = st.frame.select(
                lambda t, name=name, m=m, rem=rem: t[name] % m == rem,
                key=("fuzz-mod", name, m, rem))
            st.ops.append(f"select({name}%{m}=={rem})")
        else:
            thr = r.randint(-20, 20)
            st.frame = st.frame.select(
                lambda t, name=name, thr=thr: t[name] > thr + 0.5,
                key=("fuzz-gt", name, thr))
            st.ops.append(f"select({name}>{thr}.5)")

    def op_project():
        keep = [n for n in st.cols
                if n in st.unique or r.random() < 0.6]
        if not keep:
            keep = [next(iter(st.cols))]
        st.frame = st.frame.project(tuple(keep))
        st.cols = {n: st.cols[n] for n in keep}
        if not all(u in keep for u in st.unique):
            st.unique = ()
        st.ops.append(f"project({keep})")

    def op_limit():
        n = r.choice([0, 1, 5, 17, 100, 1000])
        st.frame = st.frame.limit(n)
        st.ops.append(f"limit({n})")

    def op_sort():
        by = [r.choice(list(st.cols))] if r.random() < 0.5 else []
        by += [u for u in st.unique if u not in by]
        st.frame = st.frame.sort(tuple(by), bucket_capacity=bucket())
        st.ordered = True
        st.ops.append(f"sort({by})")

    def op_partition():
        keys = [n for n in st.cols if st.cols[n].kind == "i"]
        keys = r.sample(keys, r.randint(1, min(2, len(keys))))
        kw = {}
        if r.random() < 0.15:
            kw["shuffle_mode"] = "ring"
        else:
            kw["stages"] = r.choice([None, 2, 3])
        st.frame = st.frame.partition_by(tuple(keys),
                                         bucket_capacity=bucket(), **kw)
        st.ordered = False
        st.ops.append(f"partition({keys},{kw})")

    def op_groupby():
        keys = [n for n in ("k", "g") if n in st.cols]
        keys = r.sample(keys, r.randint(1, len(keys)))
        cands = []
        for n, c in st.cols.items():
            if n in keys:
                continue
            for agg in _AGG_OPS:
                if agg in ("sum", "mean") and not c.sum_ok():
                    continue
                if agg == "var" and not c.var_ok():
                    continue
                cands.append((n, agg))
        aggs = r.sample(cands, r.randint(1, min(3, len(cands))))
        st.frame = st.frame.groupby(
            tuple(keys), tuple(aggs),
            strategy=r.choice(["auto", "shuffle", "two_phase"]),
            bucket_capacity=bucket())
        out = {n: st.cols[n] for n in keys}
        for n, agg in aggs:
            c = st.cols[n]
            if agg == "count":
                out[f"{n}_{agg}"] = _Col("i")
            elif agg in ("mean", "var"):
                out[f"{n}_{agg}"] = _Col("f", exact=False)
            elif agg == "sum":
                out[f"{n}_{agg}"] = _Col(c.kind, c.exact,
                                         c.bound * MAX_ROWS)
            else:  # min/max: exact selection
                out[f"{n}_{agg}"] = _Col(c.kind, c.exact, c.bound)
        st.cols, st.unique, st.ordered = out, tuple(keys), False
        st.ops.append(f"groupby({keys},{aggs})")

    def op_window():
        from repro.core.ops_agg import window_output_name

        by = [n for n in ("k", "g") if n in st.cols]
        by = r.sample(by, r.randint(1, len(by)))
        order = [n for n in st.cols
                 if n not in by and r.random() < 0.3][:1]
        order += [u for u in st.unique if u not in by and u not in order]
        cands = [("rank", None, 0), ("dense_rank", None, 0),
                 ("row_number", None, 0)]
        for n, c in st.cols.items():
            off = r.choice([1, 1, 2, 4])
            cands += [("cummax", n, 0), ("lag", n, off), ("lead", n, off)]
            if c.sum_ok():
                cands += [("cumsum", n, 0), ("running_mean", n, 0)]
        picks, out = [], dict(st.cols)
        r.shuffle(cands)
        for fn, coln, off in cands[:r.randint(1, 3)]:
            name = window_output_name(fn, coln, off)
            if name in out:
                continue
            picks.append((fn, coln, off) if coln else fn)
            if coln is None:
                out[name] = _Col("i")
            elif fn == "cumsum":
                c = st.cols[coln]
                out[name] = _Col(c.kind, c.exact, c.bound * MAX_ROWS)
            elif fn == "running_mean":
                out[name] = _Col("f", exact=False)
            else:  # cummax/lag/lead: exact selection
                out[name] = st.cols[coln]
        if not picks:
            return
        st.frame = st.frame.window(tuple(by), tuple(picks),
                                   order_by=tuple(order),
                                   bucket_capacity=bucket())
        # rows come back range-placed + locally sorted on (by + order_by),
        # which ends with the unique key: a deterministic global order
        st.cols, st.ordered = out, True
        st.ops.append(f"window({by},{picks},{order})")

    def op_distinct():
        st.frame = st.frame.distinct(bucket_capacity=bucket())
        st.unique, st.ordered = tuple(st.cols), False
        st.ops.append("distinct")

    def op_join():
        how = "left" if r.random() < 0.25 else "inner"
        st.frame = st.frame.join(
            ctx.frame(dims), "k", how=how,
            algorithm=r.choice(["hash", "sort"]),
            bucket_capacity=BUCKET, out_capacity=JOIN_OUT)
        for n, (kind, b) in _DIM_COLS.items():
            out_n = n + "_r" if n in st.cols else n
            if out_n not in st.cols:
                st.cols[out_n] = _Col(kind, True, b)
        st.ordered = False
        st.ops.append(f"join(dims,{how})")

    def op_setop():
        kind = r.choice(["union", "intersect", "difference"])
        other = ctx.frame(fact2)
        st.frame = getattr(st.frame, kind)(other, bucket_capacity=bucket())
        st.unique, st.ordered = tuple(st.cols), False
        st.ops.append(kind)

    for _ in range(r.randint(2, max_ops)):
        ops = [op_select, op_select, op_project, op_sort, op_partition,
               op_distinct]
        if "k" in st.cols or "g" in st.cols:
            ops += [op_groupby, op_groupby, op_window, op_window]
        if st.ordered:
            ops.append(op_limit)
        if "k" in st.cols and sum(o.startswith("join")
                                  for o in st.ops) < 2:
            ops += [op_join, op_join]
        if tuple(st.cols) == tuple(_FACT_COLS):
            ops.append(op_setop)
        r.choice(ops)()
    return st


def check_frame(ctx, st: _Frame) -> dict:
    """Verifier-clean optimization + bit-identical fused-vs-eager rows.
    Raises AssertionError (with the op trace) on any divergence."""
    import numpy as np

    from repro.core import plan as PL
    from repro.core import verify as V
    from repro.testing.compare import tables_bitwise_equal

    fr = st.frame
    logical = fr.logical_plan()
    schemas = [t.schema for t in fr._inputs]
    stats = [t.stats for t in fr._inputs]
    optimized = PL.optimize(logical, schemas, ctx.num_shards, stats,
                            verify=False)
    findings = V.verify_plan(logical, optimized, schemas, ctx.num_shards,
                             stats)
    assert not findings, (st.ops, [str(f) for f in findings])
    key = PL.canonical_key(optimized)
    key2 = PL.canonical_key(PL.optimize(logical, schemas, ctx.num_shards,
                                        stats, verify=False))
    assert key == key2, (st.ops, "canonical_key unstable")

    # fused: the full optimizer + cost model + verify-on-optimize path
    fused, fstats = ctx._run_plan(logical, fr._inputs, optimize=True)
    # eager oracle: the logical plan as written, no rewrites
    eager, estats = ctx._run_plan(logical, fr._inputs, optimize=False)
    f_ovf = sum(int(np.asarray(s.overflow).sum()) for s in fstats)
    e_ovf = sum(int(np.asarray(s.overflow).sum()) for s in estats)
    assert e_ovf == 0, (st.ops, "eager overflow — fuzzer sizing bug")
    assert f_ovf == 0, (st.ops, "fused overflow survived the safe retry")
    assert tables_bitwise_equal(fused, eager), (
        st.ops, "fused result != eager oracle")
    return {"ops": list(st.ops), "rows": int(fused.global_rows()),
            "cacheable": key is not None}


def run_fuzz(num_plans: int, seed: int, *, max_ops: int = 6,
             ctx=None, log=None) -> dict:
    """The CI entry: ``num_plans`` seeded random plans, each checked by
    :func:`check_frame`. Returns summary counters; raises on the first
    failing plan (the message carries the plan's op trace and index)."""
    from repro.core.context import DistContext

    if ctx is None:
        ctx = DistContext(axis_name="fuzz")
    os.environ[
        "REPRO_VERIFY_PLANS"] = "1"  # optimize() must raise on findings
    inputs_plain = make_inputs(ctx, seed, analyze=False)
    inputs_stats = make_inputs(ctx, seed + 1, analyze=True)
    summary = {"plans": 0, "rows": 0, "cacheable": 0, "cost_sized": 0}
    for i in range(num_plans):
        r = random.Random(f"{seed}:{i}")
        cost_sized = r.random() < 0.5
        inputs = inputs_stats if cost_sized else inputs_plain
        st = random_frame(ctx, inputs, r, max_ops=max_ops,
                          cost_sized=cost_sized)
        try:
            res = check_frame(ctx, st)
        except Exception:
            print(f"[plan-fuzz] FAILED at plan {i} "
                  f"(seed={seed}, ops={st.ops})", file=sys.stderr)
            raise
        summary["plans"] += 1
        summary["rows"] += res["rows"]
        summary["cacheable"] += res["cacheable"]
        summary["cost_sized"] += cost_sized
        if log and (i + 1) % 20 == 0:
            log(f"[plan-fuzz] {i + 1}/{num_plans} plans clean "
                f"(last: {'+'.join(st.ops)})")
    summary["verify"] = __import__(
        "repro.core.verify", fromlist=["counter_snapshot"]
    ).counter_snapshot()
    return summary


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plans", type=int, default=200)
    ap.add_argument("--seed", type=int, default=20260807)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--max-ops", type=int, default=6)
    args = ap.parse_args(argv)

    # must happen before jax initializes its backend (so: before any
    # repro.core import) — mirrors testing.dist_cases
    flag = f"--xla_force_host_platform_device_count={args.devices}"
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            flag + " " + os.environ.get("XLA_FLAGS", ""))
    summary = run_fuzz(args.plans, args.seed, max_ops=args.max_ops,
                       log=print)
    print(f"[plan-fuzz] OK: {summary['plans']} plans "
          f"({summary['cost_sized']} cost-sized, "
          f"{summary['cacheable']} cacheable, "
          f"{summary['rows']} result rows, "
          f"verifier {summary['verify']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
