"""Three-term roofline from compiled dry-run artifacts (no hardware).

Terms (per assignment, TPU v5e constants):
    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective = coll_bytes  / (chips * 50e9   B/s per ICI link)

``cost_analysis`` on the compiled module is **per device** and counts a
``while`` (scan) body **once** (verified on this container: a 4-iteration
scan reported 1/4 of analytic FLOPs). The extractor therefore lowers the
step with layers **unrolled at two depths** L1 < L2 under identical
shardings and solves

    cost(L) = c0 + L * c_layer        (exact for layer-homogeneous stacks)

then evaluates at the real depth. Hybrid archs (zamba2/xlstm) solve per
*period* plus a pure-recurrent pair for the remainder layers. Collective
bytes get the same treatment. The full-depth scanned compile is used only
for memory fit (memory_analysis) and the multi-pod proof.

Collective bytes are parsed from the post-SPMD per-device HLO: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
operand is summed (bytes of the per-device operand). Wire multipliers for
the hop-aware variant: all-reduce 2x (ring reduce+broadcast), others 1x.
"""
from __future__ import annotations

import dataclasses
import re

# --- TPU v5e constants (assignment) ----------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9           # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind, from post-SPMD HLO text.

    Note: scan-wrapped collectives are counted once (same while-body rule
    as cost_analysis) — callers use the L1/L2 extrapolation to correct.
    """
    by_kind: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(dtype, dims)
        by_kind[kind] = by_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    total = sum(by_kind.values())
    # ring all-reduce moves ~2x operand bytes on the wire
    wire = sum(v * (2 if k == "all-reduce" else 1) for k, v in by_kind.items())
    return {"by_kind": by_kind, "counts": counts, "bytes": total,
            "wire_bytes": wire}


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\]\S*\s+"
    r"([\w\-]+)\((.*)$")
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id"}


def hbm_bytes(hlo_text: str) -> dict:
    """TPU-style HBM traffic model from post-SPMD HLO.

    ``cost_analysis()['bytes accessed']`` on the CPU backend materializes
    every ``dot f32 -> convert bf16`` pair (XLA:TPU fuses the convert into
    the MXU output) and counts fusion-internal traffic CPU chose not to
    fuse. This walks only TOP-LEVEL ops (entry + while bodies, skipping
    fused_computation internals), sums operand + output bytes per op, and
    collapses dot->convert pairs to the converted output dtype — a faithful
    model of what a TPU-grade pipeline writes to HBM. While bodies count
    once (same rule as cost_analysis; depth-pair extrapolation corrects).
    """
    defs: dict[str, tuple[int, str, bool]] = {}  # name -> (bytes, op, score?)
    blocks = re.split(r"\n(?=(?:ENTRY\s+)?%?[\w.\-]+[^\n]*\{)", hlo_text)
    top_ops = []
    for blk in blocks:
        header = blk.split("\n", 1)[0]
        fused = "fused_computation" in header or "wrapped_" in header \
            or "region_" in header
        for line in blk.splitlines():
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, dtype, dims, op, rest = m.groups()
            b = _shape_bytes(dtype, dims)
            dd = [int(x) for x in dims.split(",") if x]
            # "score-shaped": the (.., S, T) attention-score layout — both
            # minor dims >= 2048. On TPU this traffic never reaches HBM
            # (the Pallas flash kernel, kernels/flash_attention.py); the
            # flash-adjusted memory term drops it.
            is_score = len(dd) >= 2 and dd[-1] >= 2048 and dd[-2] >= 2048
            defs[name] = (b, op, is_score)
            if not fused:
                operands = re.findall(r"%([\w.\-]+)", rest.split(
                    ", metadata=")[0].split(", calls=")[0])
                top_ops.append((name, b, op, operands, is_score))
    consumers: dict[str, list[str]] = {}
    for name, b, op, operands, is_score in top_ops:
        for o in operands:
            consumers.setdefault(o, []).append(op)

    total = 0
    score_bytes = 0
    for name, b, op, operands, is_score in top_ops:
        if op in _NO_TRAFFIC or op in ("while", "conditional", "call",
                                       "reshape", "broadcast", "transpose"):
            # transpose/reshape/broadcast fuse into consumers on TPU;
            # while/cond carry aliased state (their bodies are counted)
            continue
        if op == "convert":
            # dot/fusion output converts fuse into the producer on TPU
            src = operands[0] if operands else None
            if src and defs.get(src, (0, "", False))[1] in (
                    "dot", "fusion", "convolution"):
                continue
        if op == "dynamic-update-slice":
            # in-place on TPU (buffer aliasing): traffic = the slice r+w
            upd = defs.get(operands[1], (0, "", False))[0] \
                if len(operands) > 1 else b
            total += 2 * upd
            continue
        if op in ("dynamic-slice", "slice", "gather", "pad"):
            total += 2 * b
            if is_score:
                score_bytes += 2 * b
            continue
        if op == "scatter":
            upd = defs.get(operands[-1], (0, "", False))[0] if operands else b
            total += 2 * upd
            continue
        out_b = b
        if defs.get(name, (0, "", False))[1] == "dot":
            # if the sole consumer is a convert, emit at converted width
            cons = consumers.get(name, [])
            if cons and all(c == "convert" for c in cons):
                out_b = b // 2
        sb = out_b if is_score else 0
        rd = 0
        for o in operands:
            ob, _, osc = defs.get(o, (0, "", False))
            rd += ob
            if osc:
                sb += ob
        total += out_b + rd
        score_bytes += sb
    return {"bytes": total, "score_bytes": score_bytes,
            "flash_adjusted": total - score_bytes}


def cpu_upcast_temp_bytes(hlo_text: str) -> dict:
    """Bytes of top-level f32 buffers that are pure upcasts of bf16 tensors.

    XLA:CPU's dot lowering converts bf16 operands to f32 *materialized*
    copies (the TPU MXU consumes bf16 directly); for decode steps these
    copies of the KV cache dominate temp memory. Returns their total and
    the largest single one — a TPU-adjusted peak keeps one copy as the
    transient bound: peak_adj = peak - total + largest.
    """
    defs: dict[str, tuple[int, str]] = {}
    total = largest = 0
    blocks = re.split(r"\n(?=(?:ENTRY\s+)?%?[\w.\-]+[^\n]*\{)", hlo_text)
    for blk in blocks:
        header = blk.split("\n", 1)[0]
        fused = "fused_computation" in header or "wrapped_" in header
        for line in blk.splitlines():
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, dtype, dims, op, rest = m.groups()
            b = _shape_bytes(dtype, dims)
            defs[name] = (b, dtype)
            if fused or dtype != "f32":
                continue
            if op not in ("convert", "fusion"):
                continue
            operands = re.findall(r"%([\w.\-]+)", rest.split(
                ", metadata=")[0].split(", calls=")[0])
            if len(operands) == 1:
                ob, odt = defs.get(operands[0], (0, ""))
                if odt == "bf16" and ob * 2 == b:
                    total += b
                    largest = max(largest, b)
    return {"total": total, "largest": largest}


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis()
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        # donated buffers alias their outputs — don't count them twice
        "peak_bytes": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                          + max(0, ma.output_size_in_bytes
                                - ma.alias_size_in_bytes)),
    }


# ---------------------------------------------------------------------------
# depth extrapolation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DepthPair:
    """Costs measured at two unrolled depths; solves cost(L) = c0 + L*c1."""
    l1: int
    l2: int
    cost1: dict
    cost2: dict

    def at(self, depth: float) -> dict:
        out = {}
        keys = set(self.cost1) | set(self.cost2)
        for k in keys:
            a, b = float(self.cost1.get(k, 0)), float(self.cost2.get(k, 0))
            c_layer = (b - a) / (self.l2 - self.l1)
            c0 = a - self.l1 * c_layer
            # constant-folding noise can push tiny c0 negative — clamp
            out[k] = max(c0 + depth * c_layer, 0.0)
        return out

    def per_layer(self) -> dict:
        keys = set(self.cost1) | set(self.cost2)
        return {k: (float(self.cost2.get(k, 0)) - float(self.cost1.get(k, 0)))
                / (self.l2 - self.l1) for k in keys}


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, *, links_used: int = 1) -> dict:
    """Seconds per term, per the assignment formulas (per-device numbers)."""
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = coll_bytes_per_dev / (ICI_LINK_BW * links_used)
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dom[0],
            "bound_s": dom[1]}


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (the useful-compute yardstick)
# ---------------------------------------------------------------------------


def count_params(shapes_tree) -> dict:
    """{'total': n, 'embed': n_embed} from an eval_shape param tree."""
    import jax

    flat = jax.tree_util.tree_flatten_with_path(shapes_tree)[0]
    total = emb = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        name = "/".join(str(getattr(p, "key", "")) for p in path)
        if "embed" in name or "lm_head" in name or "dec_pos" in name:
            emb += n
    return {"total": total, "embed": emb}


def active_params(cfg, params_count: dict) -> float:
    """N_active: non-embedding params, MoE experts scaled by top-k/E."""
    n_body = params_count["total"] - params_count["embed"]
    # lm_head participates in every token's matmul — count it
    n = n_body + (0 if cfg.tie_embeddings else 0)
    if cfg.moe_num_experts:
        import math
        e = cfg.moe_num_experts
        expert_p = cfg.num_layers * 3 * cfg.d_model * cfg.moe_d_ff * e
        n = n - expert_p + expert_p * cfg.moe_top_k / e
    # unembed matmul is real compute: add the head once
    n = n + cfg.vocab_size * cfg.d_model
    return float(n)


def model_flops(cfg, params_count: dict, kind: str, global_batch: int,
                seq_len: int) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), N = active params."""
    n = active_params(cfg, params_count)
    if kind == "train":
        return 6.0 * n * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n * global_batch * seq_len
    return 2.0 * n * global_batch  # decode: one token
