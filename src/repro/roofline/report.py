"""Render results/dryrun.json into the EXPERIMENTS.md §Dry-run / §Roofline
tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys

from repro.roofline.analysis import HBM_PER_CHIP

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def dryrun_table(data: dict) -> str:
    rows = ["| arch | shape | mesh | fits | GiB/dev (TPU-adj) | %HBM | "
            "colls/step (once-counted) | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for arch, shapes in data.items():
        for shape in SHAPE_ORDER:
            rec = shapes.get(shape)
            if rec is None:
                continue
            if "skipped" in rec:
                rows.append(f"| {arch} | {shape} | — | SKIP | — | — | "
                            f"{rec['skipped'].split('(')[0].strip()} | — |")
                continue
            for mesh in ("single", "multi"):
                r = rec.get(mesh)
                if r is None:
                    continue
                if not r.get("ok"):
                    rows.append(f"| {arch} | {shape} | {mesh} | FAIL | — | — "
                                f"| {r.get('error','')[:60]} | — |")
                    continue
                mem = r["memory"]
                peak = mem.get("peak_adjusted", mem["peak_bytes"])
                cc = r["collectives_once"]["counts"]
                cstr = " ".join(f"{k.split('-')[-1] if '-' in k else k}:"
                                f"{v}" for k, v in sorted(cc.items()))
                rows.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{'Y' if peak <= HBM_PER_CHIP else 'OVER'}"
                    f" | {fmt_bytes(peak)} | "
                    f"{100*peak/HBM_PER_CHIP:.0f}% | {cstr} | "
                    f"{r['compile_s']:.0f} |")
    return "\n".join(rows)


def roofline_table(data: dict) -> str:
    rows = ["| arch | shape | compute ms | memory ms (xla / flash-adj) | "
            "collective ms | dominant | MODEL_FLOPS/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for arch, shapes in data.items():
        for shape in SHAPE_ORDER:
            rec = shapes.get(shape, {})
            r = rec.get("roofline")
            if not r or "terms" not in r:
                continue
            t, tf = r["terms"], r["terms_flash"]
            # roofline fraction: useful-compute time / achievable bound
            frac = (r["model_flops"] / r["chips"] / 197e12) / tf["bound_s"]
            rows.append(
                f"| {arch} | {shape} | {fmt_ms(t['compute_s'])} | "
                f"{fmt_ms(t['memory_s'])} / {fmt_ms(tf['memory_s'])} | "
                f"{fmt_ms(t['collective_s'])} | {tf['dominant']} | "
                f"{100*r['useful_ratio']:.0f}% | {100*frac:.0f}% |")
    return "\n".join(rows)


def bottleneck_notes(data: dict) -> str:
    notes = []
    for arch, shapes in data.items():
        for shape in SHAPE_ORDER:
            r = shapes.get(shape, {}).get("roofline")
            if not r or "terms_flash" not in r:
                continue
            dom = r["terms_flash"]["dominant"]
            hint = {
                "collective": "reduce TP degree / shard params instead of "
                              "activations (FSDP), overlap collectives",
                "memory": "fuse attention (Pallas flash), cut fp32 "
                          "materializations, seq-shard activations",
                "compute": "at the MXU bound — only algorithmic wins left "
                           "(MoE sparsity, shorter seq, fewer layers)",
            }[dom]
            notes.append(f"- **{arch} × {shape}** — {dom}-bound: {hint}.")
    return "\n".join(notes)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    with open(path) as f:
        data = json.load(f)
    print("### Dry-run matrix\n")
    print(dryrun_table(data))
    print("\n### Roofline (single-pod, per step)\n")
    print(roofline_table(data))
    print("\n### Dominant-term notes\n")
    print(bottleneck_notes(data))


if __name__ == "__main__":
    main()
