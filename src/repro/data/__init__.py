from repro.data.synthetic import (  # noqa: F401
    lm_labels_table,
    lm_samples_table,
    random_table,
    zipf_table,
)
from repro.data.pipeline import RelationalTokenPipeline, Prefetcher  # noqa: F401
