"""Relational ETL -> token batches: the paper's Fig. 5/6 integration story.

The paper's claim is that data engineering should be a *library function*
inside the training program. Here the pre-processing pipeline for LM
training is literally the relational operator chain

    samples = lm_samples_table(...)              # 'CSV read'
    good    = select(samples, quality > θ)       # Select   (paper §II-B-1)
    joined  = join(good, labels, on=sample_id)   # Join     (paper §II-B-3)
    batch   = project(head(joined, B), tokens)   # Project  (paper §II-B-2)

executed as one jitted XLA program whose output columns ARE the train-step
inputs (zero-copy hand-off, the Arrow story). The pipeline is a pure
function of ``(seed, step)`` — restart/replay determinism for fault
tolerance — and the :class:`Prefetcher` overlaps batch assembly with the
step (bounded-staleness straggler mitigation, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops_agg as A
from repro.core import ops_local as L
from repro.core.table import Table, concat_tables
from repro.data import synthetic


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    quality_threshold: float = 0.2
    oversample: float = 1.6     # raw rows generated per emitted row
    max_refills: int = 8        # deterministic refill rounds before padding
    collect_stats: bool = False  # per-source quality stats (groupby stage)
    num_sources: int = 16        # source-bucket cardinality bound (stats)
    seed: int = 0


class RelationalTokenPipeline:
    """Deterministic relational ETL producing fixed-shape token batches."""

    def __init__(self, config: PipelineConfig):
        self.config = config
        c = config
        self._raw_rows = max(4, int(np.ceil(c.global_batch * c.oversample)))
        self._etl = jax.jit(partial(
            _etl_step, threshold=c.quality_threshold, batch=c.global_batch))
        # quality-bucket stats ride the two-phase aggregation machinery:
        # one partial per refill round, combined once per batch. Bounding
        # partials by the source cardinality keeps each one tiny (and the
        # segment count inside the Pallas kernel budget) no matter how
        # large the raw sample rounds are.
        self._stats_partial = jax.jit(partial(
            A.partial_groupby, keys="source", aggs=SOURCE_STAT_AGGS,
            out_capacity=c.num_sources))
        self.last_stats: dict[str, np.ndarray] | None = None

    # -- shapes (dry-run / sharding contract) --------------------------------
    def batch_specs(self) -> dict[str, jax.ShapeDtypeStruct]:
        c = self.config
        return {
            "tokens": jax.ShapeDtypeStruct((c.global_batch, c.seq_len), jnp.int32),
            "weight": jax.ShapeDtypeStruct((c.global_batch,), jnp.float32),
        }

    # -- batch assembly -------------------------------------------------------
    def _round(self, step: int, refill: int):
        c = self.config
        samples = synthetic.lm_samples_table(
            self._raw_rows, c.seq_len, c.vocab_size,
            seed=c.seed, step=step, shard=refill)
        labels = synthetic.lm_labels_table(
            np.asarray(samples.columns["sample_id"]),
            seed=c.seed, step=step, shard=refill)
        return samples, labels

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        """Assemble batch `step`. Pure in (seed, step); refills deterministic."""
        c = self.config
        need = c.global_batch
        toks = np.zeros((need, c.seq_len), np.int32)
        wts = np.zeros((need,), np.float32)
        got = 0
        stat_partials = []
        for refill in range(c.max_refills):
            samples, labels = self._round(step, refill)
            if c.collect_stats:
                stat_partials.append(self._stats_partial(
                    L.project(samples, ["source", "quality"])))
            tokens, weight, n = self._etl(samples, labels)
            n = int(n)
            take = min(n, need - got)
            toks[got : got + take] = np.asarray(tokens[:take])
            wts[got : got + take] = np.asarray(weight[:take])
            got += take
            if got >= need:
                break
        if c.collect_stats:
            cat = stat_partials[0]
            for part in stat_partials[1:]:
                cat = concat_tables(cat, part)
            self.last_stats = A.combine_groupby(
                cat, "source", SOURCE_STAT_AGGS,
                out_capacity=c.num_sources).to_numpy()
        if got < need:  # pathological filter rate: wrap-pad deterministically
            reps = -(-need // max(got, 1))
            toks[got:] = np.tile(toks[:got], (reps, 1))[: need - got]
            wts[got:] = np.tile(wts[:got], reps)[: need - got]
        return {"tokens": toks, "weight": wts}

    def __iter__(self):
        step = 0
        while True:
            yield self.global_batch(step)
            step += 1


def _etl_step(samples: Table, labels: Table, *, threshold: float, batch: int):
    """The jitted relational chain (select -> join -> project -> head)."""
    good = L.select(samples, lambda cols: cols["quality"] > threshold)
    joined = L.join(good, labels, on="sample_id", how="inner", algorithm="hash",
                    out_capacity=good.capacity)
    out = L.head(L.project(joined, ["tokens", "weight"]), batch)
    return out.columns["tokens"], out.columns["weight"], out.row_count


SOURCE_STAT_AGGS = (("quality", "count"), ("quality", "mean"),
                    ("quality", "var"), ("quality", "min"),
                    ("quality", "max"))


def source_quality_stats(samples: Table) -> Table:
    """Quality-bucket statistics: GroupBy source -> count/mean/var/min/max
    of the quality score — the data-quality dashboard stage (and the local
    half of the distributed two-phase aggregation in examples/etl)."""
    return A.groupby(samples, "source", SOURCE_STAT_AGGS)


class Prefetcher:
    """Background-thread prefetch with bounded depth (host-side overlap).

    Decouples batch assembly from the device step: a slow ETL round (the
    'straggler') is absorbed by the queue instead of stalling the BSP step.
    """

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
