"""Relational ETL -> token batches: the paper's Fig. 5/6 integration story.

The paper's claim is that data engineering should be a *library function*
inside the training program. Here the pre-processing pipeline for LM
training is literally the relational operator chain

    samples = lm_samples_table(...)              # 'CSV read'
    frame(samples).select(quality > θ)           # Select   (paper §II-B-1)
        .join(labels, on=sample_id)              # Join     (paper §II-B-3)
        .project(tokens, weight).limit(B)        # Project  (paper §II-B-2)
        .collect()

built as a **LazyFrame** plan and compiled into ONE fused shard_map/XLA
program per batch (repro.core.frame): the optimizer pushes the quality
filter and the tokens/weight projection below the join, and on a
single-shard mesh elides every shuffle — one dispatch, no intermediate
materialization, output columns ARE the train-step inputs (zero-copy
hand-off, the Arrow story). The pipeline is a pure function of
``(seed, step)`` — restart/replay determinism for fault tolerance — and
the :class:`Prefetcher` overlaps batch assembly with the step
(bounded-staleness straggler mitigation, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops_agg as A
from repro.core import ops_local as L
from repro.core.context import DistContext
from repro.core.table import Table, concat_tables
from repro.data import synthetic


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    quality_threshold: float = 0.2
    oversample: float = 1.6     # raw rows generated per emitted row
    max_refills: int = 8        # deterministic refill rounds before padding
    collect_stats: bool = False  # per-source quality stats (groupby stage)
    num_sources: int = 16        # source-bucket cardinality bound (stats)
    seed: int = 0


class RelationalTokenPipeline:
    """Deterministic relational ETL producing fixed-shape token batches."""

    def __init__(self, config: PipelineConfig,
                 ctx: DistContext | None = None):
        self.config = config
        c = config
        self._raw_rows = max(4, int(np.ceil(c.global_batch * c.oversample)))
        # the ETL chain runs on a DistContext (1-D mesh over all local
        # devices; single device in unit tests). The LazyFrame plan is
        # identical every refill, so the fused program jit-caches on its
        # canonical plan + shapes.
        self._ctx = ctx or DistContext(axis_name="etl")
        # quality-bucket stats ride the two-phase aggregation machinery:
        # one partial per refill round, combined once per batch. Bounding
        # partials by the source cardinality keeps each one tiny (and the
        # segment count inside the Pallas kernel budget) no matter how
        # large the raw sample rounds are.
        self._stats_partial = jax.jit(partial(
            A.partial_groupby, keys="source", aggs=SOURCE_STAT_AGGS,
            out_capacity=c.num_sources))
        self.last_stats: dict[str, np.ndarray] | None = None

    # -- shapes (dry-run / sharding contract) --------------------------------
    def batch_specs(self) -> dict[str, jax.ShapeDtypeStruct]:
        c = self.config
        return {
            "tokens": jax.ShapeDtypeStruct((c.global_batch, c.seq_len), jnp.int32),
            "weight": jax.ShapeDtypeStruct((c.global_batch,), jnp.float32),
        }

    # -- batch assembly -------------------------------------------------------
    def _round(self, step: int, refill: int):
        c = self.config
        samples = synthetic.lm_samples_table(
            self._raw_rows, c.seq_len, c.vocab_size,
            seed=c.seed, step=step, shard=refill)
        labels = synthetic.lm_labels_table(
            np.asarray(samples.columns["sample_id"]),
            seed=c.seed, step=step, shard=refill)
        return samples, labels

    def _etl_frame(self, samples: Table, labels: Table):
        """The fused relational chain (select -> join -> project -> limit),
        one shard_map program via LazyFrame.collect(). The trailing
        ``limit`` is a true GLOBAL head-n, so a round yields at most
        exactly ``global_batch`` rows across all shards (not per shard).

        Capacities are skew-proof: the join's shuffle bucket holds a whole
        shard's rows (a one-source->one-destination pileup cannot overflow)
        and out_capacity covers every sample globally (sample_id is unique
        per side, so matches <= total rows even if one shard receives them
        all) — batch content never silently truncates, whatever the local
        device count.
        """
        c = self.config
        ds = self._ctx.scatter(samples)
        dl = self._ctx.scatter(labels)
        thr = c.quality_threshold
        return (self._ctx.frame(ds)
                .select(lambda cols: cols["quality"] > thr,
                        key=("quality_gt", thr))
                .join(self._ctx.frame(dl), "sample_id", how="inner",
                      algorithm="hash",
                      bucket_capacity=ds.local_capacity,
                      out_capacity=self._ctx.num_shards * ds.local_capacity)
                .project(["tokens", "weight"])
                .limit(c.global_batch))

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        """Assemble batch `step`. Pure in (seed, step); refills deterministic."""
        c = self.config
        need = c.global_batch
        toks = np.zeros((need, c.seq_len), np.int32)
        wts = np.zeros((need,), np.float32)
        got = 0
        stat_partials = []
        for refill in range(c.max_refills):
            samples, labels = self._round(step, refill)
            if c.collect_stats:
                stat_partials.append(self._stats_partial(
                    L.project(samples, ["source", "quality"])))
            batch = self._etl_frame(samples, labels).collect() \
                .to_table().to_numpy()
            take = min(len(batch["weight"]), need - got)
            toks[got : got + take] = batch["tokens"][:take]
            wts[got : got + take] = batch["weight"][:take]
            got += take
            if got >= need:
                break
        if c.collect_stats:
            cat = stat_partials[0]
            for part in stat_partials[1:]:
                cat = concat_tables(cat, part)
            self.last_stats = A.combine_groupby(
                cat, "source", SOURCE_STAT_AGGS,
                out_capacity=c.num_sources).to_numpy()
        if got < need:  # pathological filter rate: wrap-pad deterministically
            reps = -(-need // max(got, 1))
            toks[got:] = np.tile(toks[:got], (reps, 1))[: need - got]
            wts[got:] = np.tile(wts[:got], reps)[: need - got]
        return {"tokens": toks, "weight": wts}

    def __iter__(self):
        step = 0
        while True:
            yield self.global_batch(step)
            step += 1


SOURCE_STAT_AGGS = (("quality", "count"), ("quality", "mean"),
                    ("quality", "var"), ("quality", "min"),
                    ("quality", "max"))


def source_quality_stats(samples: Table) -> Table:
    """Quality-bucket statistics: GroupBy source -> count/mean/var/min/max
    of the quality score — the data-quality dashboard stage (and the local
    half of the distributed two-phase aggregation in examples/etl)."""
    return A.groupby(samples, "source", SOURCE_STAT_AGGS)


class Prefetcher:
    """Background-thread prefetch with bounded depth (host-side overlap).

    Decouples batch assembly from the device step: a slow ETL round (the
    'straggler') is absorbed by the queue instead of stalling the BSP step.
    """

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        # a crash in the source iterator must surface in the CONSUMER,
        # not vanish into the worker thread as a silent early end-of-data
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:
            self._error = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._error is not None:
                raise self._error
            raise StopIteration
        return item
