"""Synthetic table generators — the paper's CSV workload, deterministic.

The paper's experiments generate CSV files of ``1 int64 index + 3 doubles``
per row. The TPU adaptation uses ``int32`` keys (the hash kernels are 32-bit;
DESIGN.md hardware-adaptation table) and ``float32`` payloads. Every
generator is a pure function of ``(seed, step, shard)`` so a restarted job
regenerates byte-identical data — the determinism contract the fault-
tolerance layer relies on (DESIGN.md §6).
"""
from __future__ import annotations

import numpy as np

from repro.core.table import Table


def _rng(seed: int, step: int = 0, shard: int = 0) -> np.random.Generator:
    # SeedSequence spawning gives independent streams per (seed, step, shard).
    return np.random.default_rng(np.random.SeedSequence([seed, step, shard]))


def random_table(rows: int, *, num_payload: int = 3, key_range: int | None = None,
                 seed: int = 0, step: int = 0, shard: int = 0,
                 key_name: str = "k") -> Table:
    """The paper's benchmark relation: one int key + `num_payload` floats."""
    rng = _rng(seed, step, shard)
    key_range = key_range or max(1, rows)
    cols = {key_name: rng.integers(0, key_range, rows).astype(np.int32)}
    for i in range(num_payload):
        cols[f"d{i}"] = rng.standard_normal(rows).astype(np.float32)
    return Table.from_arrays(cols)


def zipf_table(rows: int, *, a: float = 1.5, num_payload: int = 3,
               key_range: int | None = None, seed: int = 0, step: int = 0,
               shard: int = 0, key_name: str = "k") -> Table:
    """Skewed keys (Zipf) — stresses shuffle bucket overflow handling."""
    rng = _rng(seed, step, shard)
    key_range = key_range or max(1, rows)
    k = (rng.zipf(a, rows) - 1) % key_range
    cols = {key_name: k.astype(np.int32)}
    for i in range(num_payload):
        cols[f"d{i}"] = rng.standard_normal(rows).astype(np.float32)
    return Table.from_arrays(cols)


def lm_samples_table(rows: int, seq_len: int, vocab_size: int, *, seed: int = 0,
                     step: int = 0, shard: int = 0) -> Table:
    """LM pre-training 'documents': tokens as a 2-D column + metadata.

    Columns: sample_id (int32), tokens (rows, seq_len) int32,
    quality (f32 in [0,1]) — the filter column, source (int32 bucket).
    """
    rng = _rng(seed, step, shard)
    base = (step * 1_000_003 + shard * 7_001) % (2**31 - rows)
    return Table.from_arrays({
        "sample_id": (base + np.arange(rows)).astype(np.int32),
        "tokens": rng.integers(1, vocab_size, (rows, seq_len)).astype(np.int32),
        "quality": rng.random(rows).astype(np.float32),
        "source": rng.integers(0, 8, rows).astype(np.int32),
    })


def lm_labels_table(sample_ids: np.ndarray, *, seed: int = 0, step: int = 0,
                    shard: int = 0, drop_fraction: float = 0.1) -> Table:
    """Per-sample weights keyed by sample_id; a fraction is missing, so the
    inner join in the pipeline also acts as a filter (the paper's ETL join).
    """
    rng = _rng(seed ^ 0x5EED, step, shard)
    keep = rng.random(len(sample_ids)) >= drop_fraction
    ids = np.asarray(sample_ids)[keep]
    return Table.from_arrays({
        "sample_id": ids.astype(np.int32),
        "weight": (0.5 + rng.random(len(ids)).astype(np.float32)[: len(ids)]),
    })
