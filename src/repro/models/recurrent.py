"""Chunked gated linear attention — the shared engine for Mamba2 SSD and
xLSTM's mLSTM — plus the sLSTM associative scan.

Both Mamba2 (state-space duality form) and mLSTM compute

    y_t = q_t · h_t,   h_t = a_t * h_{t-1} + k_t v_tᵀ        (per head)

with a scalar per-step decay ``a_t = exp(log_a_t)``. The TPU-native
evaluation is **chunkwise**: within a chunk of Q steps the contribution is a
dense Q×Q masked matmul (MXU work, like attention); across chunks a
recurrence carries the (K, V) state matrix. Sequential work is S/Q steps
instead of S — the sub-quadratic path that makes the ``long_500k`` cells
runnable (O(S·Q) + O(S/Q) instead of O(S²)).

``time_unroll=True`` unrolls the chunk loop in Python — used by the
roofline extractor so ``cost_analysis`` sees every chunk (XLA counts a
``while`` body once; see DESIGN.md §5).

Numerics note (DESIGN.md hardware-adaptation): xLSTM's exponential gating
with running-max stabilizer is replaced by sigmoid input/forget gates with
a carried normalizer — chunk-stable without per-row running-max state, FLOP
and memory structure identical. The normalizer rides the GLA as an extra
value column (v augmented with ones), so numerator and denominator come out
of one scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_gla(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
                *, chunk: int, initial_state: jax.Array | None = None,
                unroll: bool = False):
    """Gated linear attention, chunkwise-parallel.

    q, k: (B, S, H, K);  v: (B, S, H, V);  log_a: (B, S, H) with log_a <= 0.
    Returns (y (B, S, H, V), final_state (B, H, K, V) fp32).
    S must be a multiple of `chunk`.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if s % chunk:  # pad to a chunk multiple: k=0 rows are absorbing
        pad = chunk - s % chunk
        padt = lambda x: jnp.pad(x, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (x.ndim - 2))
        y, h_final = chunked_gla(padt(q), padt(k), padt(v), padt(log_a),
                                 chunk=chunk, initial_state=initial_state,
                                 unroll=unroll)
        return y[:, :s], h_final
    nc, cq = s // chunk, chunk
    dt = q.dtype

    qc = q.reshape(b, nc, cq, h, dk)
    kc = k.reshape(b, nc, cq, h, dk)
    vc = v.reshape(b, nc, cq, h, dv)
    la = log_a.reshape(b, nc, cq, h).astype(jnp.float32)
    cum = jnp.cumsum(la, axis=2)                      # inclusive ∑_{r<=t}
    total = cum[:, :, -1, :]                          # (B, NC, H)

    # --- intra-chunk: masked decay-weighted scores (the MXU part) ----------
    # w[t,s] = (q_t·k_s) * exp(cum_t - cum_s) for s <= t
    scores = jnp.einsum("bnqhk,bnshk->bnhqs", qc, kc).astype(jnp.float32)
    ct = cum.transpose(0, 1, 3, 2)                    # (B, NC, H, Q)
    decay = jnp.exp(ct[..., :, None] - ct[..., None, :])  # [q,s] = cum_q-cum_s
    mask = jnp.tril(jnp.ones((cq, cq), bool))
    w = jnp.where(mask[None, None, None], scores * decay, 0.0)
    y_intra = jnp.einsum("bnhqs,bnshv->bnqhv", w.astype(dt), vc)

    # --- per-chunk state contribution & inter-chunk recurrence -------------
    # S_n = Σ_s exp(total_n - cum_s) k_s v_sᵀ
    kd = kc.astype(jnp.float32) * jnp.exp(total[:, :, None] - cum)[..., None]
    s_chunk = jnp.einsum("bnshk,bnshv->bnhkv", kd, vc.astype(jnp.float32))

    def step(h_prev, xs):
        s_n, tot_n, q_n, cum_n = xs
        # inter contribution for this chunk, from the carried state
        qd = q_n.astype(jnp.float32) * jnp.exp(cum_n)[..., None]
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", qd, h_prev)
        h_new = jnp.exp(tot_n)[..., None, None] * h_prev + s_n
        return h_new, y_inter

    h0 = initial_state if initial_state is not None else \
        jnp.zeros((b, h, dk, dv), jnp.float32)
    xs = (
        s_chunk.transpose(1, 0, 2, 3, 4),       # (NC, B, H, K, V)
        total.transpose(1, 0, 2),               # (NC, B, H)
        qc.transpose(1, 0, 2, 3, 4),            # (NC, B, Q, H, K)
        cum.transpose(1, 0, 2, 3),              # (NC, B, Q, H)
    )
    if unroll:
        hs, ys = h0, []
        for n in range(nc):
            hs, y_n = step(hs, jax.tree.map(lambda x: x[n], xs))
            ys.append(y_n)
        h_final = hs
        y_inter = jnp.stack(ys, 0)
    else:
        h_final, y_inter = jax.lax.scan(step, h0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    y = y_intra.reshape(b, s, h, dv) + y_inter.astype(dt)
    return y, h_final


def gla_decode_step(q, k, v, log_a, state):
    """One recurrent step. q/k (B,H,K), v (B,H,V), log_a (B,H),
    state (B,H,K,V) fp32. Returns (y (B,H,V), new_state)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    new_state = a * state + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), new_state)
    return y.astype(q.dtype), new_state


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory recurrence via associative scan
# ---------------------------------------------------------------------------


def slstm_scan(i: jax.Array, f: jax.Array, z: jax.Array, o: jax.Array,
               c0: jax.Array | None = None, n0: jax.Array | None = None):
    """Stabilized scalar LSTM recurrence, parallel over time.

        c_t = f_t c_{t-1} + i_t z_t
        n_t = f_t n_{t-1} + i_t
        h_t = o_t * c_t / max(n_t, 1)

    i, f in (0,1) (sigmoid gates — see module docstring), z, o: (B, S, D).
    The linear recurrences run as one associative scan over a stacked
    (c, n) pair. Returns (h (B,S,D), (c_S, n_S) final state (B,D)).
    """
    b, s, d = i.shape
    ii = i.astype(jnp.float32)
    ff = f.astype(jnp.float32)
    zz = z.astype(jnp.float32)
    # elements (a_t, u_t) composing as (a2*a1, a2*u1 + u2); stack c and n
    # along a new leading axis so one scan solves both.
    a = jnp.stack([ff, ff], 0)                       # (2, B, S, D)
    u = jnp.stack([ii * zz, ii], 0)

    def combine(lhs, rhs):
        a1, u1 = lhs
        a2, u2 = rhs
        return a1 * a2, a2 * u1 + u2

    if c0 is not None:
        # fold the initial state into the first step's input term
        u = u.at[:, :, 0, :].add(a[:, :, 0, :] * jnp.stack([c0, n0], 0))
    av, uv = jax.lax.associative_scan(combine, (a, u), axis=2)
    c, n = uv[0], uv[1]
    h = o.astype(jnp.float32) * c / jnp.maximum(n, 1.0)
    return h.astype(i.dtype), (c[:, -1], n[:, -1])


def slstm_decode_step(i, f, z, o, state):
    """One sLSTM step. gates (B, D); state (c, n) each (B, D) fp32."""
    c, n = state
    ii, ff = i.astype(jnp.float32), f.astype(jnp.float32)
    c = ff * c + ii * z.astype(jnp.float32)
    n = ff * n + ii
    h = o.astype(jnp.float32) * c / jnp.maximum(n, 1.0)
    return h.astype(i.dtype), (c, n)


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba2 / mLSTM short conv)
# ---------------------------------------------------------------------------


def causal_depthwise_conv(x: jax.Array, w: jax.Array,
                          cache: jax.Array | None = None):
    """x (B, S, C), w (K, C) depthwise causal conv.

    cache (B, K-1, C) holds the trailing context from the previous call
    (decode); returns (y (B, S, C), new_cache (B, K-1, C)).
    """
    b, s, c = x.shape
    kk = w.shape[0]
    if cache is None:
        xp = jnp.concatenate([jnp.zeros((b, kk - 1, c), x.dtype), x], axis=1)
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x)
    for j in range(kk):  # K is 4: unrolled adds, no gather
        y = y + xp[:, j : j + s, :] * w[j][None, None, :].astype(x.dtype)
    return y, xp[:, -(kk - 1):, :]
