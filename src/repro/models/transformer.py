"""Unified decoder-only transformer: dense GQA, MLA, MoE, VLM backbone.

One scan-over-layers program covers 7 of the 10 assigned architectures
(llama3-8b, granite-3-2b, stablelm-12b, internvl2-76b backbone,
minicpm3-4b via MLA, qwen2-moe-a2.7b and dbrx-132b via MoE). Layer params
are stacked on a leading L dim and scanned (compile-time O(1) in depth);
``cfg.scan_layers=False`` unrolls instead (the roofline extractor lowers
unrolled L∈{1,2} to undo XLA's count-while-body-once accounting,
DESIGN.md §5).

The VLM frontend is a stub per the assignment: ``embeds`` (precomputed
patch embeddings, (B, n_front, d)) are projected and prepended to the token
embeddings; loss is masked to text positions.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as NN
from repro.models import moe as MOE
from repro.models.common import (
    MODEL_AXIS, ModelConfig, ShardingRules, stack_layer_specs)

AUX_ZERO = {"moe_aux": jnp.float32(0), "moe_dropped": jnp.float32(0)}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, rules: ShardingRules):
    ks = jax.random.split(key, 4)
    if cfg.attn_kind == "mla":
        attn_p, attn_s = NN.init_mla(ks[0], cfg, rules)
    else:
        attn_p, attn_s = NN.init_attention(ks[0], cfg, rules)
    p = {"ln1": NN.init_norm(cfg.d_model, cfg.param_dtype), "attn": attn_p,
         "ln2": NN.init_norm(cfg.d_model, cfg.param_dtype)}
    s = {"ln1": rules.vec(), "attn": attn_s, "ln2": rules.vec()}
    if cfg.moe_num_experts:
        p["moe"], s["moe"] = MOE.init_moe(ks[1], cfg, rules)
    else:
        p["mlp"], s["mlp"] = NN.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg,
                                         rules)
    return p, s


def init_lm(key, cfg: ModelConfig, rules: ShardingRules):
    ks = jax.random.split(key, 5)
    embed_p, embed_s = NN.init_embed(ks[0], cfg, rules)
    layer_keys = jax.random.split(ks[1], cfg.num_layers)
    lp, ls = jax.vmap(lambda k: init_block(k, cfg, rules)[0])(layer_keys), None
    _, ls = init_block(ks[1], cfg, rules)  # specs from a single block
    params = {
        "embed": embed_p,
        "layers": lp,
        "final_norm": NN.init_norm(cfg.d_model, cfg.param_dtype),
    }
    specs = {
        "embed": embed_s,
        "layers": stack_layer_specs(ls, cfg.num_layers),
        "final_norm": rules.vec(),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = NN._dense(ks[2], (cfg.padded_vocab, cfg.d_model),
                                      cfg.param_dtype)
        specs["lm_head"] = rules.embed(cfg.padded_vocab, cfg.d_model)
    if cfg.frontend == "vision_stub":
        params["front_proj"] = NN._dense(ks[3], (cfg.d_model, cfg.d_model),
                                         cfg.param_dtype)
        specs["front_proj"] = rules.col(cfg.d_model, cfg.d_model)
    return params, specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_fwd(p, x, cfg: ModelConfig, rules, mesh, *, rope, mode, cache,
               pos):
    h = NN.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, new_cache = NN.mla_fwd(p["attn"], h, cfg, mode=mode, rope=rope,
                                  cache=cache, pos=pos, mesh=mesh)
    else:
        a, new_cache = NN.attention_fwd(p["attn"], h, cfg, mode=mode,
                                        rope=rope, cache=cache, pos=pos,
                                        mesh=mesh)
    x = x + a
    h = NN.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe_num_experts:
        m, aux = MOE.moe_fwd(p["moe"], h, cfg, rules, mesh)
    else:
        m, aux = NN.mlp_fwd(p["mlp"], h), dict(AUX_ZERO)
    return x + m, new_cache, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'full'


def _run_layers_unrolled(layer_params, x, cfg, rules, mesh, *, rope, mode,
                         cache, pos):
    aux_tot = dict(AUX_ZERO)
    ncaches = []
    for i in range(cfg.num_layers):
        pl = jax.tree.map(lambda v: v[i], layer_params)
        cl = jax.tree.map(lambda v: v[i], cache) if cache is not None else None
        fn = _remat(partial(_block_fwd, cfg=cfg, rules=rules, mesh=mesh,
                            rope=rope, mode=mode, pos=pos), cfg)
        x, ncl, aux = fn(pl, x, cache=cl)
        aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
        if cache is not None:
            ncaches.append(ncl)
    ncache = None
    if cache is not None:
        ncache = jax.tree.map(lambda *vs: jnp.stack(vs, 0), *ncaches)
    return x, ncache, aux_tot


def lm_forward(params, cfg: ModelConfig, rules: ShardingRules, mesh, *,
               tokens: jax.Array, embeds: jax.Array | None = None,
               mode: str = "causal", cache=None, pos=None):
    """Returns (logits (B, S_total, V), new_cache, aux).

    tokens (B, S_text); embeds (B, n_front, d) prepended after projection
    (VLM stub). mode 'causal' (train/prefill) or 'decode' (S_text == 1).
    """
    x = NN.embed_fwd(params["embed"], tokens, cfg)
    if embeds is not None:
        e = jnp.einsum("bnd,dk->bnk", embeds.astype(cfg.dtype),
                       params["front_proj"].astype(cfg.dtype))
        x = jnp.concatenate([e, x], axis=1)
    b, s = x.shape[:2]

    rope_dim = cfg.mla_rope_dim if cfg.attn_kind == "mla" else cfg.hd
    start = 0 if mode != "decode" else pos
    positions = jnp.arange(s) + (start if start is not None else 0)
    rope = NN.rope_tables(positions, rope_dim, cfg.rope_theta)

    if cfg.scan_layers:
        x, ncache, aux = _run_layers_scan(
            params["layers"], x, cfg, rules, mesh, rope=rope, mode=mode,
            cache=cache, pos=pos)
    else:
        x, ncache, aux = _run_layers_unrolled(
            params["layers"], x, cfg, rules, mesh, rope=rope, mode=mode,
            cache=cache, pos=pos)

    x = NN.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else {"table": params["lm_head"]}
    logits = NN.unembed_fwd(head, x, cfg)
    return logits, ncache, aux


def _run_layers_scan(layer_params, x, cfg, rules, mesh, *, rope, mode, cache,
                     pos):
    def body_nc(carry, pl):
        y, _, aux = _block_fwd(pl, carry, cfg, rules, mesh, rope=rope,
                               mode=mode, cache=None, pos=pos)
        return y, aux

    def body_c(carry, xs):
        pl, cl = xs
        y, ncl, aux = _block_fwd(pl, carry, cfg, rules, mesh, rope=rope,
                                 mode=mode, cache=cl, pos=pos)
        return y, (ncl, aux)

    if cache is None:
        fn = _remat(body_nc, cfg)
        x, auxs = jax.lax.scan(fn, x, layer_params)
        return x, None, jax.tree.map(jnp.sum, auxs)
    fn = _remat(body_c, cfg)
    x, (ncache, auxs) = jax.lax.scan(fn, x, (layer_params, cache))
    return x, ncache, jax.tree.map(jnp.sum, auxs)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (L, ...) decode cache."""
    if cfg.attn_kind == "mla":
        one = NN.init_mla_cache(cfg, batch, max_len)
    else:
        one = NN.init_attn_cache(cfg, batch, max_len)
    return jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.num_layers,) + v.shape), one)


def cache_specs(cfg: ModelConfig, rules: ShardingRules, batch: int):
    if cfg.attn_kind == "mla":
        one = NN.mla_cache_specs(cfg, rules, batch)
    else:
        one = NN.attn_cache_specs(cfg, rules, batch)
    return jax.tree.map(lambda s: P(None, *s), one,
                        is_leaf=lambda v: isinstance(v, P))
