"""ModelConfig + sharding-rule helpers shared by the whole model zoo.

One dataclass covers all 10 assigned architectures (dense GQA, MLA, MoE,
Mamba2-hybrid, xLSTM, enc-dec audio, VLM backbone); the family-specific
fields are zero/empty when unused. Parameter partition specs are produced
*with* the parameters (same tree structure) so the launcher can jit with
explicit in_shardings — Megatron-style TP over ``model``, optional
FSDP over ``data``, batch over ``("pod","data")``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Mesh axis names used throughout (launch/mesh.py builds the meshes).
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
# batch / dp sharding axes, in (multi-pod, single-pod) order of preference
DP_AXES = (POD_AXIS, DATA_AXIS)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- MoE -----------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0               # per-expert ffn width
    moe_capacity_factor: float = 1.25

    # --- MLA (minicpm3) --------------------------------------------------------
    attn_kind: str = "gqa"          # gqa | mla
    mla_q_lora: int = 0
    mla_kv_lora: int = 0
    mla_rope_dim: int = 0           # per-head rope dims
    mla_nope_dim: int = 0           # per-head nope dims
    mla_v_dim: int = 0              # per-head value dims

    # --- SSM / hybrid (zamba2) / xLSTM ----------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0              # mamba2 value heads (0 -> d_inner/64)
    ssm_chunk: int = 256            # SSD chunk length
    attn_every: int = 0             # zamba2: shared attn block period
    slstm_every: int = 0            # xlstm: sLSTM block period (rest mLSTM)

    # --- enc-dec (whisper) ------------------------------------------------------
    encoder_layers: int = 0

    # --- modality frontend (stub per assignment) -------------------------------
    frontend: str = "none"          # none | vision_stub | audio_stub
    num_frontend_tokens: int = 0    # vis/audio tokens prepended (vlm)

    # --- numerics ---------------------------------------------------------------
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16       # activation/compute dtype
    # compute params are bf16; the fp32 master copy lives in the optimizer
    # state (mixed-precision, ZeRO-sharded — train/optimizer.py)
    param_dtype: Any = jnp.bfloat16

    # --- compile/perf knobs (hillclimbed in §Perf) -------------------------------
    scan_layers: bool = True        # scan over stacked layer params
    remat: str = "full"             # none | full | dots
    fsdp: bool = False              # shard params over data axis too
    # 'tp': Megatron TP over MODEL_AXIS (baseline).
    # 'fsdp': no TP — batch shards over (data, model); weights ZeRO-3
    #   sharded over both axes, all-gathered per layer. Wins when the model
    #   is small enough that TP activations dominate collective bytes
    #   (EXPERIMENTS.md §Perf, llama3-8b train hillclimb).
    layout: str = "tp"
    ep_shuffle: bool = True         # MoE dispatch via shard_map all_to_all
    # expert-dispatch shuffle pipelining (repartition's staged primitive):
    # None = auto from wire bytes (stats.pick_stages); both knobs are
    # bit-identity-preserving, like the relational `stages`/`shuffle_mode`
    moe_shuffle_stages: int | None = None
    moe_shuffle_mode: str = "alltoall"
    decode_seq_shard: bool = True   # flash-decoding: KV cache sharded over seq
    mla_seq_shard: bool = False     # MLA latent cache sharded over seq too
    time_unroll: bool = False       # unroll inner time-chunk loops (roofline)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding so the embedding/unembedding tables
        TP-shard over any mesh axis (e.g. minicpm3's 73448 -> 73472). The
        logical vocab stays `vocab_size`; pad rows are never routed to."""
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:       # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def axis_if_divisible(dim: int, axis: str, mesh_axis_size: int) -> str | None:
    """TP an axis only when the dimension divides evenly (e.g. kv_heads=8
    cannot shard over model=16 -> replicate)."""
    return axis if dim % max(mesh_axis_size, 1) == 0 and dim >= mesh_axis_size \
        else None


class ShardingRules:
    """Turns logical dims into PartitionSpecs for a given mesh shape.

    Megatron pairing: 'col' weights shard their output dim over MODEL_AXIS,
    'row' weights shard their input dim, so each block pays exactly one
    all-reduce forward and one backward. FSDP (when enabled) shards the
    complementary dim over DATA_AXIS (gather-on-use, reduce-scatter grads).
    """

    def __init__(self, mesh_shape: dict[str, int], fsdp: bool,
                 layout: str = "tp"):
        self.model = mesh_shape.get(MODEL_AXIS, 1)
        self.data = mesh_shape.get(DATA_AXIS, 1)
        self.pod = mesh_shape.get(POD_AXIS, 1)
        self.has_pod = POD_AXIS in mesh_shape
        self.fsdp = fsdp
        self.layout = layout
        if layout == "fsdp":
            # the model axis becomes a second batch/ZeRO axis
            self.fsdp = True

    def decode_layout(self, batch: int, seq_shard: bool = True):
        """(batch_axes | None, seq_axes | None) for decode caches — see
        layers.decode_layout (same rule, mesh-free)."""
        dp = self.batch_axes()
        dp_size = self.pod * self.data
        if batch % dp_size == 0 and batch >= dp_size:
            return dp, ((MODEL_AXIS,) if seq_shard and self.model > 1
                        else None)
        axes = ((POD_AXIS,) if self.has_pod else ()) + (DATA_AXIS, MODEL_AXIS)
        return None, (axes if seq_shard else None)

    def _fs(self, dim: int) -> str | None:
        return DATA_AXIS if self.fsdp and dim % self.data == 0 \
            and dim >= self.data else None

    def _mp(self, dim: int) -> str | None:
        # in the fsdp layout the model axis shards *storage*, not math:
        # the weight is gathered on use (ZeRO-3), so it still lands on a
        # "shardable" dim — reuse the same divisibility rule
        return MODEL_AXIS if dim % self.model == 0 and dim >= self.model else None

    def col(self, in_dim: int, out_dim: int) -> P:
        """(in, out) weight, output TP-sharded."""
        return P(self._fs(in_dim), self._mp(out_dim))

    def row(self, in_dim: int, out_dim: int) -> P:
        """(in, out) weight, input TP-sharded."""
        return P(self._mp(in_dim), self._fs(out_dim))

    def vec(self, dim: int = 0) -> P:
        """1-D param (norm scale, bias): replicated (tiny)."""
        return P(None)

    def embed(self, vocab: int, d: int) -> P:
        """Embedding table: vocab TP-sharded (masked-lookup + all-reduce).

        fsdp layout: vocab over MODEL for storage, d replicated — the
        unembedding all-gathers the table (64 MB) instead of all-reducing
        batch-sharded logits (1 GB)."""
        if self.layout == "fsdp":
            return P(self._mp(vocab), None)
        return P(self._mp(vocab), self._fs(d))

    def expert_col(self, e: int, in_dim: int, out_dim: int) -> P:
        """(E, in, out) expert weight: experts over MODEL (EP)."""
        return P(self._mp(e), self._fs(in_dim), None)

    def expert_row(self, e: int, in_dim: int, out_dim: int) -> P:
        return P(self._mp(e), None, self._fs(out_dim))

    def batch_axes(self):
        if self.layout == "fsdp":
            return (POD_AXIS, DATA_AXIS, MODEL_AXIS) if self.has_pod \
                else (DATA_AXIS, MODEL_AXIS)
        return (POD_AXIS, DATA_AXIS) if self.has_pod else (DATA_AXIS,)

    def act(self, *rest) -> P:
        """Activation spec: batch over dp axes, then given axes."""
        return P(self.batch_axes(), *rest)


def stack_layer_specs(spec_tree, num_layers: int):
    """Prepend a None (layer) dim to every PartitionSpec in a layer tree."""
    return jax.tree.map(
        lambda s: P(None, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def fsdp_extend(specs, shapes, data_size: int):
    """ZeRO sharding for optimizer state: additionally shard the first free,
    divisible dim of every param over DATA_AXIS. Applied to the fp32
    master/m/v copies (and the gradient accumulator) regardless of whether
    the bf16 compute params themselves are FSDP-sharded."""
    def one(spec, shape):
        parts = list(spec) + [None] * (len(shape.shape) - len(spec))
        if any(p == DATA_AXIS or (isinstance(p, tuple) and DATA_AXIS in p)
               for p in parts):
            return spec
        for i, (p, d) in enumerate(zip(parts, shape.shape)):
            if p is None and d % data_size == 0 and d >= data_size:
                parts[i] = DATA_AXIS
                return P(*parts)
        return spec

    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
