"""Model factory: one uniform interface over all architecture families.

``build_model(cfg, mesh)`` returns a :class:`Model` whose members are pure
functions suitable for jit/pjit:

* ``init(key) -> params``; ``param_specs`` has the same tree structure
  (feed both to ``jax.jit(..., in_shardings=...)``).
* ``loss_fn(params, batch) -> (loss, metrics)`` — next-token CE, weighted
  by the pipeline's per-sample weight (the relational ETL hand-off).
* ``decode_step(params, cache, tokens, pos) -> (logits, cache)`` and
  ``init_cache/cache_specs`` for serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import encdec as ED
from repro.models import transformer as TF
from repro.models import xlstm as XL
from repro.models import zamba as ZB
from repro.models.common import ModelConfig, ShardingRules

MAX_DEC_POS = 32768  # whisper learned-position table size


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rules: ShardingRules
    mesh: Any
    init: Callable
    param_specs: Any
    forward: Callable           # (params, *, tokens, embeds, mode, cache, pos)
    init_cache: Callable        # (params-free) (batch, max_len, enc_len)
    cache_specs: Callable     # (batch) -> spec tree

    # ---- training loss -----------------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        logits, _, aux = self.forward(params, tokens=tokens, embeds=embeds,
                                      mode="causal", cache=None, pos=None)
        n_front = 0
        if cfg.family == "vlm" and embeds is not None:
            n_front = embeds.shape[1]
            logits = logits[:, n_front:]
        # next-token prediction over the text tokens
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models.common import MODEL_AXIS
            b_ax = self.rules.batch_axes()
            m = self.mesh.shape.get(MODEL_AXIS, 1)
            v_ax = MODEL_AXIS if (logits.shape[-1] % m == 0 and
                                  self.rules.layout != "fsdp") else None
            from repro.utils import safe_constrain
            logits = safe_constrain(logits, self.mesh, P(b_ax, None, v_ax))
        lg = logits[:, :-1].astype(jnp.float32)
        labels = tokens[:, 1:]
        mask = (labels != 0).astype(jnp.float32)
        if "weight" in batch:
            mask = mask * batch["weight"][:, None].astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        # one-hot contraction instead of take_along_axis: elementwise on the
        # vocab-sharded dim + reduce (psum) — never gathers the logits
        onehot = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
        ll = jnp.sum(lg * onehot, axis=-1)
        tok_loss = (lse - ll) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(tok_loss) / denom
        if cfg.moe_num_experts:
            loss = loss + 0.01 * aux["moe_aux"] / cfg.num_layers
        metrics = {"loss": loss, "tokens": jnp.sum(mask), **aux}
        return loss, metrics

    # ---- serving -----------------------------------------------------------
    def decode_step(self, params, cache, tokens, pos):
        logits, new_cache, _ = self.forward(
            params, tokens=tokens, embeds=None, mode="decode", cache=cache,
            pos=pos)
        # trim Megatron-style vocab padding (pad logits are untrained noise)
        return logits[..., : self.cfg.vocab_size], new_cache


def build_model(cfg: ModelConfig, mesh=None) -> Model:
    shape = dict(mesh.shape) if mesh is not None else {}
    rules = ShardingRules(shape, cfg.fsdp, layout=cfg.layout)

    if cfg.family in ("dense", "moe", "vlm"):
        init_ws = lambda key: TF.init_lm(key, cfg, rules)
        fwd = lambda params, **kw: TF.lm_forward(params, cfg, rules, mesh, **kw)
        init_cache = lambda batch, max_len, enc_len=0: TF.init_cache(
            cfg, batch, max_len)
        cache_specs = lambda batch: TF.cache_specs(cfg, rules, batch)
    elif cfg.family == "hybrid":
        init_ws = lambda key: ZB.init_hybrid(key, cfg, rules)
        fwd = lambda params, **kw: ZB.hybrid_forward(params, cfg, rules, mesh,
                                                     **kw)
        init_cache = lambda batch, max_len, enc_len=0: ZB.init_hybrid_cache(
            cfg, batch, max_len)
        cache_specs = lambda batch: ZB.hybrid_cache_specs(cfg, rules, batch)
    elif cfg.family == "ssm":
        init_ws = lambda key: XL.init_xlstm(key, cfg, rules)
        fwd = lambda params, **kw: XL.xlstm_forward(params, cfg, rules, mesh,
                                                    **kw)
        init_cache = lambda batch, max_len, enc_len=0: XL.init_xlstm_cache(
            cfg, batch, max_len)
        cache_specs = lambda batch: XL.xlstm_cache_specs(cfg, rules, batch)
    elif cfg.family == "audio":
        init_ws = lambda key: ED.init_encdec(key, cfg, rules, MAX_DEC_POS)
        fwd = lambda params, **kw: ED.encdec_forward(params, cfg, rules, mesh,
                                                     **kw)
        init_cache = lambda batch, max_len, enc_len: ED.init_encdec_cache(
            cfg, batch, max_len, enc_len)
        cache_specs = lambda batch: ED.encdec_cache_specs(cfg, rules, batch)
    else:
        raise ValueError(cfg.family)

    return Model(cfg=cfg, rules=rules, mesh=mesh,
                 init=lambda key: init_ws(key)[0],
                 param_specs=_trace_specs(init_ws), forward=fwd,
                 init_cache=init_cache, cache_specs=cache_specs)


def _trace_specs(init_ws):
    """Capture the spec tree without allocating params: trace the init under
    eval_shape and grab the (pure-python) specs through a side channel."""
    box = {}

    def wrapped(key):
        params, specs = init_ws(key)
        box["specs"] = specs
        return params

    jax.eval_shape(wrapped, jax.random.PRNGKey(0))
    return box["specs"]
