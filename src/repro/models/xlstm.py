"""xLSTM LM: mLSTM (matrix-memory) + sLSTM (scalar-memory) blocks.

xlstm-1.3b layout: 48 blocks, one sLSTM per ``slstm_every=8`` (rest mLSTM,
the paper's 7:1 ratio) — scanned per period (7 stacked mLSTM + 1 sLSTM).
The mLSTM runs on the shared chunked-GLA engine (``recurrent.py``) with the
normalizer riding as an augmented value column; the sLSTM runs as one
associative scan. Both are O(S) — this and zamba2 are the archs that run
the ``long_500k`` cells.

Numerics simplification (documented, DESIGN.md): sigmoid input/forget gates
instead of exponential-gating + running-max stabilizer; FLOP/memory/state
structure identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as NN
from repro.models.common import ModelConfig, ShardingRules, stack_layer_specs
from repro.models.recurrent import (
    causal_depthwise_conv, chunked_gla, gla_decode_step, slstm_decode_step,
    slstm_scan)
from repro.models.transformer import _remat
from repro.utils import round_up

AUX0 = {"moe_aux": jnp.float32(0), "moe_dropped": jnp.float32(0)}


def _mlstm_dims(cfg: ModelConfig):
    d_in = 2 * cfg.d_model
    h = cfg.num_heads
    return d_in, h, d_in // h


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm_block(key, cfg: ModelConfig, rules: ShardingRules):
    d = cfg.d_model
    d_in, h, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    p = {
        "ln": NN.init_norm(d, cfg.param_dtype),
        "up": NN._dense(ks[0], (d, 2 * d_in), cfg.param_dtype),
        "conv_w": NN._dense(ks[1], (cfg.ssm_conv, d_in), cfg.param_dtype,
                            scale=0.5),
        # block-diagonal per-head q/k projections (xLSTM BlockLinear); v is
        # the unprojected inner activation — matches the 1.3b param budget
        "wq": NN._dense(ks[2], (h, hd, hd), cfg.param_dtype),
        "wk": NN._dense(ks[3], (h, hd, hd), cfg.param_dtype),
        "w_ig": NN._dense(ks[5], (d_in, h), cfg.param_dtype),
        "b_ig": jnp.zeros((h,), cfg.param_dtype),
        "w_fg": NN._dense(ks[6], (d_in, h), cfg.param_dtype),
        "b_fg": jnp.full((h,), 3.0, cfg.param_dtype),   # open forget gates
        "gnorm": NN.init_norm(d_in, cfg.param_dtype),
        "skip": jnp.ones((d_in,), cfg.param_dtype),
        "down": NN._dense(ks[7], (d_in, d), cfg.param_dtype),
    }
    s = {
        "ln": rules.vec(), "up": rules.col(d, 2 * d_in), "conv_w": P(None, None),
        # block-diag q/k: FSDP-shard the contraction dim (gather-on-use)
        "wq": P(None, rules._fs(hd), None), "wk": P(None, rules._fs(hd), None),
        "w_ig": P(None, None),
        "b_ig": rules.vec(), "w_fg": P(None, None), "b_fg": rules.vec(),
        "gnorm": rules.vec(), "skip": rules.vec(), "down": rules.row(d_in, d),
    }
    return p, s


def mlstm_fwd(p, x, cfg: ModelConfig, *, cache=None, decode=False):
    """cache = {'conv': (B,K-1,d_in), 'state': (B,H,hd,hd+1) fp32}."""
    b, s, d = x.shape
    d_in, h, hd = _mlstm_dims(cfg)
    dt = x.dtype
    hx = NN.rms_norm(x, p["ln"], cfg.norm_eps)
    ui = jnp.einsum("bsd,dk->bsk", hx, p["up"].astype(dt))
    xi, z = ui[..., :d_in], ui[..., d_in:]
    xc, new_conv = causal_depthwise_conv(
        xi, p["conv_w"], cache["conv"] if cache is not None else None)
    xc = jax.nn.silu(xc)
    xch = xc.reshape(b, s, h, hd)
    q = jnp.einsum("bshk,hkj->bshj", xch, p["wq"].astype(dt))
    k = jnp.einsum("bshk,hkj->bshj", xch, p["wk"].astype(dt))
    k = k / jnp.sqrt(jnp.float32(hd)).astype(dt)
    v = xi.reshape(b, s, h, hd)
    ig = jax.nn.sigmoid(jnp.einsum("bsk,kh->bsh", xi, p["w_ig"].astype(dt))
                        .astype(jnp.float32) + p["b_ig"].astype(jnp.float32))
    fg = jax.nn.sigmoid(jnp.einsum("bsk,kh->bsh", xi, p["w_fg"].astype(dt))
                        .astype(jnp.float32) + p["b_fg"].astype(jnp.float32))
    log_a = jnp.log(fg + 1e-6)
    kt = k * ig[..., None].astype(dt)               # fold input gate into k
    v_aug = jnp.concatenate([v, jnp.ones((b, s, h, 1), dt)], -1)

    if decode:
        assert s == 1
        y_aug, new_state = gla_decode_step(
            q[:, 0], kt[:, 0], v_aug[:, 0], log_a[:, 0], cache["state"])
        y_aug = y_aug[:, None]
    else:
        init = cache["state"] if cache is not None else None
        y_aug, new_state = chunked_gla(
            q, kt, v_aug, log_a, chunk=min(cfg.ssm_chunk, s),
            initial_state=init, unroll=cfg.time_unroll)
    y, denom = y_aug[..., :hd], y_aug[..., hd:]
    y = y / jnp.maximum(jnp.abs(denom.astype(jnp.float32)), 1.0).astype(dt)
    y = y.reshape(b, s, d_in)
    y = NN.rms_norm(y, p["gnorm"], cfg.norm_eps) + xc * p["skip"].astype(dt)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["down"].astype(dt))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": new_state}
    return x + out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    d_in, h, hd = _mlstm_dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), cfg.dtype),
            "state": jnp.zeros((batch, h, hd, hd + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block (+ its post-up FFN, PF 4/3)
# ---------------------------------------------------------------------------


def _slstm_ff(cfg: ModelConfig) -> int:
    return round_up(int(cfg.d_model * 4 / 3), 128)


def init_slstm_block(key, cfg: ModelConfig, rules: ShardingRules):
    d = cfg.d_model
    ff = _slstm_ff(cfg)
    ks = jax.random.split(key, 6)
    p = {"ln": NN.init_norm(d, cfg.param_dtype),
         "wi": NN._dense(ks[0], (d, d), cfg.param_dtype),
         "wf": NN._dense(ks[1], (d, d), cfg.param_dtype),
         "wz": NN._dense(ks[2], (d, d), cfg.param_dtype),
         "wo": NN._dense(ks[3], (d, d), cfg.param_dtype),
         "b_i": jnp.zeros((d,), cfg.param_dtype),
         "b_f": jnp.full((d,), 3.0, cfg.param_dtype),
         "gnorm": NN.init_norm(d, cfg.param_dtype),
         "ln2": NN.init_norm(d, cfg.param_dtype)}
    mlp_p, mlp_s = NN.init_mlp(ks[4], d, ff, cfg, rules)
    p["mlp"] = mlp_p
    s = {"ln": rules.vec(), "wi": rules.col(d, d), "wf": rules.col(d, d),
         "wz": rules.col(d, d), "wo": rules.col(d, d), "b_i": rules.vec(),
         "b_f": rules.vec(), "gnorm": rules.vec(), "ln2": rules.vec(),
         "mlp": mlp_s}
    return p, s


def slstm_fwd(p, x, cfg: ModelConfig, *, cache=None, decode=False):
    """cache = {'c': (B,d) fp32, 'n': (B,d) fp32}."""
    b, s, d = x.shape
    dt = x.dtype
    hx = NN.rms_norm(x, p["ln"], cfg.norm_eps)
    i = jax.nn.sigmoid(hx @ p["wi"].astype(dt) + p["b_i"].astype(dt))
    f = jax.nn.sigmoid(hx @ p["wf"].astype(dt) + p["b_f"].astype(dt))
    z = jnp.tanh(hx @ p["wz"].astype(dt))
    o = jax.nn.sigmoid(hx @ p["wo"].astype(dt))
    if decode:
        assert s == 1
        h, (c, n) = slstm_decode_step(i[:, 0], f[:, 0], z[:, 0], o[:, 0],
                                      (cache["c"], cache["n"]))
        h = h[:, None]
    else:
        c0 = cache["c"] if cache is not None else None
        n0 = cache["n"] if cache is not None else None
        h, (c, n) = slstm_scan(i, f, z, o, c0, n0)
    h = NN.rms_norm(h, p["gnorm"], cfg.norm_eps)
    x = x + h
    hx = NN.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + NN.mlp_fwd(p["mlp"], hx)
    new_cache = {"c": c, "n": n} if cache is not None else None
    return x, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32)}


# ---------------------------------------------------------------------------
# full model: periods of (slstm_every-1 mLSTM) + 1 sLSTM
# ---------------------------------------------------------------------------


def _xl_counts(cfg: ModelConfig):
    per = cfg.slstm_every
    periods = cfg.num_layers // per
    rem = cfg.num_layers - periods * per   # trailing mLSTM layers
    return periods, per - 1, rem


def init_xlstm(key, cfg: ModelConfig, rules: ShardingRules):
    periods, m_per, rem = _xl_counts(cfg)
    n_m = periods * m_per + rem
    ks = jax.random.split(key, 5)
    embed_p, embed_s = NN.init_embed(ks[0], cfg, rules)
    mkeys = jax.random.split(ks[1], max(n_m, 1))
    mp = jax.vmap(lambda k: init_mlstm_block(k, cfg, rules)[0])(mkeys)
    _, ms = init_mlstm_block(ks[1], cfg, rules)
    skeys = jax.random.split(ks[2], max(periods, 1))
    sp = jax.vmap(lambda k: init_slstm_block(k, cfg, rules)[0])(skeys)
    _, ss = init_slstm_block(ks[2], cfg, rules)
    params = {"embed": embed_p, "mlstm": mp, "slstm": sp,
              "final_norm": NN.init_norm(cfg.d_model, cfg.param_dtype),
              "lm_head": NN._dense(ks[3], (cfg.padded_vocab, cfg.d_model),
                                   cfg.param_dtype)}
    specs = {"embed": embed_s, "mlstm": stack_layer_specs(ms, n_m),
             "slstm": stack_layer_specs(ss, periods),
             "final_norm": rules.vec(),
             "lm_head": rules.embed(cfg.padded_vocab, cfg.d_model)}
    return params, specs


def xlstm_forward(params, cfg: ModelConfig, rules: ShardingRules, mesh, *,
                  tokens, embeds=None, mode="causal", cache=None, pos=None):
    assert embeds is None
    x = NN.embed_fwd(params["embed"], tokens, cfg)
    periods, m_per, rem = _xl_counts(cfg)
    decode = mode == "decode"

    mp = params["mlstm"]
    mp_main = jax.tree.map(lambda v: v[: periods * m_per].reshape(
        (periods, m_per) + v.shape[1:]), mp)
    mp_rem = jax.tree.map(lambda v: v[periods * m_per :], mp)
    cm_main = cm_rem = cs = None
    if cache is not None:
        cm_main = jax.tree.map(lambda v: v[: periods * m_per].reshape(
            (periods, m_per) + v.shape[1:]), cache["mlstm"])
        cm_rem = jax.tree.map(lambda v: v[periods * m_per :], cache["mlstm"])
        cs = cache["slstm"]

    def m_step(carry, xs):
        pl, cl = xs
        y, ncl = mlstm_fwd(pl, carry, cfg, cache=cl, decode=decode)
        return y, ncl

    def period_body(carry, xs):
        pm, ps, cm, csl = xs
        if cache is None:
            y, _ = jax.lax.scan(lambda c, pl: m_step(c, (pl, None)), carry, pm)
            ncm = None
        else:
            y, ncm = jax.lax.scan(m_step, carry, (pm, cm))
        y, ncs = slstm_fwd(ps, y, cfg, cache=csl, decode=decode)
        return y, (ncm, ncs)

    body = _remat(period_body, cfg)
    at = lambda t, i: jax.tree.map(lambda v: v[i], t)
    if not cfg.scan_layers:  # unrolled (roofline depth-pair lowerings)
        ncms, ncss = [], []
        for i in range(periods):
            cm = at(cm_main, i) if cache is not None else None
            for j in range(m_per):
                x, ncl = mlstm_fwd(at(at(mp_main, i), j), x, cfg, cache=(
                    at(cm, j) if cm is not None else None), decode=decode)
                if cache is not None:
                    ncms.append(ncl)
            x, ncsl = slstm_fwd(at(params["slstm"], i), x, cfg, cache=(
                at(cs, i) if cache is not None else None), decode=decode)
            if cache is not None:
                ncss.append(ncsl)
        for j in range(rem):
            cl = at(cm_rem, j) if cache is not None else None
            x, ncl = mlstm_fwd(at(mp_rem, j), x, cfg, cache=cl, decode=decode)
            if cache is not None:
                ncms.append(ncl)
        ncm = ncs = None
        if cache is not None:
            ncm = jax.tree.map(lambda *v: jnp.stack(v, 0), *ncms)
            ncs = jax.tree.map(lambda *v: jnp.stack(v, 0), *ncss) if ncss \
                else jax.tree.map(lambda v: v[:0], cs)
    elif periods:
        if cache is None:
            x, _ = jax.lax.scan(
                lambda c, xs: body(c, (xs[0], xs[1], None, None)), x,
                (mp_main, params["slstm"]))
            ncm = ncs = None
        else:
            x, (ncm, ncs) = jax.lax.scan(
                body, x, (mp_main, params["slstm"], cm_main, cs))
            ncm = jax.tree.map(
                lambda v: v.reshape((periods * m_per,) + v.shape[2:]), ncm)
    else:
        ncm = cache["mlstm"] if cache is not None else None
        ncs = cache["slstm"] if cache is not None else None
        ncm = jax.tree.map(lambda v: v[:0], ncm) if ncm is not None else None
    if cfg.scan_layers and rem:
        if cache is None:
            x, _ = jax.lax.scan(lambda c, pl: m_step(c, (pl, None)), x, mp_rem)
        else:
            x, ncr = jax.lax.scan(m_step, x, (mp_rem, cm_rem))
            ncm = jax.tree.map(lambda a, r: jnp.concatenate([a, r], 0),
                               ncm, ncr) if ncm is not None else ncr

    x = NN.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = NN.unembed_fwd({"table": params["lm_head"]}, x, cfg)
    ncache = None
    if cache is not None:
        ncache = {"mlstm": ncm, "slstm": ncs}
    return logits, ncache, dict(AUX0)


def init_xlstm_cache(cfg: ModelConfig, batch: int, max_len: int):
    periods, m_per, rem = _xl_counts(cfg)
    n_m = periods * m_per + rem
    m_one = init_mlstm_cache(cfg, batch)
    s_one = init_slstm_cache(cfg, batch)
    return {
        "mlstm": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n_m,) + v.shape), m_one),
        "slstm": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (periods,) + v.shape), s_one),
    }


def xlstm_cache_specs(cfg: ModelConfig, rules: ShardingRules, batch: int):
    b, _ = rules.decode_layout(batch, False)
    return {
        "mlstm": {"conv": P(None, b, None, None),
                  "state": P(None, b, None, None, None)},
        "slstm": {"c": P(None, b, None), "n": P(None, b, None)},
    }
