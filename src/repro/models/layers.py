"""Shared neural layers: norms, RoPE, embeddings, GQA/MLA attention, MLP.

All layers are functional: ``init_*`` returns ``(params, partition_specs)``
with identical tree structure; ``*_fwd`` consumes the params. Compute runs
in ``cfg.dtype`` (bf16) with fp32 softmax/normalization; master params are
``cfg.param_dtype``.

Attention modes
---------------
* ``causal`` / ``bidir`` — full S×T score matrix (training / prefill /
  encoder). Masked in fp32.
* ``decode`` — one new token against a KV cache. Two paths:
  - plain: cache replicated over MODEL_AXIS (kv_heads rarely divide the
    model axis — GQA's kv=8 vs model=16).
  - **flash-decode (seq-sharded)**: the cache is sharded over MODEL_AXIS on
    the *sequence* dim; each shard computes a partial (max, sumexp, out) and
    the shards merge via a tiny LSE all-reduce — 3 scalars-per-head of
    traffic instead of an all-gathered cache. This is the beyond-paper
    optimization for the decode cells (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    DATA_AXIS, MODEL_AXIS, POD_AXIS, ModelConfig, ShardingRules)
from repro.utils import shard_map


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with fp32-accumulated statistics but bf16 elementwise math.

    The sum-of-squares rides an einsum contraction with fp32 accumulation,
    so the (B,S,d) stream is never materialized in fp32 — forward OR
    backward (the fp32 cotangent of a full upcast would otherwise double
    every residual-stream byte and force fp32 TP all-reduces; see
    EXPERIMENTS.md §Perf iteration 'norm-traffic')."""
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv[..., None] * scale.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None,
               eps: float) -> jax.Array:
    """LayerNorm, fp32-accumulated statistics, bf16 elementwise."""
    d = x.shape[-1]
    mu = (jnp.einsum("...d->...", x, preferred_element_type=jnp.float32)
          / d)
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / d
    var = jnp.maximum(ms - mu * mu, 0.0)
    inv = jax.lax.rsqrt(var + eps)
    mu = mu.astype(x.dtype)
    inv = inv.astype(x.dtype)
    y = (x - mu[..., None]) * inv[..., None] * scale.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# RoPE (llama half-split convention)
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, dim: int, theta: float):
    """positions (S,) -> (sin, cos) each (S, dim/2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., S, H, hd); sin/cos (S, hd/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    s = sin[..., :, None, :]
    c = cos[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(dt)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig, rules: ShardingRules):
    p = {"table": _dense(key, (cfg.padded_vocab, cfg.d_model),
                         cfg.param_dtype, scale=0.02)}
    s = {"table": rules.embed(cfg.padded_vocab, cfg.d_model)}
    return p, s


def embed_fwd(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["table"].astype(cfg.dtype)[tokens]


def unembed_fwd(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B,S,d) -> logits (B,S,V). Vocab dim is TP-sharded by the table."""
    return jnp.einsum("bsd,vd->bsv", x, p["table"].astype(cfg.dtype))


# ---------------------------------------------------------------------------
# MLP (SwiGLU; whisper uses GELU via kind='gelu')
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, cfg: ModelConfig, rules: ShardingRules,
             kind: str = "swiglu"):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        p = {"wi": _dense(ks[0], (d, d_ff), cfg.param_dtype),
             "wg": _dense(ks[1], (d, d_ff), cfg.param_dtype),
             "wo": _dense(ks[2], (d_ff, d), cfg.param_dtype)}
        s = {"wi": rules.col(d, d_ff), "wg": rules.col(d, d_ff),
             "wo": rules.row(d_ff, d)}
    else:  # gelu
        p = {"wi": _dense(ks[0], (d, d_ff), cfg.param_dtype),
             "wo": _dense(ks[2], (d_ff, d), cfg.param_dtype)}
        s = {"wi": rules.col(d, d_ff), "wo": rules.row(d_ff, d)}
    return p, s


def mlp_fwd(p, x: jax.Array) -> jax.Array:
    if "wg" in p:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, rules: ShardingRules):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": _dense(ks[0], (d, H * hd), cfg.param_dtype),
         "wk": _dense(ks[1], (d, KV * hd), cfg.param_dtype),
         "wv": _dense(ks[2], (d, KV * hd), cfg.param_dtype),
         "wo": _dense(ks[3], (H * hd, d), cfg.param_dtype)}
    s = {"wq": rules.col(d, H * hd), "wk": rules.col(d, KV * hd),
         "wv": rules.col(d, KV * hd), "wo": rules.row(H * hd, d)}
    return p, s


ATTN_CHUNK_THRESHOLD = 8192   # S above this uses the chunked (flash-style)
ATTN_CHUNK = 2048             # block size for chunked attention


def _constrainer(cfg: ModelConfig, mesh, num_heads: int):
    """Returns (impl, constrain_fn). impl 'heads' TP-shards the head dim
    (requires H % model == 0); 'qseq' shards the query sequence dim instead
    (archs like minicpm3 H=40 / whisper H=8 that don't divide the axis);
    'dp' (fsdp layout) shards only the batch dim over (data, model).
    Sharding the (B,H,S,T) scores is what keeps attention transients
    per-device-small — GSPMD cannot shard the grouped (KV,G) split itself
    (EXPERIMENTS.md §Perf iteration 1)."""
    if mesh is None:
        return "heads", lambda x, spec: x

    def constrain(x, spec):
        from repro.utils import safe_constrain
        return safe_constrain(x, mesh, spec)

    if cfg.layout == "fsdp":
        return "dp", constrain
    m = mesh.shape.get(MODEL_AXIS, 1)
    impl = "heads" if num_heads % max(m, 1) == 0 and num_heads >= m else "qseq"
    return impl, constrain


def _repeat_kv(k, g: int):
    b, t, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, g, hd)) \
        .reshape(b, t, kv * g, hd)


def _mask_scores(scores, *, causal, q_offset, kv_len, s, t):
    neg = jnp.float32(-1e30)
    tpos = jnp.arange(t)
    if causal:
        qpos = jnp.arange(s) + q_offset
        scores = jnp.where(tpos[None, :] <= qpos[:, None], scores, neg)
    if kv_len is not None:
        scores = jnp.where(tpos < kv_len, scores, neg)
    return scores


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len=None, cfg=None,
          mesh=None):
    """q (B,S,H,hd), k/v (B,T,KV,hd) -> (B,S,H,hd). fp32 softmax.

    KV heads are broadcast to H (repeat-heads GQA) so the score tensor is
    (B,H,S,T) — TP-shardable on H. Long sequences take a chunked path that
    never materializes the full score matrix (flash-style; the Pallas
    kernel kernels/flash_attention.py is the TPU-runtime equivalent).
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    impl, cons = _constrainer(cfg, mesh, h) if cfg is not None else \
        ("heads", lambda x, spec: x)
    if impl == "dp":  # fsdp layout: batch over (pod?, data, model)
        dp = ((POD_AXIS, DATA_AXIS, MODEL_AXIS)
              if (mesh is not None and POD_AXIS in mesh.axis_names)
              else (DATA_AXIS, MODEL_AXIS))
    else:
        dp = (POD_AXIS, DATA_AXIS) if (mesh is not None and
                                       POD_AXIS in mesh.axis_names) else \
            (DATA_AXIS,)
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    if impl == "heads":
        q = cons(q, P(dp, None, MODEL_AXIS, None))
        k = cons(k, P(dp, None, MODEL_AXIS, None))
        v = cons(v, P(dp, None, MODEL_AXIS, None))
    elif impl == "dp":
        q = cons(q, P(dp, None, None, None))
        k = cons(k, P(dp, None, None, None))
        v = cons(v, P(dp, None, None, None))
    else:  # qseq: queries sequence-sharded, keys replicated
        q = cons(q, P(dp, MODEL_AXIS, None, None))
        k = cons(k, P(dp, None, None, None))
        v = cons(v, P(dp, None, None, None))

    chunk_it = s > ATTN_CHUNK_THRESHOLD and s % ATTN_CHUNK == 0
    if not chunk_it:
        scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
        scores = _mask_scores(scores / math.sqrt(hd), causal=causal,
                              q_offset=q_offset, kv_len=kv_len, s=s, t=t)
        if impl == "heads":
            scores = cons(scores, P(dp, MODEL_AXIS, None, None))
        elif impl == "dp":
            scores = cons(scores, P(dp, None, None, None))
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)
    if impl in ("heads", "dp"):
        return _chunked_q(q, k, v, causal=causal, q_offset=q_offset,
                          kv_len=kv_len, cfg=cfg)
    return _chunked_k(q, k, v, causal=causal, q_offset=q_offset,
                      kv_len=kv_len, cfg=cfg)


def _chunked_q(q, k, v, *, causal, q_offset, kv_len, cfg):
    """Loop over query blocks (head-sharded impl: every shard active on its
    heads each step). Scores transient = (B, H, bq, T)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(ATTN_CHUNK, s)
    nb = s // bq
    scale = 1.0 / math.sqrt(hd)

    def block(qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=1)
        scores = jnp.einsum("bshd,bthd->bhst", qb, k).astype(jnp.float32)
        scores = _mask_scores(scores * scale, causal=causal,
                              q_offset=q_offset + qi * bq, kv_len=kv_len,
                              s=bq, t=t)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)

    if cfg is not None and cfg.time_unroll:
        outs = [block(i) for i in range(nb)]
    else:
        _, outs = jax.lax.scan(lambda c, i: (c, block(i)), None,
                               jnp.arange(nb))
        return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return jnp.concatenate(outs, axis=1)


def _chunked_k(q, k, v, *, causal, q_offset, kv_len, cfg):
    """Online-softmax loop over key blocks (qseq impl: queries stay
    sequence-sharded; each step all shards process one key block)."""
    b, s, h, hd = q.shape
    dv = v.shape[-1]
    t = k.shape[1]
    bk = min(ATTN_CHUNK, t)
    nb = t // bk
    scale = 1.0 / math.sqrt(hd)
    qpos = jnp.arange(s) + q_offset

    def block(carry, ki):
        m_prev, l_prev, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=1)
        scores = jnp.einsum("bshd,bthd->bhst", q, kb).astype(jnp.float32)
        scores = scores * scale
        tpos = ki * bk + jnp.arange(bk)
        neg = jnp.float32(-1e30)
        if causal:
            scores = jnp.where(tpos[None, :] <= qpos[:, None], scores, neg)
        if kv_len is not None:
            scores = jnp.where(tpos < kv_len, scores, neg)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bhst,bthd->bshd", p.astype(q.dtype), vb)
        # corr (B,H,S,1) -> (B,S,H,1) to scale acc (B,S,H,hd)
        acc = acc * corr.transpose(0, 2, 1, 3).astype(q.dtype) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, s, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, s, 1), jnp.float32)
    a0 = jnp.zeros((b, s, h, dv), q.dtype)
    if cfg is not None and cfg.time_unroll:
        carry = (m0, l0, a0)
        for i in range(nb):
            carry, _ = block(carry, i)
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0), jnp.arange(nb))
    return acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1, 3).astype(q.dtype)


def decode_layout(mesh, batch: int):
    """(batch_axes | None, seq_axes) for decode-cell sharding.

    Normal decode (batch divides the DP axes): batch over DP, cache
    sequence over MODEL. Small-batch long-context decode (e.g. the
    long_500k cell, B=1): batch unsharded, cache sequence over EVERY mesh
    axis — 500k of KV spread across all 256/512 chips, merged by the
    flash-decode LSE reduction.
    """
    dp = (POD_AXIS, DATA_AXIS) if POD_AXIS in mesh.axis_names else (DATA_AXIS,)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if batch % dp_size == 0 and batch >= dp_size:
        return dp, (MODEL_AXIS,)
    return None, tuple(mesh.axis_names)


def _multi_axis_index(axes, mesh_shape):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh_shape[a] + jax.lax.axis_index(a)
    return idx


def _flash_decode_shard(q, k, v, kv_len, axes: tuple, mesh_shape: dict):
    """Per-shard flash-decoding body (inside shard_map over `axes`).

    q (B,S=1,KV,G,hd) replicated over `axes`; k/v (B,T_loc,KV,hd) = this
    shard's slice of the sequence dim; kv_len = global filled length.
    Combines shards with an LSE merge: traffic = (B,KV,G) * 3 scalars.
    """
    B, S, KV, G, hd = q.shape
    t_loc = k.shape[1]
    idx = _multi_axis_index(axes, mesh_shape)
    tpos = idx * t_loc + jnp.arange(t_loc)  # global positions of this slice
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(tpos < kv_len, scores, jnp.float32(-1e30))
    m = jnp.max(scores, axis=-1, keepdims=True)            # (B,KV,G,S,1)
    e = jnp.exp(scores - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bkgst,btkh->bskgh", e.astype(q.dtype), v)
    # LSE merge across shards
    M = jax.lax.pmax(m, axes)
    corr = jnp.exp(m - M)
    l_g = jax.lax.psum(l * corr, axes)
    o_g = jax.lax.psum(o * corr.transpose(0, 3, 1, 2, 4).astype(q.dtype),
                       axes)
    return (o_g / l_g.transpose(0, 3, 1, 2, 4).astype(q.dtype)).reshape(
        B, S, KV * G, hd)


def attention_fwd(p, x: jax.Array, cfg: ModelConfig, *, mode: str,
                  rope=None, cache=None, pos=None, x_kv=None, mesh=None,
                  q_offset=0):
    """Unified attention. Returns (out, new_cache).

    mode: 'causal' | 'bidir' | 'decode' | 'cross' | 'cross_decode'.
    cache: {'k','v'} (B, S_max, KV, hd) for self-decode; for cross modes the
    cache holds the (static) encoder K/V. pos: scalar int32 write position.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt)).reshape(B, S, H, hd)

    if mode in ("cross", "cross_decode"):
        if mode == "cross":  # build cross K/V from encoder output x_kv
            k = jnp.einsum("bsd,dh->bsh", x_kv, p["wk"].astype(dt)) \
                .reshape(B, -1, KV, hd)
            v = jnp.einsum("bsd,dh->bsh", x_kv, p["wv"].astype(dt)) \
                .reshape(B, -1, KV, hd)
            new_cache = {"k": k, "v": v}
        else:
            k, v = cache["k"], cache["v"]
            new_cache = cache
        out = _sdpa(q, k.astype(dt), v.astype(dt), causal=False, cfg=cfg,
                    mesh=mesh)
        out = out.reshape(B, S, H * hd)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt)), new_cache

    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt)).reshape(B, S, KV, hd)
    if rope is not None:
        sin, cos = rope
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    if mode in ("causal", "bidir"):
        out = _sdpa(q, k, v, causal=(mode == "causal"), q_offset=q_offset,
                    cfg=cfg, mesh=mesh)
        new_cache = None
        if cache is not None:  # prefill into a bigger cache
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
            }
        out = out.reshape(B, S, H * hd)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt)), new_cache

    assert mode == "decode", mode
    kc = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    new_cache = {"k": kc, "v": vc}
    kv_len = pos + S
    if mesh is not None and cfg.decode_seq_shard and \
            mesh.shape.get(MODEL_AXIS, 1) > 1:
        G = H // KV
        qg = q.reshape(B, S, KV, G, hd)
        batch, seq_axes = decode_layout(mesh, B)
        out = shard_map(
            partial(_flash_decode_shard, axes=seq_axes,
                    mesh_shape=dict(mesh.shape)),
            mesh=mesh,
            in_specs=(P(batch, None, None, None, None),
                      P(batch, seq_axes, None, None),
                      P(batch, seq_axes, None, None),
                      P()),
            out_specs=P(batch, None, None, None),
        )(qg, kc.astype(dt), vc.astype(dt), jnp.asarray(kv_len, jnp.int32))
    else:
        out = _sdpa(q, kc.astype(dt), vc.astype(dt), causal=True,
                    q_offset=pos, kv_len=kv_len, cfg=cfg, mesh=mesh)
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt)), new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    KV, hd = cfg.num_kv_heads, cfg.hd
    dtype = dtype or cfg.dtype
    return {"k": jnp.zeros((batch, max_len, KV, hd), dtype),
            "v": jnp.zeros((batch, max_len, KV, hd), dtype)}


def attn_cache_specs(cfg: ModelConfig, rules: ShardingRules, batch: int):
    """Cache specs: batch over DP + sequence over MODEL (flash-decode);
    small-batch long-context flips to sequence-over-everything."""
    b, seq = rules.decode_layout(batch, cfg.decode_seq_shard)
    return {"k": P(b, seq, None, None), "v": P(b, seq, None, None)}


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-style latent KV)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, rules: ShardingRules):
    d, H = cfg.d_model, cfg.num_heads
    ql, kvl = cfg.mla_q_lora, cfg.mla_kv_lora
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    ks = jax.random.split(key, 7)
    p = {"w_dq": _dense(ks[0], (d, ql), cfg.param_dtype),
         "q_norm": init_norm(ql, cfg.param_dtype),
         "w_uq": _dense(ks[1], (ql, H * (nd + rd)), cfg.param_dtype),
         "w_dkv": _dense(ks[2], (d, kvl + rd), cfg.param_dtype),
         "kv_norm": init_norm(kvl, cfg.param_dtype),
         "w_uk": _dense(ks[3], (kvl, H * nd), cfg.param_dtype),
         "w_uv": _dense(ks[4], (kvl, H * vd), cfg.param_dtype),
         "wo": _dense(ks[5], (H * vd, d), cfg.param_dtype)}
    s = {"w_dq": rules.col(d, ql), "q_norm": rules.vec(),
         "w_uq": rules.col(ql, H * (nd + rd)),
         "w_dkv": P(None, None), "kv_norm": rules.vec(),
         "w_uk": rules.col(kvl, H * nd), "w_uv": rules.col(kvl, H * vd),
         "wo": rules.row(H * vd, d)}
    return p, s


def mla_fwd(p, x: jax.Array, cfg: ModelConfig, *, mode: str, rope,
            cache=None, pos=None, mesh=None):
    """MLA. Cache stores the *latents* (c_kv, k_rope) — the serving win.

    prefill/train: materialize per-head K/V. decode: absorbed attention in
    latent space (q·W_uk folded into q) — never materializes K/V.
    """
    B, S, d = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kvl = cfg.mla_kv_lora
    dt = x.dtype
    sin, cos = rope

    cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["w_dq"].astype(dt)),
                  p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qh->bsh", cq, p["w_uq"].astype(dt)) \
        .reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, sin, cos)

    dkv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"].astype(dt))
    c_kv = rms_norm(dkv[..., :kvl], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., kvl:][:, :, None, :], sin, cos)[:, :, 0, :]

    scale = 1.0 / math.sqrt(nd + rd)
    w_uk = p["w_uk"].astype(dt).reshape(kvl, H, nd)

    if mode in ("causal", "prefill"):
        k_nope = jnp.einsum("bsk,khn->bshn", c_kv, w_uk)
        v = jnp.einsum("bsk,khv->bshv", c_kv,
                       p["w_uv"].astype(dt).reshape(kvl, H, vd))
        kr = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))
        k_full = jnp.concatenate([k_nope, kr], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        ctx = _sdpa(q_full, k_full, v, causal=True, cfg=cfg, mesh=mesh)
        new_cache = None
        if cache is not None:
            new_cache = {
                "c_kv": jax.lax.dynamic_update_slice(
                    cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
                "k_rope": jax.lax.dynamic_update_slice(
                    cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                    (0, 0, 0)),
            }
    else:  # decode — absorbed/latent attention
        assert mode == "decode"
        q_lat = jnp.einsum("bshn,khn->bshk", q_nope, w_uk)     # absorb W_uk
        if mesh is not None and cfg.mla_seq_shard and \
                mesh.shape.get(MODEL_AXIS, 1) > 1:
            batch, seq_axes = decode_layout(mesh, B)
            ctx_lat, ckv_c, kr_c = shard_map(
                partial(_mla_flash_decode_shard, scale=scale, axes=seq_axes,
                        mesh_shape=dict(mesh.shape)),
                mesh=mesh,
                in_specs=(P(batch, None, None, None),
                          P(batch, None, None, None),
                          P(batch, None, None), P(batch, None, None),
                          P(batch, seq_axes, None), P(batch, seq_axes, None),
                          P()),
                out_specs=(P(batch, None, None, None),
                           P(batch, seq_axes, None),
                           P(batch, seq_axes, None)),
            )(q_lat, q_rope, c_kv, k_rope, cache["c_kv"], cache["k_rope"],
              jnp.asarray(pos, jnp.int32))
            new_cache = {"c_kv": ckv_c, "k_rope": kr_c}
        else:
            ckv_c = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
            kr_c = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, pos, 0))
            new_cache = {"c_kv": ckv_c, "k_rope": kr_c}
            scores = (jnp.einsum("bshk,btk->bhst", q_lat, ckv_c.astype(dt)) +
                      jnp.einsum("bshr,btr->bhst", q_rope, kr_c.astype(dt)))
            scores = scores.astype(jnp.float32) * scale
            kv_len = pos + S
            scores = jnp.where(
                jnp.arange(cache["c_kv"].shape[1])[None, :] < kv_len,
                scores, jnp.float32(-1e30))
            probs = jax.nn.softmax(scores, -1).astype(dt)
            ctx_lat = jnp.einsum("bhst,btk->bshk", probs, ckv_c.astype(dt))
        ctx = jnp.einsum("bshk,khv->bshv", ctx_lat,
                         p["w_uv"].astype(dt).reshape(kvl, H, vd))

    out = ctx.reshape(B, S, H * vd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt)), new_cache


def _mla_flash_decode_shard(q_lat, q_rope, ckv_new, kr_new, ckv_cache,
                            kr_cache, pos, *, scale: float, axes: tuple,
                            mesh_shape: dict):
    """Seq-sharded MLA flash decode (latent cache split over `axes`).

    q_lat (B,1,H,kvl), q_rope (B,1,H,rd) replicated over axes; the latent
    caches (B,T_loc,kvl/rd) hold this shard's sequence slice. The write
    position lands on exactly one shard (masked DUS); attention merges
    across shards with the LSE reduction, with the *latent* c_kv acting as
    the value — W_uv is applied after the merge (EXPERIMENTS.md §Perf,
    minicpm3 decode hillclimb)."""
    b, one, h, kvl = q_lat.shape
    t_loc = ckv_cache.shape[1]
    idx = _multi_axis_index(axes, mesh_shape)
    lo = idx * t_loc
    lp = pos - lo
    in_r = (lp >= 0) & (lp < t_loc)
    lp_c = jnp.clip(lp, 0, t_loc - 1)
    ckv_upd = jax.lax.dynamic_update_slice(
        ckv_cache, ckv_new.astype(ckv_cache.dtype), (0, lp_c, 0))
    ckv_c = jnp.where(in_r, ckv_upd, ckv_cache)
    kr_upd = jax.lax.dynamic_update_slice(
        kr_cache, kr_new.astype(kr_cache.dtype), (0, lp_c, 0))
    kr_c = jnp.where(in_r, kr_upd, kr_cache)

    dt = q_lat.dtype
    scores = (jnp.einsum("bshk,btk->bhst", q_lat, ckv_c.astype(dt)) +
              jnp.einsum("bshr,btr->bhst", q_rope, kr_c.astype(dt)))
    scores = scores.astype(jnp.float32) * scale
    tpos = lo + jnp.arange(t_loc)
    scores = jnp.where(tpos < pos + 1, scores, jnp.float32(-1e30))
    m = jnp.max(scores, axis=-1, keepdims=True)        # (B,H,1,1)
    e = jnp.exp(scores - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bhst,btk->bshk", e.astype(dt), ckv_c.astype(dt))
    big_m = jax.lax.pmax(m, axes)
    corr = jnp.exp(m - big_m)                          # (B,H,1,1)
    l_g = jax.lax.psum(l * corr, axes)
    o_g = jax.lax.psum(o * corr.transpose(0, 2, 1, 3).astype(dt), axes)
    ctx_lat = o_g / l_g.transpose(0, 2, 1, 3).astype(dt)
    return ctx_lat, ckv_c, kr_c


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    return {"c_kv": jnp.zeros((batch, max_len, cfg.mla_kv_lora), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.mla_rope_dim), dtype)}


def mla_cache_specs(cfg: ModelConfig, rules: ShardingRules, batch: int):
    b, seq = rules.decode_layout(batch, cfg.mla_seq_shard)
    return {"c_kv": P(b, seq, None), "k_rope": P(b, seq, None)}
