"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

Per the assignment, the modality frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d) — the conv1d stack is
not modeled. Encoder: bidirectional self-attention blocks with sinusoidal
positions. Decoder: causal self-attention + cross-attention + GELU MLP,
learned positions, tied unembedding.

Serving: ``prefill`` runs the encoder once and materializes per-layer
cross-attention K/V caches; ``decode`` steps update only the self cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as NN
from repro.models.common import ModelConfig, ShardingRules, stack_layer_specs

AUX0 = {"moe_aux": jnp.float32(0), "moe_dropped": jnp.float32(0)}


def _sinusoid(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def init_enc_block(key, cfg: ModelConfig, rules: ShardingRules):
    ks = jax.random.split(key, 2)
    attn_p, attn_s = NN.init_attention(ks[0], cfg, rules)
    mlp_p, mlp_s = NN.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg, rules,
                               kind="gelu")
    p = {"ln1": NN.init_norm(cfg.d_model, cfg.param_dtype), "attn": attn_p,
         "ln2": NN.init_norm(cfg.d_model, cfg.param_dtype), "mlp": mlp_p}
    s = {"ln1": rules.vec(), "attn": attn_s, "ln2": rules.vec(), "mlp": mlp_s}
    return p, s


def init_dec_block(key, cfg: ModelConfig, rules: ShardingRules):
    ks = jax.random.split(key, 3)
    self_p, self_s = NN.init_attention(ks[0], cfg, rules)
    cross_p, cross_s = NN.init_attention(ks[1], cfg, rules)
    mlp_p, mlp_s = NN.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg, rules,
                               kind="gelu")
    p = {"ln1": NN.init_norm(cfg.d_model, cfg.param_dtype), "self": self_p,
         "ln2": NN.init_norm(cfg.d_model, cfg.param_dtype), "cross": cross_p,
         "ln3": NN.init_norm(cfg.d_model, cfg.param_dtype), "mlp": mlp_p}
    s = {"ln1": rules.vec(), "self": self_s, "ln2": rules.vec(),
         "cross": cross_s, "ln3": rules.vec(), "mlp": mlp_s}
    return p, s


def init_encdec(key, cfg: ModelConfig, rules: ShardingRules, max_dec_pos: int):
    ks = jax.random.split(key, 5)
    embed_p, embed_s = NN.init_embed(ks[0], cfg, rules)
    ekeys = jax.random.split(ks[1], cfg.encoder_layers)
    ep = jax.vmap(lambda k: init_enc_block(k, cfg, rules)[0])(ekeys)
    _, es = init_enc_block(ks[1], cfg, rules)
    dkeys = jax.random.split(ks[2], cfg.num_layers)
    dp = jax.vmap(lambda k: init_dec_block(k, cfg, rules)[0])(dkeys)
    _, ds = init_dec_block(ks[2], cfg, rules)
    params = {
        "embed": embed_p,
        "dec_pos": NN._dense(ks[3], (max_dec_pos, cfg.d_model),
                             cfg.param_dtype, scale=0.02),
        "enc_layers": ep, "dec_layers": dp,
        "enc_norm": NN.init_norm(cfg.d_model, cfg.param_dtype),
        "dec_norm": NN.init_norm(cfg.d_model, cfg.param_dtype),
    }
    specs = {
        "embed": embed_s, "dec_pos": P(None, None),
        "enc_layers": stack_layer_specs(es, cfg.encoder_layers),
        "dec_layers": stack_layer_specs(ds, cfg.num_layers),
        "enc_norm": rules.vec(), "dec_norm": rules.vec(),
    }
    return params, specs


def encode(params, cfg: ModelConfig, embeds: jax.Array, mesh=None):
    """embeds (B, S_enc, d) frame embeddings (frontend stub output)."""
    x = embeds.astype(cfg.dtype) + _sinusoid(
        embeds.shape[1], cfg.d_model).astype(cfg.dtype)[None]

    def body(carry, pl):
        h = NN.layer_norm(carry, pl["ln1"], None, cfg.norm_eps)
        a, _ = NN.attention_fwd(pl["attn"], h, cfg, mode="bidir", mesh=mesh)
        x = carry + a
        h = NN.layer_norm(x, pl["ln2"], None, cfg.norm_eps)
        return x + NN.mlp_fwd(pl["mlp"], h), None

    from repro.models.transformer import _remat
    body = _remat(body, cfg)
    if not cfg.scan_layers:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda v: v[i], params["enc_layers"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return NN.layer_norm(x, params["enc_norm"], None, cfg.norm_eps)


def _dec_block(pl, x, cfg: ModelConfig, *, mode, self_cache, cross_kv, pos, mesh=None):
    h = NN.layer_norm(x, pl["ln1"], None, cfg.norm_eps)
    a, n_self = NN.attention_fwd(
        pl["self"], h, cfg, mode=mode, cache=self_cache, pos=pos, mesh=mesh)
    x = x + a
    h = NN.layer_norm(x, pl["ln2"], None, cfg.norm_eps)
    c, _ = NN.attention_fwd(pl["cross"], h, cfg, mode="cross_decode",
                            cache=cross_kv, mesh=mesh)
    x = x + c
    h = NN.layer_norm(x, pl["ln3"], None, cfg.norm_eps)
    return x + NN.mlp_fwd(pl["mlp"], h), n_self


def build_cross_caches(params, cfg: ModelConfig, enc_out: jax.Array):
    """Per-decoder-layer cross K/V from the encoder output (stacked L)."""
    def body(_, pl):
        dt = enc_out.dtype
        kv = cfg.num_kv_heads
        k = jnp.einsum("bsd,dh->bsh", enc_out, pl["cross"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dh->bsh", enc_out, pl["cross"]["wv"].astype(dt))
        b, s = enc_out.shape[:2]
        return None, {"k": k.reshape(b, s, kv, cfg.hd),
                      "v": v.reshape(b, s, kv, cfg.hd)}

    if not cfg.scan_layers:
        outs = [body(None, jax.tree.map(lambda v: v[i], params["dec_layers"]))[1]
                for i in range(cfg.num_layers)]
        return jax.tree.map(lambda *v: jnp.stack(v, 0), *outs)
    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv


def decode_forward(params, cfg: ModelConfig, tokens: jax.Array, *, mode,
                   cross_caches, self_caches=None, pos=None, mesh=None):
    """Decoder pass. mode 'causal' (teacher forcing) or 'decode' (1 token)."""
    b, s = tokens.shape
    x = NN.embed_fwd(params["embed"], tokens, cfg)
    start = pos if mode == "decode" else 0
    pidx = jnp.arange(s) + (start if start is not None else 0)
    x = x + params["dec_pos"].astype(cfg.dtype)[pidx][None]

    def body(carry, xs):
        pl, cc, sc = xs
        y, n_self = _dec_block(pl, carry, cfg, mode=mode, self_cache=sc,
                               cross_kv=cc, pos=pos, mesh=mesh)
        return y, n_self

    from repro.models.transformer import _remat
    body = _remat(body, cfg)
    if not cfg.scan_layers:
        at = lambda t, i: jax.tree.map(lambda v: v[i], t)
        news = []
        for i in range(cfg.num_layers):
            sc = at(self_caches, i) if self_caches is not None else None
            x, ns = body(x, (at(params["dec_layers"], i),
                             at(cross_caches, i), sc))
            news.append(ns)
        new_self = None
        if self_caches is not None:
            new_self = jax.tree.map(lambda *v: jnp.stack(v, 0), *news)
    elif self_caches is None:
        x, _ = jax.lax.scan(
            lambda c, xs: body(c, (xs[0], xs[1], None)), x,
            (params["dec_layers"], cross_caches))
        new_self = None
    else:
        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cross_caches, self_caches))
    x = NN.layer_norm(x, params["dec_norm"], None, cfg.norm_eps)
    logits = NN.unembed_fwd(params["embed"], x, cfg)  # tied
    return logits, new_self


def encdec_forward(params, cfg: ModelConfig, rules, mesh, *, tokens,
                   embeds, mode="causal", cache=None, pos=None):
    """Unified entry. Train: embeds (B,S_enc,d) + tokens (B,S_dec).

    Decode: cache = {'self': stacked self KV, 'cross': stacked cross KV,
    'enc_done': ()} — encoder is NOT re-run (cross caches already built).
    """
    if mode == "decode":
        logits, new_self = decode_forward(
            params, cfg, tokens, mode="decode", cross_caches=cache["cross"],
            self_caches=cache["self"], pos=pos, mesh=mesh)
        return logits, {"self": new_self, "cross": cache["cross"]}, dict(AUX0)
    enc = encode(params, cfg, embeds, mesh=mesh)
    cross = build_cross_caches(params, cfg, enc)
    if cache is not None:  # prefill: write self/cross caches
        logits, new_self = decode_forward(
            params, cfg, tokens, mode="causal", cross_caches=cross,
            self_caches=cache["self"], pos=None, mesh=mesh)
        return logits, {"self": new_self, "cross": cross}, dict(AUX0)
    logits, _ = decode_forward(params, cfg, tokens, mode="causal",
                               cross_caches=cross, self_caches=None,
                               pos=None, mesh=mesh)
    return logits, None, dict(AUX0)


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int):
    one = NN.init_attn_cache(cfg, batch, max_len)
    self_c = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.num_layers,) + v.shape), one)
    cross_one = NN.init_attn_cache(cfg, batch, enc_len)
    cross = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.num_layers,) + v.shape),
        cross_one)
    return {"self": self_c, "cross": cross}


def encdec_cache_specs(cfg: ModelConfig, rules: ShardingRules, batch: int):
    one = NN.attn_cache_specs(cfg, rules, batch)
    lift = lambda t: jax.tree.map(lambda sp: P(None, *sp), t,
                                  is_leaf=lambda v: isinstance(v, P))
    return {"self": lift(one), "cross": lift(one)}
