"""Mixture-of-Experts layer — expert dispatch IS the paper's shuffle.

The paper's single network operator is hash-partition + AllToAll
(``repro.core.repartition``). MoE token routing is the same operator with
the router's top-k argmax playing the role of the hash: tokens are packed
into equal-capacity per-expert buckets (``pack_by_partition`` — the exact
code path the relational shuffle uses) and exchanged with one
``jax.lax.all_to_all`` over the MODEL axis (expert parallelism), processed,
and shuffled back. This substantiates the paper's "data processing as a
function, everywhere" thesis *inside* the training step (DESIGN.md §2).

Three execution paths:
* ``ep_shuffle`` (default on meshes with model>1): shard_map + explicit
  all_to_all as above. Deterministic collective schedule; the roofline's
  collective term for MoE cells comes from here.
* ``ep_psum`` (decode / S==1): every shard computes its local experts for
  all tokens and contributions are psum-merged — no shuffle for tiny S.
* local (1-device / tests): same packing, no collective.

Capacity semantics mirror the relational shuffle: per-expert buckets are
static; overflow tokens are *dropped and counted* (standard MoE capacity
drop == Cylon's surfaced bucket overflow).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.repartition import pack_by_partition, staged_all_to_all
from repro.core.stats import pick_stages
from repro.models.common import (
    DATA_AXIS, MODEL_AXIS, ModelConfig, ShardingRules)
from repro.models.layers import _dense
from repro.utils import axis_size, ceil_div, round_up, shard_map


def padded_experts(cfg: ModelConfig, model_size: int) -> int:
    """Experts padded up so the EP axis divides them (qwen2: 60 -> 64)."""
    return round_up(cfg.moe_num_experts, max(model_size, 1))


def init_moe(key, cfg: ModelConfig, rules: ShardingRules):
    d, ff = cfg.d_model, cfg.moe_d_ff
    e_pad = padded_experts(cfg, rules.model)
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, cfg.moe_num_experts), jnp.float32),
        "wi": _dense(ks[1], (e_pad, d, ff), cfg.param_dtype),
        "wg": _dense(ks[2], (e_pad, d, ff), cfg.param_dtype),
        "wo": _dense(ks[3], (e_pad, ff, d), cfg.param_dtype),
    }
    s = {
        "router": P(None, None),
        "wi": rules.expert_col(e_pad, d, ff),
        "wg": rules.expert_col(e_pad, d, ff),
        "wo": rules.expert_row(e_pad, ff, d),
    }
    if cfg.moe_num_shared:
        sh_ff = cfg.moe_num_shared * ff
        p["shared"] = {"wi": _dense(ks[4], (d, sh_ff), cfg.param_dtype),
                       "wg": _dense(jax.random.fold_in(ks[4], 1), (d, sh_ff),
                                    cfg.param_dtype),
                       "wo": _dense(jax.random.fold_in(ks[4], 2), (sh_ff, d),
                                    cfg.param_dtype)}
        s["shared"] = {"wi": rules.col(d, sh_ff), "wg": rules.col(d, sh_ff),
                       "wo": rules.row(sh_ff, d)}
    return p, s


def _route(router_w, xt, cfg: ModelConfig):
    """Token routing: top-k experts + combine weights + load-balance loss."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.moe_top_k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * sum_e f_e * p_e
    e = cfg.moe_num_experts
    frac_tokens = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / topi.size)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return topi, topw, aux


def _expert_ffn(wi, wg, wo, toks):
    """(E_loc, C, d) tokens through per-expert SwiGLU."""
    dt = toks.dtype
    h = jnp.einsum("ecd,edf->ecf", toks, wi.astype(dt))
    g = jnp.einsum("ecd,edf->ecf", toks, wg.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo.astype(dt))


def _bucket_capacity(tokens: int, e_pad: int, cfg: ModelConfig) -> int:
    c = ceil_div(int(tokens * cfg.moe_top_k * cfg.moe_capacity_factor), e_pad)
    return max(8, round_up(c, 8))


def _dispatch_compute_combine(p, xt, cfg: ModelConfig, e_pad: int,
                              axis: str | None):
    """Shared body: pack -> (all_to_all) -> expert FFN -> (all_to_all) -> unpack.

    xt: (T, d) local tokens. With `axis`, expert weights are sharded over it
    (E_loc = e_pad / M local experts) and buckets ride one all_to_all each way.
    """
    t, d = xt.shape
    topi, topw, aux = _route(p["router"], xt, cfg)
    k = cfg.moe_top_k
    flat_e = topi.reshape(t * k).astype(jnp.int32)
    cap = _bucket_capacity(t, e_pad, cfg)
    send_idx, hist = pack_by_partition(flat_e, e_pad, cap)  # (E, cap)
    tok_idx = send_idx // k  # row in xt for each slot
    sel = (send_idx >= 0)[..., None]
    buf = jnp.where(sel, xt[jnp.clip(tok_idx, 0, t - 1)], 0)  # (E, cap, d)

    if axis is not None:
        m = axis_size(axis)
        e_loc = e_pad // m
        # (E, cap, d) -> (M, E_loc*cap, d) -> exchange -> (E_loc, M*cap, d)
        sendb = buf.reshape(m, e_loc * cap, d)
        # expert dispatch rides the relational shuffle's staged primitive:
        # same cost-sized pipeline depth, same bit-identity contract
        stages = cfg.moe_shuffle_stages
        if stages is None:
            stages = pick_stages(
                m * m * e_loc * cap * d * sendb.dtype.itemsize, e_loc * cap)
        recv = staged_all_to_all(sendb, axis, stages=stages,
                                 shuffle_mode=cfg.moe_shuffle_mode)
        recv = recv.reshape(m, e_loc, cap, d).transpose(1, 0, 2, 3) \
            .reshape(e_loc, m * cap, d)
        out = _expert_ffn(p["wi"], p["wg"], p["wo"], recv)
        back = out.reshape(e_loc, m, cap, d).transpose(1, 0, 2, 3) \
            .reshape(m, e_loc * cap, d)
        back = staged_all_to_all(back, axis, stages=stages,
                                 shuffle_mode=cfg.moe_shuffle_mode)
        back = back.reshape(e_pad, cap, d)
    else:
        back = _expert_ffn(p["wi"], p["wg"], p["wo"], buf)

    # scatter processed slots to flat (t*k) entries; overflow slots dropped
    flat_dest = jnp.where(send_idx >= 0, send_idx, t * k).reshape(-1)
    out_flat = jnp.zeros((t * k, d), xt.dtype).at[flat_dest].set(
        back.reshape(e_pad * cap, d), mode="drop")
    y = jnp.sum(out_flat.reshape(t, k, d) * topw[..., None].astype(xt.dtype), 1)
    dropped = jnp.sum(jnp.maximum(hist - cap, 0))
    return y, {"moe_aux": aux, "moe_dropped": dropped.astype(jnp.float32)}


def _shuffle_body(p, x, *, cfg: ModelConfig, e_pad: int):
    """shard_map body over MODEL axis: x (B, S_loc, d) seq-sharded."""
    b, s_loc, d = x.shape
    y, aux = _dispatch_compute_combine(
        p, x.reshape(b * s_loc, d), cfg, e_pad, MODEL_AXIS)
    # aux values are per-shard partials -> mean over the axis
    aux = {k: jax.lax.pmean(v, MODEL_AXIS) for k, v in aux.items()}
    return y.reshape(b, s_loc, d), aux


def _psum_body(p_local, x, *, cfg: ModelConfig, e_pad: int, e_loc: int):
    """Decode path: each shard computes only its local experts, psum-merged.

    x (B, S, d) replicated over MODEL; p_local expert weights are the local
    (E_loc, ...) slice; router weight replicated.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    topi, topw, aux = _route(p_local["router"], xt, cfg)
    k = cfg.moe_top_k
    shard = jax.lax.axis_index(MODEL_AXIS)
    lo = shard * e_loc
    flat_e = topi.reshape(t * k).astype(jnp.int32) - lo
    flat_e = jnp.where((flat_e >= 0) & (flat_e < e_loc), flat_e, -1)
    cap = max(8, round_up(ceil_div(t * k, 1), 8))  # no drops in decode
    send_idx, hist = pack_by_partition(flat_e, e_loc, cap)
    tok_idx = send_idx // k
    sel = (send_idx >= 0)[..., None]
    buf = jnp.where(sel, xt[jnp.clip(tok_idx, 0, t - 1)], 0)
    out = _expert_ffn(p_local["wi"], p_local["wg"], p_local["wo"], buf)
    flat_dest = jnp.where(send_idx >= 0, send_idx, t * k).reshape(-1)
    out_flat = jnp.zeros((t * k, d), xt.dtype).at[flat_dest].set(
        out.reshape(e_loc * cap, d), mode="drop")
    y = jnp.sum(out_flat.reshape(t, k, d) * topw[..., None].astype(xt.dtype), 1)
    y = jax.lax.psum(y, MODEL_AXIS)
    aux = {"moe_aux": aux, "moe_dropped": jnp.float32(0)}
    return y.reshape(b, s, d), aux


def moe_fwd(p, x: jax.Array, cfg: ModelConfig, rules: ShardingRules,
            mesh=None):
    """MoE layer forward. x (B, S, d). Returns (y, aux dict of scalars)."""
    b, s, d = x.shape
    m = mesh.shape.get(MODEL_AXIS, 1) if mesh is not None else 1
    e_pad = padded_experts(cfg, m)
    routed_p = {k: p[k] for k in ("router", "wi", "wg", "wo")}

    if mesh is None or m == 1 or not cfg.ep_shuffle \
            or cfg.layout == "fsdp":
        y, aux = _dispatch_compute_combine(
            routed_p, x.reshape(b * s, d), cfg, e_pad, None)
        y = y.reshape(b, s, d)
    elif s % m == 0 and s >= m:
        batch = rules.batch_axes()
        espec = {"router": P(None, None), "wi": P(MODEL_AXIS, None, None),
                 "wg": P(MODEL_AXIS, None, None),
                 "wo": P(MODEL_AXIS, None, None)}
        y, aux = shard_map(
            partial(_shuffle_body, cfg=cfg, e_pad=e_pad), mesh=mesh,
            in_specs=(espec, P(batch, MODEL_AXIS, None)),
            out_specs=(P(batch, MODEL_AXIS, None), P()),
        )(routed_p, x)
    else:  # decode (S == 1): psum over local-expert contributions
        batch = rules.batch_axes()
        espec = {"router": P(None, None), "wi": P(MODEL_AXIS, None, None),
                 "wg": P(MODEL_AXIS, None, None),
                 "wo": P(MODEL_AXIS, None, None)}
        e_loc = e_pad // m
        y, aux = shard_map(
            partial(_psum_body, cfg=cfg, e_pad=e_pad, e_loc=e_loc), mesh=mesh,
            in_specs=(espec, P(batch, None, None)),
            out_specs=(P(batch, None, None), P()),
        )(routed_p, x)

    if cfg.moe_num_shared:
        from repro.models.layers import mlp_fwd
        y = y + mlp_fwd(p["shared"], x)
    return y, aux
