"""Zamba2-style hybrid: Mamba2 (SSD) backbone + a shared attention block.

Layout (zamba2-1.2b: 38 mamba layers, ``attn_every=6``): after every 6th
mamba layer the **shared** transformer block (one set of weights, fresh
activations/KV per invocation) runs — 6 invocations + 2 trailing mamba
layers. The model scans over *periods* (6 stacked mamba + 1 shared-attn
call) so compile size stays O(1) in depth while keeping the heterogeneous
pattern exact (DESIGN.md §5 extrapolates rooflines per period).

Simplification vs. the released checkpoint (DESIGN.md §Arch-applicability):
Zamba2 concatenates the original embeddings onto the shared-block input and
applies per-invocation LoRA; here the shared block is a standard GQA+MLP
block on the hidden state. Structure, state sizes and FLOP shape per
invocation match.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as NN
from repro.models.common import ModelConfig, ShardingRules, stack_layer_specs
from repro.models.recurrent import (
    causal_depthwise_conv, chunked_gla, gla_decode_step)
from repro.models.transformer import AUX_ZERO, _remat


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------


def _mamba_dims(cfg: ModelConfig):
    d_in = cfg.d_inner                       # expand * d_model
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    p = d_in // h                            # value head dim
    conv_ch = d_in + 2 * n                   # x, B, C go through the conv
    d_proj = 2 * d_in + 2 * n + h            # z, x, B, C, dt
    return d_in, n, h, p, conv_ch, d_proj


def init_mamba_block(key, cfg: ModelConfig, rules: ShardingRules):
    d = cfg.d_model
    d_in, n, h, pdim, conv_ch, d_proj = _mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "ln": NN.init_norm(d, cfg.param_dtype),
        "in_proj": NN._dense(ks[0], (d, d_proj), cfg.param_dtype),
        "conv_w": NN._dense(ks[1], (cfg.ssm_conv, conv_ch), cfg.param_dtype,
                            scale=0.5),
        "A_log": jnp.zeros((h,), cfg.param_dtype),       # A = -exp(A_log)
        "D": jnp.ones((h,), cfg.param_dtype),
        "dt_bias": jnp.full((h,), -1.0, cfg.param_dtype),
        "norm": NN.init_norm(d_in, cfg.param_dtype),
        "out_proj": NN._dense(ks[2], (d_in, d), cfg.param_dtype),
    }
    s = {
        "ln": rules.vec(), "in_proj": rules.col(d, d_proj),
        "conv_w": P(None, None), "A_log": rules.vec(), "D": rules.vec(),
        "dt_bias": rules.vec(), "norm": rules.vec(),
        "out_proj": rules.row(d_in, d),
    }
    return p, s


def _mamba_split(zxbcdt, cfg: ModelConfig):
    d_in, n, h, pdim, conv_ch, _ = _mamba_dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + conv_ch]
    dt = zxbcdt[..., d_in + conv_ch :]
    return z, xbc, dt


def mamba_fwd(p, x: jax.Array, cfg: ModelConfig, *, cache=None, pos=None,
              decode: bool = False):
    """Mamba2 block. cache = {'conv': (B,K-1,CC), 'ssm': (B,H,N,P) fp32}."""
    b, s, d = x.shape
    d_in, n, h, pdim, conv_ch, _ = _mamba_dims(cfg)
    dt_ = x.dtype
    hx = NN.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dk->bsk", hx, p["in_proj"].astype(dt_))
    z, xbc, dtp = _mamba_split(zxbcdt, cfg)

    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = causal_depthwise_conv(xbc, p["conv_w"], conv_cache)
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :d_in]
    bmat = xbc[..., d_in : d_in + n]                 # (B,S,N) shared groups=1
    cmat = xbc[..., d_in + n :]

    dt = jax.nn.softplus(dtp.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))    # (B,S,H)
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt     # <= 0
    v = xin.reshape(b, s, h, pdim)
    k = bmat[:, :, None, :] * dt[..., None].astype(dt_)       # fold Δ into k
    k = jnp.broadcast_to(k, (b, s, h, n)).astype(dt_)
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, h, n)).astype(dt_)

    if decode:
        assert s == 1
        y, new_ssm = gla_decode_step(
            q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], cache["ssm"])
        y = y[:, None]
    else:
        init = cache["ssm"] if cache is not None else None
        y, new_ssm = chunked_gla(q, k, v, log_a, chunk=min(cfg.ssm_chunk, s),
                                 initial_state=init, unroll=cfg.time_unroll)
    y = y + v * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = NN.rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_ssm}
    return x + out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int):
    d_in, n, h, pdim, conv_ch, _ = _mamba_dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype),
            "ssm": jnp.zeros((batch, h, n, pdim), jnp.float32)}


# ---------------------------------------------------------------------------
# hybrid model
# ---------------------------------------------------------------------------


def _period_counts(cfg: ModelConfig):
    periods = cfg.num_layers // cfg.attn_every
    rem = cfg.num_layers - periods * cfg.attn_every
    return periods, rem


def init_hybrid(key, cfg: ModelConfig, rules: ShardingRules):
    from repro.models.transformer import init_block
    ks = jax.random.split(key, 6)
    embed_p, embed_s = NN.init_embed(ks[0], cfg, rules)
    mkeys = jax.random.split(ks[1], cfg.num_layers)
    mp = jax.vmap(lambda k: init_mamba_block(k, cfg, rules)[0])(mkeys)
    _, ms = init_mamba_block(ks[1], cfg, rules)
    shared_p, shared_s = init_block(ks[2], cfg, rules)
    params = {"embed": embed_p, "mamba": mp, "shared": shared_p,
              "final_norm": NN.init_norm(cfg.d_model, cfg.param_dtype),
              "lm_head": NN._dense(ks[3], (cfg.padded_vocab, cfg.d_model),
                                   cfg.param_dtype)}
    specs = {"embed": embed_s,
             "mamba": stack_layer_specs(ms, cfg.num_layers),
             "shared": shared_s, "final_norm": rules.vec(),
             "lm_head": rules.embed(cfg.padded_vocab, cfg.d_model)}
    return params, specs


def hybrid_forward(params, cfg: ModelConfig, rules: ShardingRules, mesh, *,
                   tokens, embeds=None, mode="causal", cache=None, pos=None):
    """Period-scanned hybrid forward. Returns (logits, new_cache, aux)."""
    assert embeds is None
    x = NN.embed_fwd(params["embed"], tokens, cfg)
    b, s = x.shape[:2]
    periods, rem = _period_counts(cfg)
    per = cfg.attn_every
    decode = mode == "decode"

    positions = jnp.arange(s) + (pos if decode else 0)
    rope = NN.rope_tables(positions, cfg.hd, cfg.rope_theta)

    # split stacked mamba params into (periods, per, ...) + remainder
    mp = params["mamba"]
    mp_main = jax.tree.map(lambda v: v[: periods * per].reshape(
        (periods, per) + v.shape[1:]), mp)
    mp_rem = jax.tree.map(lambda v: v[periods * per :], mp)

    c_main = c_rem = c_attn = None
    if cache is not None:
        c_main = jax.tree.map(lambda v: v[: periods * per].reshape(
            (periods, per) + v.shape[1:]), cache["mamba"])
        c_rem = jax.tree.map(lambda v: v[periods * per :], cache["mamba"])
        c_attn = cache["attn"]  # stacked (periods, ...)

    from repro.models.transformer import _block_fwd

    def mamba_step(carry, xs):
        pl, cl = xs
        y, ncl = mamba_fwd(pl, carry, cfg, cache=cl, pos=pos, decode=decode)
        return y, ncl

    def period_body(carry, xs):
        pmb, cmb, cat = xs
        if cache is None:
            y, _ = jax.lax.scan(lambda c, pl: mamba_step(c, (pl, None)),
                                carry, pmb)
            ncm = None
        else:
            y, ncm = jax.lax.scan(mamba_step, carry, (pmb, cmb))
        y, ncat, aux = _block_fwd(params["shared"], y, cfg, rules, mesh,
                                  rope=rope, mode=mode, cache=cat, pos=pos)
        return y, (ncm, ncat, aux)

    body = _remat(period_body, cfg)
    at = lambda t, i: jax.tree.map(lambda v: v[i], t)

    if not cfg.scan_layers:  # unrolled (roofline depth-pair lowerings)
        aux = dict(AUX_ZERO)
        ncms, ncats = [], []
        for i in range(periods):
            cmb = at(c_main, i) if cache is not None else None
            cat = at(c_attn, i) if cache is not None else None
            yncm = []
            for j in range(per):
                x, ncl = mamba_fwd(at(at(mp_main, i), j), x, cfg, cache=(
                    at(cmb, j) if cmb is not None else None), pos=pos,
                    decode=decode)
                yncm.append(ncl)
            x, ncat, a = _block_fwd(params["shared"], x, cfg, rules, mesh,
                                    rope=rope, mode=mode, cache=cat, pos=pos)
            aux = {k: aux[k] + a[k] for k in aux}
            if cache is not None:
                ncms.extend(yncm)
                ncats.append(ncat)
        for j in range(rem):
            cl = at(c_rem, j) if cache is not None else None
            x, ncl = mamba_fwd(at(mp_rem, j), x, cfg, cache=cl, pos=pos,
                               decode=decode)
            if cache is not None:
                ncms.append(ncl)
        ncache = None
        if cache is not None:
            ncache = {
                "mamba": jax.tree.map(lambda *v: jnp.stack(v, 0), *ncms)
                if ncms else jax.tree.map(lambda v: v[:0], cache["mamba"]),
                "attn": jax.tree.map(lambda *v: jnp.stack(v, 0), *ncats)
                if ncats else c_attn,
            }
    elif cache is None:
        if periods:
            x, (_, _, auxs) = jax.lax.scan(
                lambda c, xs: body(c, (xs[0], None, None)), x, (mp_main,))
            aux = jax.tree.map(jnp.sum, auxs)
        else:
            aux = dict(AUX_ZERO)
        ncache = None
        if rem:
            x, _ = jax.lax.scan(lambda c, pl: mamba_step(c, (pl, None)),
                                x, mp_rem)
    else:
        if periods:
            x, (ncm, ncat, auxs) = jax.lax.scan(
                body, x, (mp_main, c_main, c_attn))
            aux = jax.tree.map(jnp.sum, auxs)
            ncm = jax.tree.map(
                lambda v: v.reshape((periods * per,) + v.shape[2:]), ncm)
        else:
            aux = dict(AUX_ZERO)
            ncm, ncat = jax.tree.map(lambda v: v[:0], c_rem), c_attn
        if rem:
            x, ncr = jax.lax.scan(mamba_step, x, (mp_rem, c_rem))
            ncm = jax.tree.map(lambda a, r: jnp.concatenate([a, r], 0), ncm, ncr)
        ncache = {"mamba": ncm, "attn": ncat}
    x = NN.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = NN.unembed_fwd({"table": params["lm_head"]}, x, cfg)
    return logits, (ncache if cache is not None else None), aux


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int):
    periods, _ = _period_counts(cfg)
    mamba_one = init_mamba_cache(cfg, batch)
    mamba = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.num_layers,) + v.shape),
        mamba_one)
    attn_one = NN.init_attn_cache(cfg, batch, max_len)
    attn = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (periods,) + v.shape), attn_one)
    return {"mamba": mamba, "attn": attn}


def hybrid_cache_specs(cfg: ModelConfig, rules: ShardingRules, batch: int):
    b, _ = rules.decode_layout(batch, False)
    mamba = {"conv": P(None, b, None, None), "ssm": P(None, b, None, None, None)}
    attn_one = NN.attn_cache_specs(cfg, rules, batch)
    attn = jax.tree.map(lambda sp: P(None, *sp), attn_one,
                        is_leaf=lambda v: isinstance(v, P))
    return {"mamba": mamba, "attn": attn}
