"""AdamW with global-norm clipping + warmup-cosine schedule, sharding-aware.

Optimizer state mirrors the parameter tree (m, v have the same partition
specs as the params — FSDP shards optimizer state for free), fp32
throughout. No optax dependency: the update is ~30 lines and owning it lets
the checkpoint/elastic layer treat state as a plain pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    master: Any  # fp32 master params (mixed precision; ZeRO data-sharded)
    m: Any
    v: Any
    count: jax.Array


def init_opt(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return OptState(master=master, m=z, v=jax.tree.map(jnp.copy, z),
                    count=jnp.zeros((), jnp.int32))


def opt_state_specs(master_specs) -> OptState:
    from jax.sharding import PartitionSpec as P
    return OptState(master=master_specs, m=master_specs, v=master_specs,
                    count=P())


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One AdamW step on the fp32 masters; bf16 params re-cast from them.

    The masters/m/v are ZeRO-sharded (extra data-axis sharding) so the
    update is local; the cast back to the compute params' sharding is the
    once-per-step bf16 all-gather. Returns (new_params, new_state, metrics).
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v, mst):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * mst
        mst = mst - lr * step
        return mst.astype(p.dtype), m, v, mst

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mst = treedef.flatten_up_to(state.master)
    out = [upd(p, g, m, v, mst) for p, g, m, v, mst in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mst)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_mst = treedef.unflatten([o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_mst, new_m, new_v, count), metrics
