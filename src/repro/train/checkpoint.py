"""Sharded, atomic-commit, elastic checkpoints.

Production contract (DESIGN.md §6):
* **Atomic commit** — state is written into ``<dir>/tmp.<step>`` and
  renamed to ``<dir>/step_<n>`` only after every leaf + manifest is
  fsync'd; a crash mid-save never corrupts the latest checkpoint.
* **Elastic restore** — leaves are stored as full logical arrays plus the
  PartitionSpec they were trained under; ``restore`` re-device_puts onto
  *any* mesh (different shape/device count), so a job can resume on a
  degraded or grown slice. (On a real multi-host pod each host writes its
  local shards + a JSON index; this container is single-process so full
  arrays stand in — the commit protocol and re-shard path are identical.)
* **Async save** — a snapshot is taken on-device (cheap) and serialized on
  a background thread so the train loop is not blocked.
* **Retention** — keep the last N checkpoints; deletion only after a newer
  commit succeeds.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                        for p in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Atomically write `state` (a pytree) as checkpoint `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # snapshot to host — do this on the caller thread so the state captured
    # is the state at call time even if saving is async
    host = jax.tree.map(lambda x: np.asarray(x), state)

    def _write():
        tmp = os.path.join(ckpt_dir, f"tmp.{step}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(host)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            fn = f"{i:05d}_{name[:80]}.npy"
            arr = np.asarray(leaf)
            logical = str(arr.dtype)
            if arr.dtype.kind == "V" or logical == "bfloat16":
                # numpy can't persist ml_dtypes (bf16 etc.): store raw bits
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"file": fn, "shape": list(np.shape(leaf)),
                 "dtype": logical})
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # the atomic commit point
        _retain(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, MANIFEST)):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *, mesh=None, specs=None
            ) -> Any:
    """Load checkpoint `step` into the structure of `like`.

    With (mesh, specs) the leaves are device_put with NamedSharding —
    the **elastic** path: the target mesh may differ from the one the
    checkpoint was written under.
    """
    from jax.sharding import NamedSharding

    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    flat, treedef = jax.tree.flatten(like)
    assert len(flat) == len(leaves_meta), \
        f"tree mismatch: {len(flat)} leaves vs {len(leaves_meta)} in ckpt"

    def _load(m):
        arr = np.load(os.path.join(d, m["file"]))
        if m["dtype"] not in (str(arr.dtype),):
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, m["dtype"], m["dtype"])))
        return arr

    arrays = [_load(m) for m in leaves_meta]
    if mesh is not None and specs is not None:
        flat_specs = treedef.flatten_up_to(specs)
        arrays = [jax.device_put(a, NamedSharding(mesh, s))
                  for a, s in zip(arrays, flat_specs)]
    else:
        arrays = [jax.device_put(a) for a in arrays]
    return treedef.unflatten(arrays)


class CheckpointManager:
    """save-every-N + auto-resume + async writes, for the train loop."""

    def __init__(self, ckpt_dir: str, *, every: int = 50, keep: int = 3,
                 async_save: bool = True):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, state) -> bool:
        if step % self.every:
            return False
        self.wait()
        self._pending = save(self.dir, step, state, keep=self.keep,
                             blocking=not self.async_save)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def resume(self, like, *, mesh=None, specs=None):
        """(state, step) from the newest checkpoint, or (None, 0)."""
        step = latest_step(self.dir)
        if step is None:
            return None, 0
        return restore(self.dir, step, like, mesh=mesh, specs=specs), step
