"""Sharded, atomic-commit, elastic checkpoints.

Production contract (DESIGN.md §6):
* **Atomic commit** — state is written into ``<dir>/tmp.<step>`` and
  renamed to ``<dir>/step_<n>`` only after every leaf + manifest is
  fsync'd; a crash mid-save never corrupts the latest checkpoint.
* **Elastic restore** — leaves are stored as full logical arrays plus the
  PartitionSpec they were trained under; ``restore`` re-device_puts onto
  *any* mesh (different shape/device count), so a job can resume on a
  degraded or grown slice. (On a real multi-host pod each host writes its
  local shards + a JSON index; this container is single-process so full
  arrays stand in — the commit protocol and re-shard path are identical.)
* **Async save** — a snapshot is taken on-device (cheap) and serialized on
  a background thread so the train loop is not blocked.
* **Retention** — keep the last N checkpoints; deletion only after a newer
  commit succeeds.
* **Corruption detection + fallback** — every leaf's byte length and
  crc32 go into the manifest at save time; ``restore`` verifies them and
  raises :class:`CheckpointCorruptError` on any truncated / bit-flipped /
  missing leaf, and :meth:`CheckpointManager.resume` falls back to the
  newest checkpoint that DOES verify (loud ``warnings.warn``, never a
  silent load of garbage weights).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
import zlib
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed its integrity check (truncated or corrupt)."""


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                        for p in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Atomically write `state` (a pytree) as checkpoint `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # snapshot to host — do this on the caller thread so the state captured
    # is the state at call time even if saving is async
    host = jax.tree.map(lambda x: np.asarray(x), state)

    def _write():
        tmp = os.path.join(ckpt_dir, f"tmp.{step}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(host)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            fn = f"{i:05d}_{name[:80]}.npy"
            arr = np.asarray(leaf)
            logical = str(arr.dtype)
            if arr.dtype.kind == "V" or logical == "bfloat16":
                # numpy can't persist ml_dtypes (bf16 etc.): store raw bits
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            np.save(os.path.join(tmp, fn), arr)
            raw = np.ascontiguousarray(arr)
            manifest["leaves"].append(
                {"file": fn, "shape": list(np.shape(leaf)),
                 "dtype": logical, "nbytes": int(raw.nbytes),
                 "crc32": zlib.crc32(raw.tobytes()) & 0xFFFFFFFF})
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # the atomic commit point
        _retain(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _readable_manifest(path: str) -> bool:
    """True when the manifest parses — a half-written / truncated JSON
    (crash outside the atomic-rename window, disk fault) marks the whole
    step unreadable rather than exploding later in ``restore``."""
    try:
        with open(path) as f:
            json.load(f)
        return True
    except (OSError, ValueError):
        return False


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and _readable_manifest(
                os.path.join(ckpt_dir, d, MANIFEST)):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *, mesh=None, specs=None
            ) -> Any:
    """Load checkpoint `step` into the structure of `like`.

    With (mesh, specs) the leaves are device_put with NamedSharding —
    the **elastic** path: the target mesh may differ from the one the
    checkpoint was written under.
    """
    from jax.sharding import NamedSharding

    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"step {step}: unreadable manifest ({e})") from e
    leaves_meta = manifest["leaves"]
    flat, treedef = jax.tree.flatten(like)
    assert len(flat) == len(leaves_meta), \
        f"tree mismatch: {len(flat)} leaves vs {len(leaves_meta)} in ckpt"

    def _load(m):
        try:
            arr = np.load(os.path.join(d, m["file"]))
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"step {step}: leaf {m['file']} unreadable ({e})") from e
        # length + crc verification against the manifest written at save
        # time; manifests from before digests existed verify trivially
        if "nbytes" in m:
            raw = np.ascontiguousarray(arr)
            if int(raw.nbytes) != int(m["nbytes"]):
                raise CheckpointCorruptError(
                    f"step {step}: leaf {m['file']} truncated "
                    f"({raw.nbytes} bytes, manifest says {m['nbytes']})")
            crc = zlib.crc32(raw.tobytes()) & 0xFFFFFFFF
            if crc != int(m["crc32"]):
                raise CheckpointCorruptError(
                    f"step {step}: leaf {m['file']} fails crc32 "
                    f"({crc:#x} != {int(m['crc32']):#x})")
        if m["dtype"] not in (str(arr.dtype),):
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, m["dtype"], m["dtype"])))
        return arr

    arrays = [_load(m) for m in leaves_meta]
    if mesh is not None and specs is not None:
        flat_specs = treedef.flatten_up_to(specs)
        arrays = [jax.device_put(a, NamedSharding(mesh, s))
                  for a, s in zip(arrays, flat_specs)]
    else:
        arrays = [jax.device_put(a) for a in arrays]
    return treedef.unflatten(arrays)


class CheckpointManager:
    """save-every-N + auto-resume + async writes, for the train loop."""

    def __init__(self, ckpt_dir: str, *, every: int = 50, keep: int = 3,
                 async_save: bool = True):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, state) -> bool:
        if step % self.every:
            return False
        self.wait()
        self._pending = save(self.dir, step, state, keep=self.keep,
                             blocking=not self.async_save)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def resume(self, like, *, mesh=None, specs=None):
        """(state, step) from the newest checkpoint that VERIFIES, or
        (None, 0). A truncated/corrupt newest checkpoint (e.g. the disk
        died mid-retention, bit rot) is skipped with a loud warning and
        the next-newest retained step is tried — resuming slightly older
        beats crashing, and far beats loading garbage weights."""
        bad = []
        for step in reversed(list_steps(self.dir)):
            try:
                state = restore(self.dir, step, like, mesh=mesh, specs=specs)
            except CheckpointCorruptError as e:
                bad.append(step)
                warnings.warn(
                    f"checkpoint step {step} is corrupt, trying an older "
                    f"one: {e}", RuntimeWarning, stacklevel=2)
                continue
            if bad:
                warnings.warn(
                    f"resumed from step {step}; corrupt step(s) "
                    f"{sorted(bad)} were skipped", RuntimeWarning,
                    stacklevel=2)
            return state, step
        return None, 0
