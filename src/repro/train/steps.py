"""Step functions: train (microbatched, optionally pod-compressed grads),
prefill, decode — plus the sharding specs to jit them with.

Compute/comm overlap: gradient accumulation is a ``lax.scan`` over
microbatches, so XLA can overlap microbatch k+1's compute with the
reduce-scatter/all-gather traffic of microbatch k's backward (and the
single post-scan DP all-reduce hides behind the optimizer). Microbatch
slicing is *interleaved* (batch row r belongs to microbatch r mod K) so the
slice is shard-local — no relayout collective (DESIGN.md §6).

Gradient compression (``compress_pod=True``): on multi-pod meshes the
grads crossing the DCN (pod axis) are int8-quantized with per-leaf scales
and **error feedback**: each pod keeps the quantization residual and adds
it to the next step's gradient, so the bias vanishes over steps. Wire
format is an all-gather of (int8 tensor, fp32 scale) over ``pod`` + local
mean — 4x fewer DCN bytes than an fp32 ring all-reduce.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import DATA_AXIS, MODEL_AXIS, POD_AXIS
from repro.models.factory import Model
from repro.train.optimizer import OptConfig, OptState, apply_updates, init_opt, opt_state_specs


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jax.Array
    ef: Any  # error-feedback residuals (int8 pod compression) or None


def init_train_state(model: Model, key, *, compress_pod: bool = False,
                     n_pods: int = 1) -> TrainState:
    params = model.init(key)
    ef = None
    if compress_pod:
        ef = jax.tree.map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
    return TrainState(params=params, opt=init_opt(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def master_specs(model: Model):
    """ZeRO specs for fp32 optimizer state + grad accumulator: param specs
    with one extra DATA_AXIS dim sharded (common.fsdp_extend)."""
    from repro.models.common import fsdp_extend
    data = model.rules.data
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return fsdp_extend(model.param_specs, shapes, max(data, 1))


def train_state_specs(model: Model, *, compress_pod: bool = False):
    ps = model.param_specs
    ms = master_specs(model)
    ef = None
    if compress_pod:
        ef = jax.tree.map(lambda s: P(POD_AXIS, *s), ms,
                          is_leaf=lambda x: isinstance(x, P))
    return TrainState(params=ps, opt=opt_state_specs(ms), step=P(), ef=ef)


def batch_specs(model: Model, batch_tree):
    """PartitionSpecs for a batch pytree: batch dim over the DP axes."""
    b = model.rules.batch_axes()
    return jax.tree.map(lambda x: P(b, *([None] * (x.ndim - 1))), batch_tree)


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------


def _microbatch(batch, k: jax.Array, num: int):
    """Interleaved microbatch k of `num` — shard-local slicing (row r of the
    global batch belongs to microbatch r mod num)."""
    def slice_one(x):
        b = x.shape[0]
        xr = x.reshape((b // num, num) + x.shape[1:])
        return jax.lax.dynamic_index_in_dim(xr, k, axis=1, keepdims=False)
    return jax.tree.map(slice_one, batch)


def _accumulate_grads(loss_fn, params, batch, num: int, *, mesh=None,
                      acc_specs=None):
    """Mean loss/grads over `num` microbatches via scan (overlap-friendly).

    The fp32 accumulator is constrained to the ZeRO (master) specs so each
    microbatch's gradients are reduce-scattered over DATA_AXIS instead of
    all-reduced (ZeRO-2); memory is params_fp32 / (model*data)."""
    def constrain(g):
        if mesh is None or acc_specs is None:
            return g
        from repro.utils import safe_constrain
        return jax.tree.map(lambda x, s: safe_constrain(x, mesh, s),
                            g, acc_specs)

    if num == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return constrain(jax.tree.map(
            lambda g: g.astype(jnp.float32), grads)), metrics

    def body(carry, k):
        acc, msum = carry
        mb = _microbatch(batch, k, num)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        acc = constrain(jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), acc, grads))
        msum = jax.tree.map(lambda a, m: a + m.astype(jnp.float32),
                            msum, metrics)
        return (acc, msum), None

    zero_g = constrain(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))
    zero_m = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params,
                            _microbatch(batch, jnp.int32(0), num))
    zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), zero_m)
    (grads, msum), _ = jax.lax.scan(body, (zero_g, zero_m),
                                    jnp.arange(num, dtype=jnp.int32))
    grads = jax.tree.map(lambda g: g / num, grads)
    metrics = jax.tree.map(lambda m: m / num, msum)
    return grads, metrics


# ---------------------------------------------------------------------------
# int8 error-feedback pod compression
# ---------------------------------------------------------------------------


def _quantize(g):
    s = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8)
    return q, s


def _pod_compress(grads, ef):
    """Inside shard_map(manual={'pod'}): per-pod grads -> mean of int8
    all-gathered grads; returns (decompressed mean, new residuals)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quantize(g)
        deq = q.astype(jnp.float32) * s
        new_e = g - deq
        qg = jax.lax.all_gather(q, POD_AXIS)
        sg = jax.lax.all_gather(s, POD_AXIS)
        shp = (-1,) + (1,) * g.ndim
        mean = jnp.mean(qg.astype(jnp.float32) * sg.reshape(shp), axis=0)
        return mean, new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


# ---------------------------------------------------------------------------
# step factories
# ---------------------------------------------------------------------------


def make_train_step(model: Model, ocfg: OptConfig, *, microbatches: int = 1,
                    compress_pod: bool = False):
    """Returns step_fn(state, batch) -> (state, metrics)."""
    loss_fn = model.loss_fn
    acc_specs = master_specs(model) if model.mesh is not None else None

    if not compress_pod:
        def step_fn(state: TrainState, batch):
            grads, metrics = _accumulate_grads(
                loss_fn, state.params, batch, microbatches, mesh=model.mesh,
                acc_specs=acc_specs)
            params, opt, om = apply_updates(state.params, grads, state.opt,
                                            ocfg)
            return TrainState(params, opt, state.step + 1, state.ef), \
                {**metrics, **om}
        return step_fn

    mesh = model.mesh
    assert mesh is not None and POD_AXIS in mesh.axis_names, \
        "compress_pod needs a multi-pod mesh"

    def pod_body(params, ef_local, batch_local):
        ef_local = jax.tree.map(lambda e: e[0], ef_local)  # strip pod dim
        grads, metrics = _accumulate_grads(
            loss_fn, params, batch_local, microbatches)
        grads, new_ef = _pod_compress(grads, ef_local)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, POD_AXIS), metrics)
        new_ef = jax.tree.map(lambda e: e[None], new_ef)
        return grads, new_ef, metrics

    def step_fn(state: TrainState, batch):
        ef_specs = jax.tree.map(lambda e: P(POD_AXIS), state.ef)
        batch_in = jax.tree.map(lambda x: P(POD_AXIS), batch)
        from repro.utils import shard_map as _sm  # compat wrapper
        grads, new_ef, metrics = _sm(
            pod_body, mesh=mesh,
            in_specs=(P(), ef_specs, batch_in),
            out_specs=(P(), ef_specs, P()),
            axis_names={POD_AXIS}, check_rep=False,
        )(state.params, state.ef, batch)
        params, opt, om = apply_updates(state.params, grads, state.opt, ocfg)
        return TrainState(params, opt, state.step + 1, new_ef), \
            {**metrics, **om}

    return step_fn


def make_eval_step(model: Model):
    def eval_fn(params, batch):
        return model.loss_fn(params, batch)[1]
    return eval_fn


def make_prefill_step(model: Model, max_len: int, enc_len: int = 0):
    """(params, batch) -> (last_logits, cache): causal pass writing the cache."""
    def prefill_fn(params, batch):
        b = batch["tokens"].shape[0]
        cache = model.init_cache(b, max_len, enc_len)
        if model.mesh is not None:
            from jax.sharding import NamedSharding
            cache = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(model.mesh, s)),
                cache, model.cache_specs(b))
        logits, cache, _ = model.forward(
            params, tokens=batch["tokens"], embeds=batch.get("embeds"),
            mode="causal", cache=cache, pos=None)
        return logits[:, -1], cache
    return prefill_fn


def make_decode_step(model: Model):
    """(params, cache, tokens (B,1), pos ()) -> (logits (B,V), cache)."""
    def decode_fn(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return logits[:, -1], cache
    return decode_fn
