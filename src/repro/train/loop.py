"""Fault-tolerant training loop: checkpoint/restart + deterministic replay.

Restart contract: state is (params, opt, step) in the checkpoint; the data
pipeline is a pure function of the step index, so a restarted job replays
the exact batch stream from the resume step — training is bitwise
reproducible across failures (tested in tests/test_fault.py, including a
kill mid-run). Straggler mitigation: the host-side Prefetcher decouples
batch assembly from the device step (bounded staleness); on a real pod the
same loop runs per-host with jax.distributed and within-job slice
exclusion is handled by re-initializing on the surviving mesh and taking
the elastic-restore path (checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.data.pipeline import RelationalTokenPipeline
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig
from repro.train.steps import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    microbatches: int = 1
    compress_pod: bool = False
    seed: int = 0


def run(model, pipeline: RelationalTokenPipeline, ocfg: OptConfig,
        lcfg: LoopConfig, *, fail_at_step: int | None = None,
        log: Callable[[str], None] = print, state: TrainState | None = None):
    """Train until lcfg.total_steps (resuming from the latest checkpoint).

    fail_at_step: raise after that step's checkpoint (fault-injection for
    tests). Returns (state, history list of metric dicts).
    """
    step_fn = make_train_step(model, ocfg, microbatches=lcfg.microbatches,
                              compress_pod=lcfg.compress_pod)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    n_pods = model.mesh.shape.get("pod", 1) if model.mesh is not None else 1
    if state is None:
        state = init_train_state(model, jax.random.PRNGKey(lcfg.seed),
                                 compress_pod=lcfg.compress_pod,
                                 n_pods=n_pods)
    start = 0
    manager = None
    if lcfg.ckpt_dir:
        manager = ckpt.CheckpointManager(lcfg.ckpt_dir, every=lcfg.ckpt_every,
                                         keep=lcfg.ckpt_keep)
        restored, start = manager.resume(state)
        if restored is not None:
            state = restored
            log(f"[resume] from step {start}")

    history = []
    t0 = time.perf_counter()
    for step in range(start, lcfg.total_steps):
        batch = pipeline.global_batch(step)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % lcfg.log_every == 0 or step + 1 == lcfg.total_steps:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step + 1
            m["s_per_step"] = (time.perf_counter() - t0) / (step + 1 - start)
            history.append(m)
            log(f"[step {step+1:5d}] loss={m.get('loss', float('nan')):.4f} "
                f"gnorm={m.get('grad_norm', float('nan')):.3f} "
                f"({m['s_per_step']*1e3:.0f} ms/step)")
        if manager is not None:
            manager.maybe_save(step + 1, state)
        if fail_at_step is not None and step + 1 >= fail_at_step:
            if manager is not None:
                manager.wait()
            raise RuntimeError(f"injected failure at step {step+1}")
    if manager is not None:
        manager.wait()
    return state, history
