"""Assigned input shapes × runnability rules + input_specs construction.

Four shapes per architecture (assignment block):
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill_step (inference)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 new token,
                                                KV cache of seq_len)
  long_500k    seq 524288, global_batch 1    -> serve_step; ONLY for
               sub-quadratic archs (ssm/hybrid); full-attention archs skip
               (DESIGN.md §4 skip notes).

``input_specs`` returns ShapeDtypeStructs only (shannon/kernels pattern):
weak-type-correct, shardable, zero allocation — the dry-run contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Whether the (arch, shape) cell runs, with the skip reason if not."""
    cell = SHAPES[shape_name]
    if cell.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, (
            f"{cfg.arch} is pure full-attention ({cfg.family}); long_500k "
            "requires sub-quadratic sequence mixing (assignment skip rule)")
    return True, ""


def runnable_cells(cfg: ModelConfig) -> list[str]:
    return [n for n in SHAPES if runnable(cfg, n)[0]]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: {'tokens', 'weight'[, 'embeds']}.
    decode: {'tokens' (B, 1), 'pos' ()} — the cache is built separately
    (launch/dryrun.py) since it is state, not input.
    """
    cell = SHAPES[shape_name]
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        specs = {"weight": _sds((b,), jnp.float32)}
        if cfg.family == "vlm":
            nf = cfg.num_frontend_tokens
            specs["tokens"] = _sds((b, s - nf), jnp.int32)
            specs["embeds"] = _sds((b, nf, cfg.d_model), jnp.float32)
        elif cfg.family == "audio":
            # encoder gets `s` stub frame embeddings, decoder `s` tokens
            specs["tokens"] = _sds((b, s), jnp.int32)
            specs["embeds"] = _sds((b, s, cfg.d_model), jnp.float32)
        else:
            specs["tokens"] = _sds((b, s), jnp.int32)
        return specs
    # decode: one new token against a cache of length s
    return {"tokens": _sds((b, 1), jnp.int32)}


def cache_shape(cfg: ModelConfig, shape_name: str) -> tuple[int, int]:
    """(batch, max_len) for the decode cache of this cell."""
    cell = SHAPES[shape_name]
    return cell.global_batch, cell.seq_len
