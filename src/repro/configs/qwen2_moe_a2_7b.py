"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=151936.
Experts are padded 60 -> 64 for the 16-way EP axis (dummy experts receive
no routes). Shared-expert width = 4 * 1408 = 5632.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=151936, head_dim=128,
    moe_num_experts=60, moe_top_k=4, moe_num_shared=4, moe_d_ff=1408,
    rope_theta=1000000.0,
)

TINY = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, vocab_size=512, moe_num_experts=8,
                      moe_top_k=2, moe_num_shared=1, moe_d_ff=96)
