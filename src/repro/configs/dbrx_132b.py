"""dbrx-132b [moe]: 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) per-expert d_ff=10752 vocab=100352.
16 experts over a 16-way model axis = exactly one expert per shard.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=0, vocab_size=100352, head_dim=128,
    moe_num_experts=16, moe_top_k=4, moe_num_shared=0, moe_d_ff=10752,
    rope_theta=500000.0, fsdp=True,
)

TINY = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, vocab_size=512, moe_num_experts=4,
                      moe_top_k=2, moe_d_ff=96, fsdp=False)
