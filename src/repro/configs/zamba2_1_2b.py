"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

38 mamba2 layers (d_model=2048, expand=2 -> d_inner=4096, ssm_state=64,
64 value heads of dim 64), shared GQA(32H, kv=32)+MLP(8192) block invoked
every 6 layers. Runs the long_500k cell (sub-quadratic).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    attn_every=6, rope_theta=10000.0,
)

TINY = CONFIG.replace(num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=512, ssm_state=16,
                      attn_every=3, ssm_chunk=8)
