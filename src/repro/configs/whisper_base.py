"""whisper-base [audio]: enc-dec, conv frontend STUB [arXiv:2212.04356].

6L encoder + 6L decoder, d_model=512, 8H (kv=8), d_ff=2048, vocab=51865.
Per the assignment the conv1d frontend is stubbed: input_specs provides
precomputed frame embeddings (B, S, 512). No long_500k (full attention).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    tie_embeddings=True, frontend="audio_stub",
)

TINY = CONFIG.replace(num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512)
