"""internvl2-76b [vlm]: InternViT frontend (STUB) + InternLM2-style backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified]. The vision frontend is a stub per the
assignment: ``input_specs`` supplies precomputed patch embeddings
(B, 256, d_model) which are linearly projected and prepended.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    frontend="vision_stub", num_frontend_tokens=256,
    rope_theta=500000.0, fsdp=True,
)

# reduced same-family config for the CPU smoke test
TINY = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=512,
                      num_frontend_tokens=8, fsdp=False)
