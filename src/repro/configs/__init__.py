"""Architecture registry: the 10 assigned configs + tiny smoke variants."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCH_IDS = [
    "internvl2-76b",
    "llama3-8b",
    "minicpm3-4b",
    "granite-3-2b",
    "stablelm-12b",
    "zamba2-1.2b",
    "whisper-base",
    "qwen2-moe-a2.7b",
    "dbrx-132b",
    "xlstm-1.3b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}

# grad-accumulation microbatch counts for the train_4k cell (per-arch memory
# budget on a 16 GB v5e chip; hillclimbed in EXPERIMENTS.md §Perf)
TRAIN_MICROBATCHES = {
    "internvl2-76b": 16,
    "dbrx-132b": 16,
    "stablelm-12b": 8,
    "llama3-8b": 8,
    "minicpm3-4b": 8,
    "granite-3-2b": 4,
    "zamba2-1.2b": 4,
    "qwen2-moe-a2.7b": 4,
    "xlstm-1.3b": 4,
    "whisper-base": 1,
}


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_tiny(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).TINY


def train_microbatches(arch: str) -> int:
    return TRAIN_MICROBATCHES.get(arch, 1)
