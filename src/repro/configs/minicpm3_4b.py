"""minicpm3-4b [dense]: MLA (multi-head latent attention)
[hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448; MLA dims from the HF
config: q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
qk_rope_head_dim=32, v_head_dim=64. The KV cache stores latents
(256+32 per token) — 10x smaller than GQA at this width. For batch-128
32k decode enable ``mla_seq_shard=True`` (latent cache sequence-sharded
over the model axis, flash-decode LSE merge): 40.4 -> 3.1 GiB/dev
(EXPERIMENTS.md §Perf cell 2). Kept off here so the dry-run table shows
the paper-faithful baseline.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    attn_kind="mla", mla_q_lora=768, mla_kv_lora=256,
    mla_rope_dim=32, mla_nope_dim=64, mla_v_dim=64,
    rope_theta=10000.0,
)

TINY = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      d_ff=128, vocab_size=512, mla_q_lora=32, mla_kv_lora=16,
                      mla_rope_dim=8, mla_nope_dim=16, mla_v_dim=16)
