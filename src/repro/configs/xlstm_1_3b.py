"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48 blocks, d_model=2048, 4 heads (mLSTM head dim 1024), one sLSTM per 8
blocks (the paper's 7:1 ratio), vocab=50304, d_ff=0 (projections live
inside the blocks; sLSTM blocks carry a PF-4/3 gated FFN). Runs long_500k
(recurrent, O(1)/token decode).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=8, ssm_conv=4, ssm_chunk=256,
)

TINY = CONFIG.replace(num_layers=6, d_model=64, num_heads=4, num_kv_heads=4,
                      vocab_size=512, slstm_every=3, ssm_chunk=8)
