"""granite-3-2b [dense]: GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=49155, head_dim=64,
    tie_embeddings=True, rope_theta=10000.0,
)

TINY = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=512)
