"""The network operator: hash-partition + AllToAll shuffle (Cylon §II-B/C, Fig. 3).

This is the paper's single network primitive ("Initially we have implemented
the All to All network operator which is widely required when implementing
the distributed counterparts of the local operators"). Every distributed
relational operator — and, in this framework, MoE expert dispatch — is
``local prep -> repartition -> local op``.

MPI ``AllToAllv`` (variable counts) has no dense-collective equivalent on a
TPU mesh, so we adapt: each shard packs rows into ``num_partitions`` equal
``bucket_capacity`` send slots (grouped with a stable sort — dense, vectorized)
and exchanges them with ``jax.lax.all_to_all``. Skew beyond
``bucket_capacity`` is *counted and surfaced* (``overflow``) rather than
silently dropped being undetectable — the production recourse is re-running
with a bigger capacity, mirroring Cylon's memory-budget failure mode.

The exchange itself is **staged** (:func:`staged_all_to_all`): the
``(p, bucket_capacity)`` send buckets split into ``S`` chunks along the
capacity axis, one collective per chunk, so XLA's scheduler can overlap
chunk i+1's gather/pack and chunk i-1's unpack with chunk i's wire time
inside the one fused shard_map program. Chunks are written back into the
same ``(p, bucket)`` slots a monolithic exchange fills, so every staging
(and the ``ppermute``-ring strategy, ``shuffle_mode="ring"``) is
bit-identical to ``S=1`` — same recv buffers, same overflow counts, same
row order after ``compact``. The per-bucket send counts ride *inside* the
first chunk of the first 4-byte column (bitcast into a prepended capacity
slot), folding the old separate ``recv_counts`` collective into the data
exchange — one fewer collective per shuffle.

Runs inside ``shard_map`` (BSP lockstep = SPMD).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as FLT
from repro.core.table import Table
from repro.core.ops_local import compact
from repro.kernels import ops as kops
from repro.utils import axis_size


class ShuffleStats(NamedTuple):
    overflow: jax.Array  # int32 scalar: rows dropped on THIS shard's sends
    received: jax.Array  # int32 scalar: valid rows received


class Partitioning(NamedTuple):
    """Static placement metadata: rows live on shard ``hash(keys) % n``.

    Tagged onto a ``DistTable`` (and tracked through the plan optimizer) so a
    downstream join/groupby on the same key columns, seed, and modulus can
    *elide* its AllToAll entirely — equal keys are already colocated. The
    tag is exact, not advisory: it is only attached to tables produced by a
    hash repartition (or an operator that provably preserves one).
    """

    keys: tuple[str, ...]   # key columns, in the order they were hashed
    num_partitions: int     # the modulus (== mesh axis size when created)
    seed: int               # murmur3 seed of the partitioning hash


@dataclasses.dataclass(frozen=True)
class RangePartitioning:
    """Static placement metadata for range-partitioned tables (sort output).

    Rows live on shard ``f(keys)`` for a *monotone lexicographic* placement
    function f: shard i's key tuples are all <= shard i+1's, and equal key
    tuples are colocated (``dist_sort``'s splitter assignment is a pure
    function of the key tuple). Unlike the hash tag the splitters are
    data-dependent, so the tag does not name them — downstream operators
    that must co-place a second table re-derive the shard boundaries from
    the tagged table itself (per-shard key maxima, an all_gather of p
    scalars, not an AllToAll — see ``ops_dist._range_align_pid``).

    ``fingerprint`` is splitter provenance: two tags compare equal (and a
    join may skip BOTH shuffles) only when they provably came from the same
    splitter computation over the same data. Plan-internal tags use the
    canonical form of the producing subtree; materialized DistTables get a
    fresh unique token so tables from different executions never
    false-match. A deliberate dataclass (not NamedTuple): tuple equality
    would let a RangePartitioning compare equal to a hash ``Partitioning``
    with coincident fields.
    """

    keys: tuple[str, ...]   # key columns, lexicographic significance order
    num_partitions: int     # number of range buckets (== mesh axis size)
    fingerprint: object     # hashable provenance token, or None (unknown)


_FINGERPRINTS = itertools.count()


def fresh_range_fingerprint() -> tuple:
    """Unique provenance token for a materialized range-partitioned table."""
    return ("table", next(_FINGERPRINTS))


def range_prefix_matches(part, keys: tuple[str, ...]) -> bool:
    """True when ``part`` is a RangePartitioning whose key columns are a
    prefix of ``keys`` — the placement is then a function of a prefix of
    the operator's keys, so equal operator-key tuples are colocated."""
    return (isinstance(part, RangePartitioning)
            and len(part.keys) <= len(keys)
            and part.keys == tuple(keys[:len(part.keys)]))


def zero_shuffle_stats() -> ShuffleStats:
    """Stats for an elided shuffle: nothing sent, nothing dropped."""
    return ShuffleStats(overflow=jnp.zeros((), jnp.int32),
                        received=jnp.zeros((), jnp.int32))


def pack_by_partition(part_id: jax.Array, num_partitions: int,
                      bucket_capacity: int):
    """Group rows into equal-capacity per-partition send slots.

    part_id: (n,) int32 destination in [0, num_partitions); -1 = skip.
    Returns (send_idx (num_partitions, bucket_capacity) int32 with -1 for
    empty slots, hist (num_partitions,) int32 true per-partition counts).

    This is the shared dense-packing primitive behind BOTH the relational
    shuffle (`repartition`) and MoE expert dispatch (`models/moe.py`) —
    the paper's AllToAll network operator reused for token routing
    (DESIGN.md §2, level-2).
    """
    (n,) = part_id.shape
    if n == 0:
        # clip(off + j, 0, n - 1) has an invalid upper bound at n == 0 and
        # order is empty — nothing to pack, every slot is vacant
        return (jnp.full((num_partitions, bucket_capacity), -1, jnp.int32),
                jnp.zeros((num_partitions,), jnp.int32))
    pid_sort = jnp.where(part_id >= 0, part_id, num_partitions)
    order = jnp.argsort(pid_sort, stable=True)
    hist = kops.bucket_histogram(part_id, num_partitions)
    off = jnp.cumsum(hist) - hist
    j = jnp.arange(bucket_capacity)[None, :]
    src = jnp.clip(off[:, None] + j, 0, n - 1)
    ok = j < hist[:, None]
    return jnp.where(ok, order[src], -1), hist


def _chunk_bounds(width: int, stages: int) -> list[tuple[int, int]]:
    """Split ``[0, width)`` into ~``stages`` contiguous chunks.

    Clamps: ``stages <= 1`` (or ``width <= 1``) is one chunk, ``stages >
    width`` degrades to one slot per chunk, and a non-divisible width puts
    the remainder in the last chunk. Empty list when ``width == 0``.
    """
    if width <= 0:
        return []
    from repro.utils import ceil_div

    step = ceil_div(width, max(1, min(int(stages), width)))
    return [(lo, min(lo + step, width)) for lo in range(0, width, step)]


def _ring_exchange(buf: jax.Array, axis_name: str) -> jax.Array:
    """AllToAll via a ``ppermute`` ring: p-1 point-to-point steps.

    Step k sends this shard's bucket for destination ``(i + k) % p`` along
    the static permutation ``s -> (s + k) % p``; the receiver stores it at
    recv slot ``(i - k) % p`` — element-for-element the placement
    ``jax.lax.all_to_all(split=0, concat=0)`` produces (k = 0 is the local
    bucket, no collective). A comparison strategy for the staged dense
    collective: maximally decomposed, so `stages` does not subdivide it.
    """
    p = axis_size(axis_name)
    if p == 1:
        return buf
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros_like(buf)
    for k in range(p):
        send_slot = jax.lax.rem(idx + k, p)
        chunk = jax.lax.dynamic_index_in_dim(buf, send_slot, axis=0,
                                             keepdims=True)
        if k:
            chunk = jax.lax.ppermute(
                chunk, axis_name, [(s, (s + k) % p) for s in range(p)])
        recv_slot = jax.lax.rem(idx - k + p, p)
        out = jax.lax.dynamic_update_index_in_dim(out, chunk, recv_slot,
                                                  axis=0)
    return out


def staged_all_to_all(buf: jax.Array, axis_name: str, *, stages: int = 1,
                      shuffle_mode: str = "alltoall") -> jax.Array:
    """Exchange ``(p, width, *rest)`` send buckets, optionally pipelined.

    ``stages > 1`` splits the width (capacity) axis into that many chunks
    and issues one ``all_to_all`` per chunk; each chunk lands in the same
    ``(source, slot)`` position the monolithic collective fills, so the
    result is bit-identical for every staging while XLA overlaps one
    chunk's wire time with its neighbours' pack/unpack compute.
    ``shuffle_mode="ring"`` swaps in :func:`_ring_exchange` (p-1 ppermute
    steps) — also bit-identical, also already decomposed, so ``stages`` is
    ignored there.
    """
    if shuffle_mode == "ring":
        return _ring_exchange(buf, axis_name)
    if shuffle_mode != "alltoall":
        raise ValueError(f"unknown shuffle_mode: {shuffle_mode!r}")
    bounds = _chunk_bounds(buf.shape[1], stages)
    if len(bounds) <= 1:
        return jax.lax.all_to_all(buf, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)
    return jnp.concatenate(
        [jax.lax.all_to_all(buf[:, lo:hi], axis_name, split_axis=0,
                            concat_axis=0, tiled=True) for lo, hi in bounds],
        axis=1)


def _poison_chunk(recv: jax.Array, width: int) -> jax.Array:
    """Overwrite the first ``width`` received capacity slots with the NaN
    bit pattern — the ``shuffle.chunk`` garble/drop fault. Floats become
    NaN (caught by the finalize NaN scan); a 4-byte carrier's bitcast
    counts decode to an absurd row count (caught by the received-rows
    invariant). Either way validation quarantines the run."""
    if jnp.issubdtype(recv.dtype, jnp.floating):
        bad = jnp.asarray(jnp.nan, recv.dtype)
    elif recv.dtype.itemsize == 4:
        # the float32 quiet-NaN bit pattern, so bitcast counts explode
        bad = jnp.asarray(np.float32(np.nan).view(np.int32), recv.dtype)
    else:
        bad = jnp.asarray(jnp.iinfo(recv.dtype).max, recv.dtype)
    return recv.at[:, :width].set(bad)


def _shuffle_fault(bucket_capacity: int, stages: int,
                   shuffle_mode: str) -> FLT.FaultPlan | None:
    """Consult the ``shuffle.chunk`` site for one exchange. Only a
    pipelined exchange (staged chunks or the ppermute ring) is eligible —
    the fault models pipelining bugs, so the monolithic-AllToAll recovery
    rung provably avoids it. Raise-mode aborts the trace here; garble
    mode returns the plan for :func:`repartition` to poison a received
    chunk with."""
    staged = (shuffle_mode == "ring"
              or len(_chunk_bounds(bucket_capacity, stages)) > 1)
    if not staged:
        return None
    fp = FLT.check("shuffle.chunk")
    if fp is not None and fp.effective_mode == "raise":
        raise FLT.FaultError("shuffle.chunk",
                             f"stages={stages} mode={shuffle_mode}")
    return fp


def _counts_carrier(table: Table) -> str | None:
    """The column whose exchange carries the per-bucket send counts: the
    first (sorted) 4-byte column — the int32 counts bitcast losslessly into
    its dtype and ride a prepended capacity slot of its FIRST chunk, so no
    separate counts collective is needed. None when no column qualifies
    (the separate-collective fallback)."""
    for name in table.column_names:
        if table.columns[name].dtype.itemsize == 4:
            return name
    return None


def repartition(
    table: Table,
    part_id: jax.Array,
    *,
    axis_name: str,
    bucket_capacity: int,
    stages: int = 1,
    shuffle_mode: str = "alltoall",
) -> tuple[Table, ShuffleStats]:
    """Send each valid row to the shard named by ``part_id`` (int32, -1=invalid).

    Returns the received table (capacity = num_shards * bucket_capacity,
    valid rows front-compacted) and shuffle stats. ``stages`` pipelines the
    exchange (see :func:`staged_all_to_all`); every ``(stages,
    shuffle_mode)`` is bit-identical — same recv layout, same overflow
    accounting, same compacted row order.
    """
    p = axis_size(axis_name)
    c = table.capacity
    cb = bucket_capacity
    valid = table.valid_mask()

    # group rows by destination: stable sort on (pid, original order)
    send_idx, hist = pack_by_partition(
        jnp.where(valid, part_id, -1), p, cb)  # (p, cb)
    sent = jnp.minimum(hist, cb).astype(jnp.int32)
    carrier = _counts_carrier(table)
    fault = _shuffle_fault(cb, stages, shuffle_mode)
    # garble the carrier (or the only exchanged column when none): its
    # first received chunk — counts slot included — turns to NaN-pattern
    # bytes, exactly what a lost/corrupt pipeline chunk looks like
    garble_col = carrier if carrier is not None else table.column_names[0]

    recv_cols = {}
    recv_counts = None
    for name, col in table.columns.items():
        rest = col.shape[1:]
        if c == 0:  # empty table: nothing to gather, all slots vacant
            buf = jnp.zeros((p, cb) + rest, col.dtype)
        else:
            buf = col[jnp.clip(send_idx, 0, c - 1)]  # (p, cb, *rest)
            sel = send_idx.reshape(send_idx.shape + (1,) * (col.ndim - 1)) >= 0
            buf = jnp.where(sel, buf, jnp.zeros_like(buf))
        if name == carrier:
            # counts fold: bitcast the (p,) int32 sent counts into this
            # column's dtype and PREPEND them as capacity slot 0, so they
            # ride the first chunk of the staged exchange; the collective
            # moves bytes verbatim, so the round trip is lossless
            cnt = jax.lax.bitcast_convert_type(sent, col.dtype)
            if rest:
                meta = jnp.zeros((p, int(np.prod(rest))), col.dtype)
                meta = meta.at[:, 0].set(cnt).reshape((p, 1) + rest)
            else:
                meta = cnt[:, None]
            buf = jnp.concatenate([meta, buf], axis=1)  # (p, cb+1, *rest)
        recv = staged_all_to_all(buf, axis_name, stages=stages,
                                 shuffle_mode=shuffle_mode)
        if fault is not None and name == garble_col:
            bounds = _chunk_bounds(buf.shape[1], stages)
            width = bounds[0][1] if shuffle_mode != "ring" else buf.shape[1]
            recv = _poison_chunk(recv, width)
        if name == carrier:
            meta_r = recv[:, 0]
            if rest:
                meta_r = meta_r.reshape(p, -1)[:, 0]
            recv_counts = jax.lax.bitcast_convert_type(meta_r, jnp.int32)
            recv = recv[:, 1:]
        recv_cols[name] = recv.reshape((p * cb,) + rest)

    if recv_counts is None:  # no 4-byte column: separate counts collective
        recv_counts = staged_all_to_all(
            sent.reshape(p, 1), axis_name,
            shuffle_mode=shuffle_mode).reshape(p)

    recv_valid = (jnp.arange(cb)[None, :] < recv_counts[:, None]).reshape(p * cb)
    out = compact(Table(recv_cols, jnp.asarray(p * cb, jnp.int32)), recv_valid)
    stats = ShuffleStats(
        overflow=jnp.sum(jnp.maximum(hist - cb, 0)).astype(jnp.int32),
        received=jnp.sum(recv_counts).astype(jnp.int32),
    )
    return out, stats


def default_bucket_capacity(capacity: int, num_shards: int,
                            slack: float | None = None) -> int:
    """Per-destination slot budget: even split x slack for skew.

    ``slack=None`` uses :data:`repro.core.stats.FALLBACK_SLACK` — the one
    documented no-statistics constant. The plan optimizer replaces this
    sizing entirely when table statistics are available (see
    ``repro.core.stats`` and the cost pass in ``repro.core.plan``).
    """
    from repro.core.stats import FALLBACK_SLACK
    from repro.utils import ceil_div

    if slack is None:
        slack = FALLBACK_SLACK
    return max(1, ceil_div(int(capacity * slack), num_shards))
