"""The network operator: hash-partition + AllToAll shuffle (Cylon §II-B/C, Fig. 3).

This is the paper's single network primitive ("Initially we have implemented
the All to All network operator which is widely required when implementing
the distributed counterparts of the local operators"). Every distributed
relational operator — and, in this framework, MoE expert dispatch — is
``local prep -> repartition -> local op``.

MPI ``AllToAllv`` (variable counts) has no dense-collective equivalent on a
TPU mesh, so we adapt: each shard packs rows into ``num_partitions`` equal
``bucket_capacity`` send slots (grouped with a stable sort — dense, vectorized)
and runs ``jax.lax.all_to_all`` once for all columns. Skew beyond
``bucket_capacity`` is *counted and surfaced* (``overflow``) rather than
silently dropped being undetectable — the production recourse is re-running
with a bigger capacity, mirroring Cylon's memory-budget failure mode.

Runs inside ``shard_map`` (BSP lockstep = SPMD).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.table import Table
from repro.core.ops_local import compact
from repro.kernels import ops as kops
from repro.utils import axis_size


class ShuffleStats(NamedTuple):
    overflow: jax.Array  # int32 scalar: rows dropped on THIS shard's sends
    received: jax.Array  # int32 scalar: valid rows received


class Partitioning(NamedTuple):
    """Static placement metadata: rows live on shard ``hash(keys) % n``.

    Tagged onto a ``DistTable`` (and tracked through the plan optimizer) so a
    downstream join/groupby on the same key columns, seed, and modulus can
    *elide* its AllToAll entirely — equal keys are already colocated. The
    tag is exact, not advisory: it is only attached to tables produced by a
    hash repartition (or an operator that provably preserves one).
    """

    keys: tuple[str, ...]   # key columns, in the order they were hashed
    num_partitions: int     # the modulus (== mesh axis size when created)
    seed: int               # murmur3 seed of the partitioning hash


@dataclasses.dataclass(frozen=True)
class RangePartitioning:
    """Static placement metadata for range-partitioned tables (sort output).

    Rows live on shard ``f(keys)`` for a *monotone lexicographic* placement
    function f: shard i's key tuples are all <= shard i+1's, and equal key
    tuples are colocated (``dist_sort``'s splitter assignment is a pure
    function of the key tuple). Unlike the hash tag the splitters are
    data-dependent, so the tag does not name them — downstream operators
    that must co-place a second table re-derive the shard boundaries from
    the tagged table itself (per-shard key maxima, an all_gather of p
    scalars, not an AllToAll — see ``ops_dist._range_align_pid``).

    ``fingerprint`` is splitter provenance: two tags compare equal (and a
    join may skip BOTH shuffles) only when they provably came from the same
    splitter computation over the same data. Plan-internal tags use the
    canonical form of the producing subtree; materialized DistTables get a
    fresh unique token so tables from different executions never
    false-match. A deliberate dataclass (not NamedTuple): tuple equality
    would let a RangePartitioning compare equal to a hash ``Partitioning``
    with coincident fields.
    """

    keys: tuple[str, ...]   # key columns, lexicographic significance order
    num_partitions: int     # number of range buckets (== mesh axis size)
    fingerprint: object     # hashable provenance token, or None (unknown)


_FINGERPRINTS = itertools.count()


def fresh_range_fingerprint() -> tuple:
    """Unique provenance token for a materialized range-partitioned table."""
    return ("table", next(_FINGERPRINTS))


def range_prefix_matches(part, keys: tuple[str, ...]) -> bool:
    """True when ``part`` is a RangePartitioning whose key columns are a
    prefix of ``keys`` — the placement is then a function of a prefix of
    the operator's keys, so equal operator-key tuples are colocated."""
    return (isinstance(part, RangePartitioning)
            and len(part.keys) <= len(keys)
            and part.keys == tuple(keys[:len(part.keys)]))


def zero_shuffle_stats() -> ShuffleStats:
    """Stats for an elided shuffle: nothing sent, nothing dropped."""
    return ShuffleStats(overflow=jnp.zeros((), jnp.int32),
                        received=jnp.zeros((), jnp.int32))


def pack_by_partition(part_id: jax.Array, num_partitions: int,
                      bucket_capacity: int):
    """Group rows into equal-capacity per-partition send slots.

    part_id: (n,) int32 destination in [0, num_partitions); -1 = skip.
    Returns (send_idx (num_partitions, bucket_capacity) int32 with -1 for
    empty slots, hist (num_partitions,) int32 true per-partition counts).

    This is the shared dense-packing primitive behind BOTH the relational
    shuffle (`repartition`) and MoE expert dispatch (`models/moe.py`) —
    the paper's AllToAll network operator reused for token routing
    (DESIGN.md §2, level-2).
    """
    (n,) = part_id.shape
    pid_sort = jnp.where(part_id >= 0, part_id, num_partitions)
    order = jnp.argsort(pid_sort, stable=True)
    hist = kops.bucket_histogram(part_id, num_partitions)
    off = jnp.cumsum(hist) - hist
    j = jnp.arange(bucket_capacity)[None, :]
    src = jnp.clip(off[:, None] + j, 0, n - 1)
    ok = j < hist[:, None]
    return jnp.where(ok, order[src], -1), hist


def repartition(
    table: Table,
    part_id: jax.Array,
    *,
    axis_name: str,
    bucket_capacity: int,
) -> tuple[Table, ShuffleStats]:
    """Send each valid row to the shard named by ``part_id`` (int32, -1=invalid).

    Returns the received table (capacity = num_shards * bucket_capacity,
    valid rows front-compacted) and shuffle stats.
    """
    p = axis_size(axis_name)
    c = table.capacity
    cb = bucket_capacity
    valid = table.valid_mask()

    # group rows by destination: stable sort on (pid, original order)
    send_idx, hist = pack_by_partition(
        jnp.where(valid, part_id, -1), p, cb)  # (p, cb)

    recv_cols = {}
    for name, col in table.columns.items():
        buf = col[jnp.clip(send_idx, 0, c - 1)]  # (p, cb, *rest)
        sel = send_idx.reshape(send_idx.shape + (1,) * (col.ndim - 1)) >= 0
        buf = jnp.where(sel, buf, jnp.zeros_like(buf))
        recv = jax.lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=0,
                                  tiled=True)
        recv_cols[name] = recv.reshape((p * cb,) + col.shape[1:])

    sent = jnp.minimum(hist, cb)
    recv_counts = jax.lax.all_to_all(
        sent.reshape(p, 1), axis_name, split_axis=0, concat_axis=0, tiled=True
    ).reshape(p)

    recv_valid = (jnp.arange(cb)[None, :] < recv_counts[:, None]).reshape(p * cb)
    out = compact(Table(recv_cols, jnp.asarray(p * cb, jnp.int32)), recv_valid)
    stats = ShuffleStats(
        overflow=jnp.sum(jnp.maximum(hist - cb, 0)).astype(jnp.int32),
        received=jnp.sum(recv_counts).astype(jnp.int32),
    )
    return out, stats


def default_bucket_capacity(capacity: int, num_shards: int,
                            slack: float | None = None) -> int:
    """Per-destination slot budget: even split x slack for skew.

    ``slack=None`` uses :data:`repro.core.stats.FALLBACK_SLACK` — the one
    documented no-statistics constant. The plan optimizer replaces this
    sizing entirely when table statistics are available (see
    ``repro.core.stats`` and the cost pass in ``repro.core.plan``).
    """
    from repro.core.stats import FALLBACK_SLACK
    from repro.utils import ceil_div

    if slack is None:
        slack = FALLBACK_SLACK
    return max(1, ceil_div(int(capacity * slack), num_shards))
