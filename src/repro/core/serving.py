"""Concurrent-query serving: a session layer over async plan dispatch.

The paper's pitch is a data-engineering layer embedded in live AI workloads
(PyTorch/TF/Jupyter, paper §III) rather than batch pipelines — which means
MANY concurrent clients issuing small relational queries over shared
registered tables, and the metric that matters is per-query p50/p99 latency
and sustained queries/sec under an open loop, not single-query wall time.

:class:`ServingSession` is that layer:

* **registered tables** — named ``DistTable``s shared by every client
  (``register`` / ``frame``), the catalog a SQL front-end will later bind
  to;
* **async submission** — ``submit`` dispatches a ``LazyFrame`` through
  ``DistContext.submit`` and returns the future immediately; the shared
  plan cache means a query shape any client has run before skips
  straight to dispatch (0 recompiles on the warm path);
* **the open loop** — :meth:`run_open_loop` drives N logical clients
  through a mixed-shape workload either ``sequential`` (submit + resolve
  one at a time: every cost-sized query pays its deferred-verification
  sync before the next starts) or ``async`` (a bounded in-flight window
  of futures: dispatch overlaps device execution and verification folds
  into later dispatches), and reports per-query latency percentiles,
  queries/sec, and the plan-cache counter deltas.

Results are bit-identical between the two modes — asserted by
``benchmarks/bench_serving.py`` and the dist-case tests — because a future
is only observable through its verified ``result()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.context import DistContext, DistTable, PlanFuture
from repro.core.frame import LazyFrame
from repro.core.table import Table

# one workload entry: (label, builder); the builder receives the session
# and returns the LazyFrame to execute — keyless lambdas inside it stay
# cache-hot because the plan cache content-keys their code + captures
QueryBuilder = Callable[["ServingSession"], LazyFrame]


@dataclasses.dataclass
class ServingReport:
    """Open-loop measurement: latency distribution + throughput + cache."""

    mode: str                  # "sequential" | "async"
    num_clients: int
    num_queries: int
    elapsed_s: float
    latencies_s: list[float]
    shapes: list[str]          # per-query workload label, submission order
    cache_before: dict
    cache_after: dict
    # (label, repr(error)) per FAILED query, submission order — a failed
    # query resolves exceptionally for its owner but never kills the loop
    errors: list = dataclasses.field(default_factory=list)

    @property
    def qps(self) -> float:
        return self.num_queries / self.elapsed_s if self.elapsed_s > 0 \
            else float("inf")

    def percentile_ms(self, q: float) -> float:
        return float(np.percentile(np.asarray(self.latencies_s), q) * 1e3)

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99)

    @property
    def compiles(self) -> int:
        """Executables compiled DURING the run (cache-miss delta) — 0 on a
        warm cache is the serving gate."""
        return self.cache_after["misses"] - self.cache_before["misses"]

    @property
    def recompiles(self) -> int:
        """Misses on previously-cached-then-evicted keys during the run —
        nonzero means the cache budgets are too small for the working set."""
        return self.cache_after["recompiles"] - self.cache_before["recompiles"]

    @property
    def failed(self) -> int:
        """Queries that resolved exceptionally during the run."""
        return len(self.errors)

    def _delta(self, key: str) -> int:
        # recovery counters appeared after the first report consumers;
        # .get keeps old snapshots (tests, serialized reports) readable
        return int(self.cache_after.get(key, 0)) \
            - int(self.cache_before.get(key, 0))

    @property
    def retries(self) -> int:
        """Recovery-ladder attempts taken during the run: overflow-safe
        recompiles + compile retries + generic retries."""
        return (self._delta("overflow_retries")
                + self._delta("compile_retries")
                + self._delta("generic_retries"))

    @property
    def degraded(self) -> int:
        """Queries that fell back to a degraded execution path (XLA
        oracle kernels and/or monolithic AllToAll shuffles)."""
        return self._delta("degraded_kernel") + self._delta("degraded_shuffle")

    @property
    def quarantines(self) -> int:
        """Results that failed validation and were re-executed degraded."""
        return self._delta("quarantines")

    def to_dict(self) -> dict:
        return {"mode": self.mode, "clients": self.num_clients,
                "queries": self.num_queries,
                "elapsed_s": self.elapsed_s, "qps": self.qps,
                "p50_ms": self.p50_ms, "p99_ms": self.p99_ms,
                "compiles": self.compiles, "recompiles": self.recompiles,
                "failed": self.failed, "retries": self.retries,
                "degraded": self.degraded, "quarantines": self.quarantines,
                "errors": list(self.errors),
                "cache": dict(self.cache_after)}

    def summary(self) -> str:
        recov = ""
        if self.failed or self.retries or self.degraded or self.quarantines:
            recov = (f", {self.failed} failed / {self.retries} retries / "
                     f"{self.degraded} degraded / "
                     f"{self.quarantines} quarantined")
        return (f"[{self.mode}] {self.num_queries} queries / "
                f"{self.num_clients} clients: {self.qps:.1f} q/s, "
                f"p50 {self.p50_ms:.1f}ms, p99 {self.p99_ms:.1f}ms, "
                f"{self.compiles} compiles ({self.recompiles} recompiles)"
                + recov)


class ServingSession:
    """Named shared tables + async dispatch + the open-loop driver.

    Concurrency contract: the N clients of :meth:`run_open_loop` are
    LOGICAL — one driver thread interleaves their submissions (an open
    loop measures queueing/overlap, not thread parallelism). Calling
    :meth:`submit` / ``future.result()`` from real threads is also safe
    for the shared bookkeeping — the plan cache and the context's
    deferred-verification list are internally locked, and a future
    resolves exactly once — but the catalog (:meth:`register`) must be
    populated before concurrent submission starts, and two racing misses
    on one plan shape may both compile it (the second wins; wasted work,
    never a wrong result).
    """

    def __init__(self, ctx: DistContext, *, max_in_flight: int = 32):
        assert max_in_flight >= 1, max_in_flight
        self.ctx = ctx
        self.max_in_flight = max_in_flight
        self._tables: dict[str, DistTable] = {}

    # -- the catalog ---------------------------------------------------------
    def register(self, name: str, table: Table | DistTable, *,
                 analyze: bool = False) -> DistTable:
        """Register ``table`` under ``name`` (scattering a host Table).
        ``analyze=True`` attaches TableStats so every query over it is
        cost-sized — overflow verification rides the deferred path."""
        if isinstance(table, Table):
            table = self.ctx.scatter(table)
        if analyze:
            table = self.ctx.analyze(table)
        self._tables[name] = table
        return table

    def table(self, name: str) -> DistTable:
        return self._tables[name]

    def table_names(self) -> list[str]:
        return sorted(self._tables)

    def frame(self, name: str) -> LazyFrame:
        """A LazyFrame over the registered table — the query entry point."""
        return self.ctx.frame(self._tables[name])

    # -- submission ----------------------------------------------------------
    def submit(self, query: LazyFrame | QueryBuilder) -> PlanFuture:
        """Dispatch one query (a LazyFrame or a builder over this session)
        and return its future immediately."""
        frame = query(self) if callable(query) else query
        return frame.collect_async()

    # -- the open loop -------------------------------------------------------
    def run_open_loop(self, workload: Sequence[tuple[str, QueryBuilder]], *,
                      num_clients: int = 4, queries_per_client: int = 4,
                      mode: str = "async"
                      ) -> tuple[ServingReport, list[DistTable]]:
        """Drive ``num_clients`` logical clients through the mixed-shape
        ``workload`` (round-robin interleaved, so no two consecutive
        submissions share a shape once clients > 1) and measure per-query
        latency (submit -> verified result materialized) and overall
        queries/sec. Returns the report and the per-query results in
        submission order — the bit-identity anchor between modes.
        """
        assert mode in ("sequential", "async"), mode
        assert len(workload) >= 1
        # submission order: clients interleave, each walking the workload
        # from a different offset — the mixed-shape open loop
        queries = []
        for step in range(queries_per_client):
            for client in range(num_clients):
                label, builder = workload[
                    (step + client) % len(workload)]
                queries.append((label, builder))

        before = self.ctx.cache_stats()
        results: list[DistTable | None] = [None] * len(queries)
        latencies: list[float] = [0.0] * len(queries)
        errors: list[tuple[str, str]] = []

        def resolve(i: int, t_submit: float, fut: PlanFuture):
            # a query that exhausted its recovery ladder resolves
            # exceptionally; record it and keep serving — one bad query
            # must never kill the session or the other clients' results
            try:
                out = fut.result()
                jax.block_until_ready(out.columns)
                results[i] = out
            except Exception as e:
                errors.append((queries[i][0], repr(e)))
            latencies[i] = time.perf_counter() - t_submit

        def dispatch(builder) -> PlanFuture:
            # plan-level failures already come back as pre-failed futures
            # (DistContext.submit never raises); this guards the BUILDER
            try:
                return self.submit(builder)
            except Exception as e:
                return PlanFuture.failed(e)

        t0 = time.perf_counter()
        if mode == "sequential":
            for i, (label, builder) in enumerate(queries):
                t = time.perf_counter()
                resolve(i, t, dispatch(builder))
        else:
            in_flight: list[tuple[int, float, PlanFuture]] = []
            for i, (label, builder) in enumerate(queries):
                t = time.perf_counter()
                in_flight.append((i, t, dispatch(builder)))
                if len(in_flight) >= self.max_in_flight:
                    resolve(*in_flight.pop(0))
            for item in in_flight:
                resolve(*item)
        elapsed = time.perf_counter() - t0

        report = ServingReport(
            mode=mode, num_clients=num_clients, num_queries=len(queries),
            elapsed_s=elapsed, latencies_s=latencies,
            shapes=[label for label, _ in queries],
            cache_before=before, cache_after=self.ctx.cache_stats(),
            errors=errors)
        return report, results
