"""Keyed aggregation (GroupBy) — the paper's missing operator family.

Cylon's follow-up ("A Fast, Scalable, Universal Approach For Distributed
Data Aggregations", arXiv:2010.14596) treats keyed aggregation as the
workhorse of distributed data engineering. The local algorithm here is the
sort-based path adapted to the compacted-front Table invariant:

    sort-by-key  ->  segment-boundary detection  ->  segment reductions

Exact multi-column keys throughout (the sort compares real key columns, as
in ops_local's sort path); hashing appears only as the distributed
pre-partitioner (ops_dist.dist_groupby). The segment reductions run on the
Pallas one-hot kernel (kernels/segment_reduce.py) for the hot 1-D shapes
and on XLA scatter-reduce otherwise — identical semantics.

Aggregators: sum / count / min / max / mean / var / first. Every aggregator
decomposes into *algebraic* partials (sum, sumsq, count, min, max, first)
that combine associatively across shards — the paper's two-phase
(partial-aggregate -> AllToAll -> final-combine) strategy falls out of the
same machinery: ``groupby == finalize ∘ partial_groupby`` locally, and
``finalize ∘ combine ∘ shuffle ∘ partial`` distributed.

Output Table: one row per group (compacted to the front, ordered by key),
columns = key columns + ``{col}_{agg}`` result columns.

Window functions (:func:`window`) ride the same sorted-segment machinery
but are ROW-preserving: sort by (keys, order), detect group segments and
value runs, then express every function as a segmented prefix scan
(``kernels/segment_scan.py``) or an in-segment gather. The module exposes
the building blocks (:func:`window_state`, :func:`window_sorted`,
:func:`window_summary`, :func:`window_lead_summary`) separately so
``ops_dist.dist_window`` can run them per shard over a globally sorted
frame and stitch shard boundaries with carried partial state instead of a
shuffle.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import ops_local as L
from repro.core.table import Table
from repro.kernels import ops as kops

AGG_OPS = ("sum", "count", "min", "max", "mean", "var", "first")

# aggregator -> algebraic partials it needs (combine: sums add, min/max
# re-reduce, first takes the earliest partial in global row order)
_DECOMP = {
    "sum": ("sum",),
    "count": ("count",),
    "min": ("min",),
    "max": ("max",),
    "mean": ("sum", "count"),
    "var": ("sum", "sumsq", "count"),
    "first": ("first",),
}
_COMBINE = {"sum": "sum", "sumsq": "sum", "count": "sum",
            "min": "min", "max": "max", "first": "first"}


def normalize_aggs(aggs) -> tuple[tuple[str, str], ...]:
    """Accept {col: op | [ops]} or [(col, op), ...] -> ((col, op), ...)."""
    if isinstance(aggs, dict):
        pairs = []
        for col, ops in aggs.items():
            ops = [ops] if isinstance(ops, str) else list(ops)
            pairs += [(col, op) for op in ops]
    else:
        pairs = [(c, o) for c, o in aggs]
    for col, op in pairs:
        assert op in AGG_OPS, (op, AGG_OPS)
    return tuple(pairs)


def _prim_name(col: str, prim: str) -> str:
    """Internal partial-column name (count is group size, column-free)."""
    return "__count" if prim == "count" else f"__{prim}__{col}"


def _segments(table: Table, keys: Sequence[str]):
    """Sort by keys -> (sorted table, seg_id (cap,) int32 [-1 invalid],
    num_groups, starts (cap,) int32 row index of each group's first row)."""
    if table.capacity == 0:
        table = Table({k: jnp.zeros((1,) + v.shape[1:], v.dtype)
                       for k, v in table.columns.items()}, table.row_count)
    st = L.sort_by(table, list(keys))
    cap = st.capacity
    valid = st.valid_mask()
    differs = jnp.zeros((cap,), bool)
    for k in keys:
        col = st.columns[k]
        differs = differs | (col != jnp.roll(col, 1))
    boundary = valid & (differs | (jnp.arange(cap) == 0))
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    seg = jnp.where(valid, seg, -1)
    num_groups = jnp.sum(boundary).astype(jnp.int32)
    # one boundary row per group: scatter its row index to slot seg[i]
    starts = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(boundary, seg, cap)].set(jnp.arange(cap, dtype=jnp.int32),
                                           mode="drop")
    return st, seg, num_groups, starts


def _first(col: jax.Array, starts: jax.Array, group_valid: jax.Array):
    """Per-group value at the segment start (stable sort => first in input
    order). Works for N-D payload columns."""
    v = col[starts]
    sel = group_valid.reshape((-1,) + (1,) * (col.ndim - 1))
    return jnp.where(sel, v, jnp.zeros_like(v))


def _reduce(col: jax.Array, seg: jax.Array, slots: int, prim: str,
            group_valid: jax.Array, use_kernel):
    """One algebraic partial over a (cap, ...) column -> (slots, ...)."""
    if prim == "sumsq":
        col = col.astype(jnp.float32) ** 2
        prim = "sum"
    out = kops.segment_reduce(col, seg, slots, prim, use_kernel=use_kernel)
    # empty slots hold the op identity (e.g. +inf for min): zero them so
    # rows past row_count stay benign garbage
    sel = group_valid.reshape((-1,) + (1,) * (out.ndim - 1))
    return jnp.where(sel, out, jnp.zeros_like(out))


def _partial_columns(table: Table, keys: Sequence[str], pairs, *,
                     out_capacity: int | None = None, use_kernel=None):
    """Shared phase-1 machinery: per-group key values + algebraic partials.

    Reductions run into ``out_capacity`` slots when given (groups past it
    truncate, mirroring join's explicit memory-budget failure mode) — a
    tight bound both shrinks the output table and keeps the segment count
    within the Pallas kernel's VMEM budget on large inputs.
    """
    st, seg, num_groups, starts = _segments(table, keys)
    cap = st.capacity
    slots = cap if out_capacity is None else min(cap, out_capacity)
    row_count = jnp.minimum(num_groups, slots)
    group_valid = jnp.arange(slots) < row_count
    starts = starts[:slots]

    cols: dict[str, jax.Array] = {}
    for k in keys:
        cols[k] = _first(st.columns[k], starts, group_valid)
    prims = {(c, p) for c, op in pairs for p in _DECOMP[op]}
    for col, prim in sorted(prims, key=lambda cp: _prim_name(*cp)):
        name = _prim_name(col, prim)
        if name in cols:
            continue  # shared count slot
        if prim == "count":
            ones = jnp.where(seg >= 0, 1, 0).astype(jnp.int32)
            cols[name] = _reduce(ones, seg, slots, "sum", group_valid,
                                 use_kernel)
        elif prim == "first":
            cols[name] = _first(st.columns[col], starts, group_valid)
        else:
            cols[name] = _reduce(st.columns[col], seg, slots, prim,
                                 group_valid, use_kernel)
    return Table(cols, row_count)


def _finalize(partial: Table, keys: Sequence[str], pairs) -> Table:
    """Turn algebraic partials into the user-facing aggregate columns."""
    cols = {k: partial.columns[k] for k in keys}
    get = lambda c, p: partial.columns[_prim_name(c, p)]
    for col, op in pairs:
        name = f"{col}_{op}"
        if op in ("sum", "min", "max", "first"):
            cols[name] = get(col, op)
        elif op == "count":
            cols[name] = get(col, "count")
        elif op == "mean":
            s = get(col, "sum").astype(jnp.float32)
            n = jnp.maximum(get(col, "count"), 1).astype(jnp.float32)
            cols[name] = s / n.reshape((-1,) + (1,) * (s.ndim - 1))
        elif op == "var":  # population variance: E[x^2] - E[x]^2, clamped
            s = get(col, "sum").astype(jnp.float32)
            n = jnp.maximum(get(col, "count"), 1).astype(jnp.float32)
            n = n.reshape((-1,) + (1,) * (s.ndim - 1))
            mean = s / n
            cols[name] = jnp.maximum(get(col, "sumsq") / n - mean * mean, 0.0)
    return Table(cols, partial.row_count)


def groupby(table: Table, keys: Sequence[str] | str, aggs, *,
            out_capacity: int | None = None, use_kernel=None) -> Table:
    """Local GroupBy: one output row per distinct key tuple, ordered by key.

    keys: 1-D key column name(s) (exact multi-column comparison).
    aggs: {col: op | [ops]} or [(col, op), ...]; ops in AGG_OPS. N-D payload
    columns support sum/min/max/mean/first (element-wise per row-vector).
    Output columns: keys + ``{col}_{op}``; row_count = number of groups.
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    pairs = normalize_aggs(aggs)
    partial = _partial_columns(table, keys, pairs, out_capacity=out_capacity,
                               use_kernel=use_kernel)
    return _finalize(partial, keys, pairs)


def partial_groupby(table: Table, keys: Sequence[str] | str, aggs, *,
                    out_capacity: int | None = None, use_kernel=None) -> Table:
    """Phase 1 of the two-phase strategy: per-shard algebraic partials.

    Output rows are one per locally-distinct key (<= key cardinality, the
    shuffle-volume win); columns are the mangled partial slots + keys.
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    pairs = normalize_aggs(aggs)
    return _partial_columns(table, keys, pairs, out_capacity=out_capacity,
                            use_kernel=use_kernel)


def combine_groupby(partials: Table, keys: Sequence[str] | str, aggs, *,
                    out_capacity: int | None = None, use_kernel=None) -> Table:
    """Phase 2: merge partial rows that share a key, then finalize.

    ``combine_groupby(partial_groupby(t, ...), ...) == groupby(t, ...)`` —
    and partials arriving from different shards (via repartition) combine
    the same way: sums add, min/max re-reduce, first takes the earliest
    partial in row order (repartition preserves source-shard order).
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    pairs = normalize_aggs(aggs)
    st, seg, num_groups, starts = _segments(partials, keys)
    cap = st.capacity
    slots = cap if out_capacity is None else min(cap, out_capacity)
    row_count = jnp.minimum(num_groups, slots)
    group_valid = jnp.arange(slots) < row_count
    starts = starts[:slots]

    cols = {k: _first(st.columns[k], starts, group_valid) for k in keys}
    for name in st.column_names:
        if not name.startswith("__"):
            continue
        prim = "count" if name == "__count" else name[2:].split("__", 1)[0]
        comb = _COMBINE[prim]
        if comb == "first":
            cols[name] = _first(st.columns[name], starts, group_valid)
        else:
            cols[name] = _reduce(st.columns[name], seg, slots, comb,
                                 group_valid, use_kernel)
    merged = Table(cols, row_count)
    return _finalize(merged, keys, pairs)


# ---------------------------------------------------------------------------
# window functions (row-preserving analytics over sorted segments)
# ---------------------------------------------------------------------------

WINDOW_FUNCS = ("rank", "dense_rank", "row_number", "lag", "lead",
                "cumsum", "cummax", "running_mean")
_NO_COL_FUNCS = ("rank", "dense_rank", "row_number")
_SCAN_COL_FUNCS = ("cumsum", "cummax", "running_mean")


def normalize_funcs(funcs) -> tuple[tuple[str, str | None, int], ...]:
    """Canonicalize a window-function spec to ``((fn, col, offset), ...)``.

    Accepts a single string, or a sequence of: ``"rank"`` (column-free
    funcs), ``("cumsum", "d0")``, ``("lag", "d0")`` (offset defaults to
    1), ``("lag", "d0", 3)``. The canonical tuple is hashable — it is the
    plan-node field and part of the jit-cache key.
    """
    if isinstance(funcs, str):
        funcs = [funcs]
    out = []
    for f in funcs:
        if isinstance(f, str):
            fn, col, off = f, None, 0
        else:
            f = tuple(f)
            fn, col = f[0], f[1]
            off = int(f[2]) if len(f) > 2 else 0
        assert fn in WINDOW_FUNCS, (fn, WINDOW_FUNCS)
        if fn in _NO_COL_FUNCS:
            assert col is None, f"{fn} takes no column (got {col!r})"
        else:
            assert col is not None, f"{fn} needs a column"
        if fn in ("lag", "lead"):
            off = 1 if off == 0 else off
            assert off >= 1, (fn, off)
        else:
            assert off == 0, f"{fn} takes no offset"
        out.append((fn, col, off))
    return tuple(out)


def window_output_name(fn: str, col: str | None, offset: int = 0) -> str:
    """Output column name: ``rank`` / ``{col}_cumsum`` / ``{col}_lag`` /
    ``{col}_lag{k}`` for offsets beyond the default 1."""
    if col is None:
        return fn
    if fn in ("lag", "lead") and offset > 1:
        return f"{col}_{fn}{offset}"
    return f"{col}_{fn}"


def carry_requirements(pairs):
    """Static description of the cross-shard carry a funcs set needs:
    ``(sums, maxs, lag, lead)`` where sums maps internal slot name ->
    (col, 'native'|'f32'), maxs is a column set, lag/lead map col -> the
    largest requested offset (the boundary-buffer depth)."""
    sums: dict[str, tuple[str, str]] = {}
    maxs: set[str] = set()
    lag: dict[str, int] = {}
    lead: dict[str, int] = {}
    for fn, col, off in pairs:
        if fn == "cumsum":
            sums[f"cumsum:{col}"] = (col, "native")
        elif fn == "running_mean":
            sums[f"rmean:{col}"] = (col, "f32")
        elif fn == "cummax":
            maxs.add(col)
        elif fn == "lag":
            lag[col] = max(lag.get(col, 0), off)
        elif fn == "lead":
            lead[col] = max(lead.get(col, 0), off)
    return sums, maxs, lag, lead


def _dtype_min(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


def _tuple_eq(cols_a, cols_b) -> jax.Array:
    """Scalar equality of two same-keyed dicts of scalars (True if empty)."""
    eq = jnp.asarray(True)
    for k in cols_a:
        eq = eq & (cols_a[k] == cols_b[k])
    return eq


def window_state(st: Table, by: Sequence[str], order_by: Sequence[str]):
    """Segment/run geometry of an ALREADY (by + order_by)-sorted table.

    Returns a dict of per-row arrays: ``seg`` (group id, -1 invalid),
    ``starts`` (group start row, scatter-indexed by group id), ``pos``
    (0-based position within group), ``vb`` (True at the first row of
    each (by + order_by) value run), ``num_groups``, and ``end_excl``
    (one past the row's group's last row).
    """
    cap = st.capacity
    valid = st.valid_mask()
    pos0 = jnp.arange(cap) == 0
    differs_by = jnp.zeros((cap,), bool)
    for k in by:
        col = st.columns[k]
        differs_by = differs_by | (col != jnp.roll(col, 1))
    boundary = valid & (differs_by | pos0)
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    seg = jnp.where(valid, seg, -1)
    num_groups = jnp.sum(boundary).astype(jnp.int32)
    starts = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(boundary, seg, cap)].set(jnp.arange(cap, dtype=jnp.int32),
                                           mode="drop")
    differs_run = differs_by
    for k in order_by:
        col = st.columns[k]
        differs_run = differs_run | (col != jnp.roll(col, 1))
    vb = valid & (differs_run | pos0)
    pos = jnp.arange(cap, dtype=jnp.int32) - starts[
        jnp.clip(seg, 0, cap - 1)]
    pos = jnp.where(valid, pos, 0)
    next_start = starts[jnp.clip(seg + 1, 0, cap - 1)]
    end_excl = jnp.where(seg + 1 < num_groups, next_start, st.row_count)
    end_excl = jnp.where(valid, end_excl, 0)
    return {"seg": seg, "starts": starts, "pos": pos, "vb": vb,
            "num_groups": num_groups, "end_excl": end_excl}


def window_sorted(st: Table, state, by: Sequence[str],
                  order_by: Sequence[str], pairs, *, carry=None,
                  lead_carry=None, use_kernel=None) -> dict[str, jax.Array]:
    """Window output columns over a (by + order_by)-sorted table.

    ``carry`` / ``lead_carry`` are the cross-shard boundary states built
    by ``ops_dist`` (None for a purely local frame): ``carry`` folds the
    preceding shards' trailing-group partials into this shard's LEADING
    group, ``lead_carry`` folds the following shards' heading-group
    values into this shard's TRAILING group (lead only). Every function
    is exact under both — the distributed result is bit-identical to the
    single-host computation on integer-valued columns.
    """
    cap = st.capacity
    valid = st.valid_mask()
    seg, pos, vb = state["seg"], state["pos"], state["vb"]
    end_excl, num_groups = state["end_excl"], state["num_groups"]
    arange = jnp.arange(cap, dtype=jnp.int32)
    sums_req, maxs_req, lag_req, lead_req = carry_requirements(pairs)
    fns = {fn for fn, _, _ in pairs}

    rn = pos + 1  # 1-based row number within group
    dr_local = rk = None
    if "dense_rank" in fns or "rank" in fns:
        dr_local = kops.segment_scan(vb.astype(jnp.int32), seg, "sum",
                                     use_kernel=use_kernel)
        dr = dr_local
    if "rank" in fns:
        rk = kops.segment_scan(jnp.where(vb, rn, 0).astype(jnp.int32), seg,
                               "max", use_kernel=use_kernel)
    cs = {}
    for name, (col, kind) in sums_req.items():
        v = st.columns[col]
        v = v.astype(jnp.float32) if kind == "f32" else v
        cs[name] = kops.segment_scan(v, seg, "sum", use_kernel=use_kernel)
    cm = {col: kops.segment_scan(st.columns[col], seg, "max",
                                 use_kernel=use_kernel) for col in maxs_req}
    lg = {}
    ld = {}
    for fn, col, off in pairs:
        if fn == "lag":
            v = st.columns[col][jnp.clip(arange - off, 0, cap - 1)]
            lg[(col, off)] = jnp.where(valid & (pos >= off), v,
                                       jnp.zeros_like(v))
        elif fn == "lead":
            v = st.columns[col][jnp.clip(arange + off, 0, cap - 1)]
            ld[(col, off)] = jnp.where(valid & (arange + off < end_excl), v,
                                       jnp.zeros_like(v))

    if carry is not None:
        first_by = {k: st.columns[k][0] for k in by}
        match = carry["has"] & (st.row_count > 0) \
            & _tuple_eq(first_by, carry["key"])
        m = (seg == 0) & match
        C = carry["count"]
        if "rank" in fns or "dense_rank" in fns:
            first_order = {k: st.columns[k][0] for k in order_by}
            cont = match & _tuple_eq(first_order, carry["last_order"])
        if "rank" in fns:
            # rows continuing the previous shards' trailing VALUE RUN take
            # the run's global rank (C - E + 1); other leading-group rows
            # shift by the carried row count
            run0 = m & (dr_local == 1)
            rk = jnp.where(run0 & cont, C - carry["run_eq"] + 1,
                           jnp.where(m, rk + C, rk))
        if "dense_rank" in fns:
            dr = jnp.where(m, dr + carry["runs"] - cont.astype(jnp.int32),
                           dr)
        rn = jnp.where(m, rn + C, rn)
        for name in cs:
            cs[name] = jnp.where(m, cs[name] + carry["sums"][name], cs[name])
        for col in cm:
            cm[col] = jnp.where(m, jnp.maximum(cm[col], carry["maxs"][col]),
                                cm[col])
        for (col, off), v in lg.items():
            buf = carry["lag"][col]  # (K,): buf[j] = j+1 rows before the cut
            j = off - 1 - pos
            take = m & (pos < off) & (j < C)
            lg[(col, off)] = jnp.where(
                take, buf[jnp.clip(j, 0, buf.shape[0] - 1)], v)

    if lead_carry is not None:
        idx_last = jnp.maximum(st.row_count - 1, 0)
        last_by = {k: st.columns[k][idx_last] for k in by}
        match_l = lead_carry["has"] & (st.row_count > 0) \
            & _tuple_eq(last_by, lead_carry["key"])
        in_last = valid & (seg == num_groups - 1)
        e = end_excl - 1 - arange  # rows after this one within its group
        H = lead_carry["head_count"]
        for (col, off), v in ld.items():
            buf = lead_carry["head"][col]  # (K,): buf[j] = j-th row after cut
            j = off - 1 - e
            take = in_last & match_l & (e < off) & (j < H)
            ld[(col, off)] = jnp.where(
                take, buf[jnp.clip(j, 0, buf.shape[0] - 1)], v)

    out: dict[str, jax.Array] = {}
    for fn, col, off in pairs:
        name = window_output_name(fn, col, off)
        if fn == "row_number":
            out[name] = jnp.where(valid, rn, 0).astype(jnp.int32)
        elif fn == "rank":
            out[name] = jnp.where(valid, rk, 0).astype(jnp.int32)
        elif fn == "dense_rank":
            out[name] = jnp.where(valid, dr, 0).astype(jnp.int32)
        elif fn == "cumsum":
            v = cs[f"cumsum:{col}"]
            out[name] = jnp.where(valid, v, jnp.zeros_like(v))
        elif fn == "cummax":
            v = cm[col]
            out[name] = jnp.where(valid, v, jnp.zeros_like(v))
        elif fn == "running_mean":
            v = cs[f"rmean:{col}"] / jnp.maximum(rn, 1).astype(jnp.float32)
            out[name] = jnp.where(valid, v, 0.0)
        elif fn == "lag":
            out[name] = lg[(col, off)]
        elif fn == "lead":
            out[name] = ld[(col, off)]
    return out


def window_summary(st: Table, state, by: Sequence[str],
                   order_by: Sequence[str], pairs):
    """This shard's TRAILING-group boundary state (for the next shards).

    All scalars / fixed (K,) buffers — the per-shard payload of the
    boundary ``all_gather``: the trailing group's row count, algebraic
    partials (sum/max per carried column), value-run count, trailing-run
    size, the boundary key/order tuples, and the last ``K`` values per
    lag column (K = largest requested offset).
    """
    cap = st.capacity
    rc = st.row_count
    valid = st.valid_mask()
    idx_last = jnp.maximum(rc - 1, 0)
    starts, vb = state["starts"], state["vb"]
    num_groups = state["num_groups"]
    gstart = starts[jnp.clip(num_groups - 1, 0, cap - 1)]
    count = (rc - gstart).astype(jnp.int32)
    tm = (jnp.arange(cap) >= gstart) & valid
    sums_req, maxs_req, lag_req, _ = carry_requirements(pairs)

    eq_last = jnp.ones((cap,), bool)
    for k in order_by:
        col = st.columns[k]
        eq_last = eq_last & (col == col[idx_last])
    summ = {
        "rows": rc,
        "first_by": {k: st.columns[k][0] for k in by},
        "last_by": {k: st.columns[k][idx_last] for k in by},
        "first_order": {k: st.columns[k][0] for k in order_by},
        "last_order": {k: st.columns[k][idx_last] for k in order_by},
        "count": count,
        "runs": jnp.sum(vb & tm).astype(jnp.int32),
        "run_eq": jnp.sum(tm & eq_last).astype(jnp.int32),
        "sums": {}, "maxs": {}, "lag": {},
    }
    for name, (col, kind) in sums_req.items():
        v = st.columns[col]
        v = v.astype(jnp.float32) if kind == "f32" else v
        summ["sums"][name] = jnp.sum(jnp.where(tm, v, jnp.zeros_like(v)))
    for col in maxs_req:
        v = st.columns[col]
        summ["maxs"][col] = jnp.max(jnp.where(tm, v, _dtype_min(v.dtype)))
    for col, k in lag_req.items():
        idxs = rc - 1 - jnp.arange(k, dtype=jnp.int32)
        ok = (idxs >= gstart) & (idxs >= 0)
        v = st.columns[col][jnp.clip(idxs, 0, cap - 1)]
        summ["lag"][col] = jnp.where(ok, v, jnp.zeros_like(v))
    return summ


def window_lead_summary(st: Table, state, by: Sequence[str], pairs):
    """This shard's HEADING-group boundary state (for the previous shards):
    the heading group's row count and its first ``K`` values per lead
    column."""
    cap = st.capacity
    rc = st.row_count
    starts, num_groups = state["starts"], state["num_groups"]
    head = jnp.where(num_groups > 1, starts[jnp.clip(1, 0, cap - 1)], rc)
    head = head.astype(jnp.int32)
    _, _, _, lead_req = carry_requirements(pairs)
    idx_last = jnp.maximum(rc - 1, 0)
    summ = {
        "rows": rc,
        "first_by": {k: st.columns[k][0] for k in by},
        "last_by": {k: st.columns[k][idx_last] for k in by},
        "head_count": head,
        "head": {},
    }
    for col, k in lead_req.items():
        idxs = jnp.arange(k, dtype=jnp.int32)
        v = st.columns[col][jnp.clip(idxs, 0, cap - 1)]
        summ["head"][col] = jnp.where(idxs < head, v, jnp.zeros_like(v))
    return summ


def _window_validate(table: Table, by, order_by, pairs):
    for k in list(by) + list(order_by):
        assert table.columns[k].ndim == 1, f"window key {k!r} must be 1-D"
    for fn, col, off in pairs:
        name = window_output_name(fn, col, off)
        assert name not in table.columns, (
            f"window output {name!r} collides with an input column")
        if col is None:
            continue
        v = table.columns[col]
        assert v.ndim == 1, f"window input {col!r} must be 1-D"
        if fn in _SCAN_COL_FUNCS:
            assert v.dtype in (jnp.float32, jnp.int32), (
                f"{fn} needs f32/i32 input; {col!r} is {v.dtype}")


def window(table: Table, by: Sequence[str] | str, funcs, *,
           order_by: Sequence[str] | str = (), use_kernel=None) -> Table:
    """Window functions over sorted segments — row-preserving analytics.

    ``by``: partition key column(s); ``order_by``: in-group ordering
    column(s); ``funcs``: see :func:`normalize_funcs`. Returns the input
    rows SORTED by (by, order_by) — the canonical frame order — with one
    appended column per requested function (:func:`window_output_name`):

    ``rank``/``dense_rank``/``row_number`` (int32, 1-based; ties on the
    full (by, order_by) tuple share rank), ``lag``/``lead`` (the value
    ``offset`` rows away within the group, 0 outside it — the
    static-shape NULL analog), ``cumsum``/``cummax`` (running aggregate
    in the column dtype), ``running_mean`` (f32).
    """
    by = [by] if isinstance(by, str) else list(by)
    order = [order_by] if isinstance(order_by, str) else list(order_by)
    pairs = normalize_funcs(funcs)
    _window_validate(table, by, order, pairs)
    if table.capacity == 0:
        table = Table({k: jnp.zeros((1,) + v.shape[1:], v.dtype)
                       for k, v in table.columns.items()}, table.row_count)
    st = L.sort_by(table, by + order)
    state = window_state(st, by, order)
    cols = window_sorted(st, state, by, order, pairs, use_kernel=use_kernel)
    return Table({**st.columns, **cols}, st.row_count)
