"""Keyed aggregation (GroupBy) — the paper's missing operator family.

Cylon's follow-up ("A Fast, Scalable, Universal Approach For Distributed
Data Aggregations", arXiv:2010.14596) treats keyed aggregation as the
workhorse of distributed data engineering. The local algorithm here is the
sort-based path adapted to the compacted-front Table invariant:

    sort-by-key  ->  segment-boundary detection  ->  segment reductions

Exact multi-column keys throughout (the sort compares real key columns, as
in ops_local's sort path); hashing appears only as the distributed
pre-partitioner (ops_dist.dist_groupby). The segment reductions run on the
Pallas one-hot kernel (kernels/segment_reduce.py) for the hot 1-D shapes
and on XLA scatter-reduce otherwise — identical semantics.

Aggregators: sum / count / min / max / mean / var / first. Every aggregator
decomposes into *algebraic* partials (sum, sumsq, count, min, max, first)
that combine associatively across shards — the paper's two-phase
(partial-aggregate -> AllToAll -> final-combine) strategy falls out of the
same machinery: ``groupby == finalize ∘ partial_groupby`` locally, and
``finalize ∘ combine ∘ shuffle ∘ partial`` distributed.

Output Table: one row per group (compacted to the front, ordered by key),
columns = key columns + ``{col}_{agg}`` result columns.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import ops_local as L
from repro.core.table import Table
from repro.kernels import ops as kops

AGG_OPS = ("sum", "count", "min", "max", "mean", "var", "first")

# aggregator -> algebraic partials it needs (combine: sums add, min/max
# re-reduce, first takes the earliest partial in global row order)
_DECOMP = {
    "sum": ("sum",),
    "count": ("count",),
    "min": ("min",),
    "max": ("max",),
    "mean": ("sum", "count"),
    "var": ("sum", "sumsq", "count"),
    "first": ("first",),
}
_COMBINE = {"sum": "sum", "sumsq": "sum", "count": "sum",
            "min": "min", "max": "max", "first": "first"}


def normalize_aggs(aggs) -> tuple[tuple[str, str], ...]:
    """Accept {col: op | [ops]} or [(col, op), ...] -> ((col, op), ...)."""
    if isinstance(aggs, dict):
        pairs = []
        for col, ops in aggs.items():
            ops = [ops] if isinstance(ops, str) else list(ops)
            pairs += [(col, op) for op in ops]
    else:
        pairs = [(c, o) for c, o in aggs]
    for col, op in pairs:
        assert op in AGG_OPS, (op, AGG_OPS)
    return tuple(pairs)


def _prim_name(col: str, prim: str) -> str:
    """Internal partial-column name (count is group size, column-free)."""
    return "__count" if prim == "count" else f"__{prim}__{col}"


def _segments(table: Table, keys: Sequence[str]):
    """Sort by keys -> (sorted table, seg_id (cap,) int32 [-1 invalid],
    num_groups, starts (cap,) int32 row index of each group's first row)."""
    if table.capacity == 0:
        table = Table({k: jnp.zeros((1,) + v.shape[1:], v.dtype)
                       for k, v in table.columns.items()}, table.row_count)
    st = L.sort_by(table, list(keys))
    cap = st.capacity
    valid = st.valid_mask()
    differs = jnp.zeros((cap,), bool)
    for k in keys:
        col = st.columns[k]
        differs = differs | (col != jnp.roll(col, 1))
    boundary = valid & (differs | (jnp.arange(cap) == 0))
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    seg = jnp.where(valid, seg, -1)
    num_groups = jnp.sum(boundary).astype(jnp.int32)
    # one boundary row per group: scatter its row index to slot seg[i]
    starts = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(boundary, seg, cap)].set(jnp.arange(cap, dtype=jnp.int32),
                                           mode="drop")
    return st, seg, num_groups, starts


def _first(col: jax.Array, starts: jax.Array, group_valid: jax.Array):
    """Per-group value at the segment start (stable sort => first in input
    order). Works for N-D payload columns."""
    v = col[starts]
    sel = group_valid.reshape((-1,) + (1,) * (col.ndim - 1))
    return jnp.where(sel, v, jnp.zeros_like(v))


def _reduce(col: jax.Array, seg: jax.Array, slots: int, prim: str,
            group_valid: jax.Array, use_kernel):
    """One algebraic partial over a (cap, ...) column -> (slots, ...)."""
    if prim == "sumsq":
        col = col.astype(jnp.float32) ** 2
        prim = "sum"
    out = kops.segment_reduce(col, seg, slots, prim, use_kernel=use_kernel)
    # empty slots hold the op identity (e.g. +inf for min): zero them so
    # rows past row_count stay benign garbage
    sel = group_valid.reshape((-1,) + (1,) * (out.ndim - 1))
    return jnp.where(sel, out, jnp.zeros_like(out))


def _partial_columns(table: Table, keys: Sequence[str], pairs, *,
                     out_capacity: int | None = None, use_kernel=None):
    """Shared phase-1 machinery: per-group key values + algebraic partials.

    Reductions run into ``out_capacity`` slots when given (groups past it
    truncate, mirroring join's explicit memory-budget failure mode) — a
    tight bound both shrinks the output table and keeps the segment count
    within the Pallas kernel's VMEM budget on large inputs.
    """
    st, seg, num_groups, starts = _segments(table, keys)
    cap = st.capacity
    slots = cap if out_capacity is None else min(cap, out_capacity)
    row_count = jnp.minimum(num_groups, slots)
    group_valid = jnp.arange(slots) < row_count
    starts = starts[:slots]

    cols: dict[str, jax.Array] = {}
    for k in keys:
        cols[k] = _first(st.columns[k], starts, group_valid)
    prims = {(c, p) for c, op in pairs for p in _DECOMP[op]}
    for col, prim in sorted(prims, key=lambda cp: _prim_name(*cp)):
        name = _prim_name(col, prim)
        if name in cols:
            continue  # shared count slot
        if prim == "count":
            ones = jnp.where(seg >= 0, 1, 0).astype(jnp.int32)
            cols[name] = _reduce(ones, seg, slots, "sum", group_valid,
                                 use_kernel)
        elif prim == "first":
            cols[name] = _first(st.columns[col], starts, group_valid)
        else:
            cols[name] = _reduce(st.columns[col], seg, slots, prim,
                                 group_valid, use_kernel)
    return Table(cols, row_count)


def _finalize(partial: Table, keys: Sequence[str], pairs) -> Table:
    """Turn algebraic partials into the user-facing aggregate columns."""
    cols = {k: partial.columns[k] for k in keys}
    get = lambda c, p: partial.columns[_prim_name(c, p)]
    for col, op in pairs:
        name = f"{col}_{op}"
        if op in ("sum", "min", "max", "first"):
            cols[name] = get(col, op)
        elif op == "count":
            cols[name] = get(col, "count")
        elif op == "mean":
            s = get(col, "sum").astype(jnp.float32)
            n = jnp.maximum(get(col, "count"), 1).astype(jnp.float32)
            cols[name] = s / n.reshape((-1,) + (1,) * (s.ndim - 1))
        elif op == "var":  # population variance: E[x^2] - E[x]^2, clamped
            s = get(col, "sum").astype(jnp.float32)
            n = jnp.maximum(get(col, "count"), 1).astype(jnp.float32)
            n = n.reshape((-1,) + (1,) * (s.ndim - 1))
            mean = s / n
            cols[name] = jnp.maximum(get(col, "sumsq") / n - mean * mean, 0.0)
    return Table(cols, partial.row_count)


def groupby(table: Table, keys: Sequence[str] | str, aggs, *,
            out_capacity: int | None = None, use_kernel=None) -> Table:
    """Local GroupBy: one output row per distinct key tuple, ordered by key.

    keys: 1-D key column name(s) (exact multi-column comparison).
    aggs: {col: op | [ops]} or [(col, op), ...]; ops in AGG_OPS. N-D payload
    columns support sum/min/max/mean/first (element-wise per row-vector).
    Output columns: keys + ``{col}_{op}``; row_count = number of groups.
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    pairs = normalize_aggs(aggs)
    partial = _partial_columns(table, keys, pairs, out_capacity=out_capacity,
                               use_kernel=use_kernel)
    return _finalize(partial, keys, pairs)


def partial_groupby(table: Table, keys: Sequence[str] | str, aggs, *,
                    out_capacity: int | None = None, use_kernel=None) -> Table:
    """Phase 1 of the two-phase strategy: per-shard algebraic partials.

    Output rows are one per locally-distinct key (<= key cardinality, the
    shuffle-volume win); columns are the mangled partial slots + keys.
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    pairs = normalize_aggs(aggs)
    return _partial_columns(table, keys, pairs, out_capacity=out_capacity,
                            use_kernel=use_kernel)


def combine_groupby(partials: Table, keys: Sequence[str] | str, aggs, *,
                    out_capacity: int | None = None, use_kernel=None) -> Table:
    """Phase 2: merge partial rows that share a key, then finalize.

    ``combine_groupby(partial_groupby(t, ...), ...) == groupby(t, ...)`` —
    and partials arriving from different shards (via repartition) combine
    the same way: sums add, min/max re-reduce, first takes the earliest
    partial in row order (repartition preserves source-shard order).
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    pairs = normalize_aggs(aggs)
    st, seg, num_groups, starts = _segments(partials, keys)
    cap = st.capacity
    slots = cap if out_capacity is None else min(cap, out_capacity)
    row_count = jnp.minimum(num_groups, slots)
    group_valid = jnp.arange(slots) < row_count
    starts = starts[:slots]

    cols = {k: _first(st.columns[k], starts, group_valid) for k in keys}
    for name in st.column_names:
        if not name.startswith("__"):
            continue
        prim = "count" if name == "__count" else name[2:].split("__", 1)[0]
        comb = _COMBINE[prim]
        if comb == "first":
            cols[name] = _first(st.columns[name], starts, group_valid)
        else:
            cols[name] = _reduce(st.columns[name], seg, slots, comb,
                                 group_valid, use_kernel)
    merged = Table(cols, row_count)
    return _finalize(merged, keys, pairs)
