"""Distributed relational operators (Cylon Fig. 3): local ops ∘ shuffle.

Each function here runs **inside** ``shard_map`` over the shuffle axis —
the BSP worker program of the paper. ``repro.core.context.DistContext``
provides the user-facing wrappers that build the shard_map/jit around them.

Composition table (paper §II-B):
  select/project      : pleasingly parallel, no network
  join                : hash_partition(key) -> AllToAll -> local join
  union/intersect/diff: hash_partition(whole row) -> AllToAll -> local op
  sort (global)       : sample splitters -> range partition -> local sort
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import ops_agg as A
from repro.core import ops_local as L
from repro.core.repartition import ShuffleStats, repartition
from repro.core.table import Table
from repro.kernels import ops as kops
from repro.utils import axis_size


def _row_pid(table: Table, key_columns: Sequence[str], p: int, seed: int):
    pid, _ = L.hash_partition(table, key_columns, p, seed=seed)
    return pid


def dist_join(
    left: Table,
    right: Table,
    on: Sequence[str] | str,
    *,
    axis_name: str,
    bucket_capacity: int,
    how: str = "inner",
    algorithm: str = "sort",
    out_capacity: int | None = None,
    seed: int = 7,
):
    """Distributed join = shuffle both sides by key hash, then local join.

    Rows with equal keys land on the same shard (same hash, same modulus),
    so the local join of the repartitioned tables is exact.
    """
    on_l = [on] if isinstance(on, str) else list(on)
    p = axis_size(axis_name)
    left2, st_l = repartition(
        left, _row_pid(left, on_l, p, seed), axis_name=axis_name,
        bucket_capacity=bucket_capacity)
    right2, st_r = repartition(
        right, _row_pid(right, on_l, p, seed), axis_name=axis_name,
        bucket_capacity=bucket_capacity)
    out = L.join(left2, right2, on_l, how=how, algorithm=algorithm,
                 out_capacity=out_capacity, seed=seed + 1)
    return out, (st_l, st_r)


def _dist_set_op(a: Table, b: Table, op, *, axis_name: str, bucket_capacity: int,
                 seed: int = 7, **kw):
    """Shuffle by whole-row hash (paper §II-B-4) so duplicates colocate."""
    names = a.column_names
    p = axis_size(axis_name)
    a2, st_a = repartition(a, _row_pid(a, names, p, seed), axis_name=axis_name,
                           bucket_capacity=bucket_capacity)
    b2, st_b = repartition(b, _row_pid(b, names, p, seed), axis_name=axis_name,
                           bucket_capacity=bucket_capacity)
    return op(a2, b2, **kw), (st_a, st_b)


def dist_union(a: Table, b: Table, **kw):
    return _dist_set_op(a, b, L.union, **kw)


def dist_intersect(a: Table, b: Table, **kw):
    return _dist_set_op(a, b, L.intersect, **kw)


def dist_difference(a: Table, b: Table, *, mode: str = "symmetric", **kw):
    return _dist_set_op(a, b, lambda x, y: L.difference(x, y, mode=mode), **kw)


def dist_distinct(a: Table, *, axis_name: str, bucket_capacity: int, seed: int = 7):
    p = axis_size(axis_name)
    a2, st = repartition(a, _row_pid(a, a.column_names, p, seed),
                         axis_name=axis_name, bucket_capacity=bucket_capacity)
    return L.distinct(a2), (st,)


def dist_groupby(
    table: Table,
    keys: Sequence[str] | str,
    aggs,
    *,
    axis_name: str,
    bucket_capacity: int,
    strategy: str = "two_phase",
    partial_capacity: int | None = None,
    out_capacity: int | None = None,
    seed: int = 7,
):
    """Distributed GroupBy — both strategies of arXiv:2010.14596.

    strategy='shuffle': hash-partition raw rows by key -> AllToAll -> local
      groupby. Shuffle volume is O(rows) — every row crosses the wire.

    strategy='two_phase': local partial_groupby (<= one row per locally
      distinct key) -> hash-partition the *partials* -> AllToAll -> local
      combine + finalize. Shuffle volume is O(shards x cardinality): on
      low-cardinality keys this moves far fewer bytes, and the AllToAll's
      ``bucket_capacity`` can shrink to ~cardinality/shards.

    ``partial_capacity`` optionally trims the phase-1 partial table (must
    bound the per-shard key cardinality; overflow truncates like join).
    Both strategies produce identical results: one global row per key.
    """
    keys_l = [keys] if isinstance(keys, str) else list(keys)
    pairs = A.normalize_aggs(aggs)
    p = axis_size(axis_name)
    if strategy == "shuffle":
        t2, st = repartition(table, _row_pid(table, keys_l, p, seed),
                             axis_name=axis_name,
                             bucket_capacity=bucket_capacity)
        return A.groupby(t2, keys_l, pairs, out_capacity=out_capacity), (st,)
    if strategy == "two_phase":
        part = A.partial_groupby(table, keys_l, pairs,
                                 out_capacity=partial_capacity)
        part2, st = repartition(part, _row_pid(part, keys_l, p, seed),
                                axis_name=axis_name,
                                bucket_capacity=bucket_capacity)
        return A.combine_groupby(part2, keys_l, pairs,
                                 out_capacity=out_capacity), (st,)
    raise ValueError(strategy)


def dist_sort(
    table: Table,
    by: str,
    *,
    axis_name: str,
    bucket_capacity: int,
    samples_per_shard: int = 64,
):
    """Global sort: sampled range partition, then local sort per shard.

    Output ordering: shard i holds keys <= shard i+1's keys; each shard is
    locally sorted — the standard distributed sort contract.
    """
    p = axis_size(axis_name)
    key = table.columns[by]
    valid = table.valid_mask()
    sentinel = kops.key_max(key.dtype)
    # stride-sample this shard's keys (sentinel where invalid)
    c = table.capacity
    stride = max(1, c // samples_per_shard)
    samp = jnp.where(valid, key, sentinel)[::stride][:samples_per_shard]
    all_samp = jax.lax.all_gather(samp, axis_name).reshape(-1)
    all_samp = jnp.sort(all_samp)
    # p-1 splitters at even quantiles of the sample
    n_s = all_samp.shape[0]
    qs = (jnp.arange(1, p) * n_s) // p
    splitters = all_samp[qs]
    pid = jnp.searchsorted(splitters, key, side="right").astype(jnp.int32)
    pid = jnp.where(valid, pid, -1)
    out, st = repartition(table, pid, axis_name=axis_name,
                          bucket_capacity=bucket_capacity)
    return L.sort_by(out, by), (st,)
