"""Distributed relational operators (Cylon Fig. 3): local ops ∘ shuffle.

Each function here runs **inside** ``shard_map`` over the shuffle axis —
the BSP worker program of the paper. ``repro.core.context.DistContext``
provides the user-facing wrappers that build the shard_map/jit around them,
and ``repro.core.plan`` fuses whole chains of them into one body.

Composition table (paper §II-B):
  select/project      : pleasingly parallel, no network
  join                : hash_partition(key) -> AllToAll -> local join
  union/intersect/diff: hash_partition(whole row) -> AllToAll -> local op
  sort (global)       : sample splitters -> range partition -> local sort

Shuffle elision: every operator takes ``skip_*_shuffle`` flags. When the
plan optimizer proves an input is already hash-partitioned on the operator's
keys (same seed, same modulus — the :class:`~repro.core.repartition.
Partitioning` tag), the AllToAll is skipped and a zero :class:`ShuffleStats`
is emitted in its place, so stats shapes stay stable either way. The
optional ``report`` list collects one static record per potential shuffle
(bucket, bytes/row, dense wire bytes) at trace time — the fused-vs-eager
accounting surfaced by ``benchmarks/bench_plan``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import ops_agg as A
from repro.core import ops_local as L
from repro.core.repartition import (ShuffleStats, _counts_carrier,
                                    repartition, zero_shuffle_stats)
from repro.core.table import Table
from repro.utils import axis_size


def _row_pid(table: Table, key_columns: Sequence[str], p: int, seed: int):
    pid, _ = L.hash_partition(table, key_columns, p, seed=seed)
    return pid


def _row_bytes(table: Table) -> int:
    """Bytes per row of the dense wire format (all columns, all payload)."""
    total = 0
    for v in table.columns.values():
        n = 1
        for d in v.shape[1:]:
            n *= d
        total += n * v.dtype.itemsize
    return total


def _shuffle(table: Table, keys: Sequence[str], *, axis_name: str,
             bucket_capacity: int, seed: int, skip: bool = False,
             report: list | None = None, label: str = "shuffle",
             pid=None, stages: int | None = None,
             shuffle_mode: str = "alltoall") -> tuple[Table, ShuffleStats]:
    """Hash-partition + AllToAll, or the elided identity when ``skip``.

    One record per call lands in ``report`` (at trace time): the dense
    AllToAll ships ``p^2 * bucket * row_bytes`` regardless of row validity,
    so the wire volume is static — 0 when the shuffle is elided, and the
    same for every ``stages`` (staging re-chunks the exchange, it never
    changes what crosses the wire). ``stages=None`` auto-sizes from the
    wire-byte estimate (:func:`repro.core.stats.pick_stages`).
    """
    from repro.core import stats as S

    p = axis_size(axis_name)
    rb = _row_bytes(table)
    if stages is None and not skip:
        stages = S.pick_stages(p * p * bucket_capacity * rb, bucket_capacity)
    if report is not None:
        report.append({
            "op": label, "elided": bool(skip), "row_bytes": rb,
            "bucket": 0 if skip else bucket_capacity,
            "wire_bytes": 0 if skip else p * p * bucket_capacity * rb,
            "stages": 0 if skip else stages, "mode": shuffle_mode,
            # enough shape detail that verify.expected_collectives can
            # reconstruct the per-column exchange decomposition statically
            "columns": len(table.columns),
            "carrier": _counts_carrier(table) is not None,
        })
    if skip:
        return table, zero_shuffle_stats()
    if pid is None:
        pid = _row_pid(table, list(keys), p, seed)
    return repartition(table, pid, axis_name=axis_name,
                       bucket_capacity=bucket_capacity, stages=stages,
                       shuffle_mode=shuffle_mode)


def dist_repartition_by(table: Table, keys: Sequence[str] | str, *,
                        axis_name: str, bucket_capacity: int, seed: int = 7,
                        skip_shuffle: bool = False, report: list | None = None,
                        stages: int | None = None,
                        shuffle_mode: str = "alltoall"):
    """Explicit hash repartition — pre-partition once, elide shuffles later.

    The caller (DistContext / LazyFrame) tags the result with the matching
    :class:`Partitioning`, making every subsequent join/groupby on ``keys``
    with the same seed a shuffle-free local operator.
    """
    keys_l = [keys] if isinstance(keys, str) else list(keys)
    out, st = _shuffle(table, keys_l, axis_name=axis_name,
                       bucket_capacity=bucket_capacity, seed=seed,
                       skip=skip_shuffle, report=report, label="repartition",
                       stages=stages, shuffle_mode=shuffle_mode)
    return out, (st,)


def _lex_cascade_pid(splitters, row_keys, capacity: int, *,
                     strict: bool) -> jax.Array:
    """pid[r] = #{splitter tuples lexicographically < row r} (strict) or
    <= (non-strict), via a comparison cascade over the key columns —
    sidesteps packing multi-key tuples into one wide integer (no uint64
    without x64 on this stack). The single shared kernel behind BOTH the
    sort's splitter assignment and the join's range alignment: the two
    placements must mirror each other exactly.
    """
    m = splitters[0].shape[0]
    lt = jnp.zeros((m, capacity), bool)
    eq = jnp.ones((m, capacity), bool)
    for s, r in zip(splitters, row_keys):
        s2, r2 = s[:, None], r[None, :]
        lt = lt | (eq & (s2 < r2))
        eq = eq & (s2 == r2)
    le = lt if strict else lt | eq
    return jnp.sum(le.astype(jnp.int32), axis=0)


def _lex_max_key_tuple(table: Table, keys: Sequence[str]):
    """This shard's lexicographically largest valid key tuple, in the
    order-preserving uint32 space (zeros — the lex minimum — on an empty
    shard)."""
    invalid = (~table.valid_mask()).astype(jnp.int32)
    cols_u = [L.ordered_u32(table.columns[k]) for k in keys]
    out = jax.lax.sort((invalid, *cols_u), num_keys=1 + len(cols_u))
    idx = jnp.maximum(table.row_count - 1, 0)  # valid max sorts to rc-1
    return [jnp.where(table.row_count > 0, c[idx], jnp.uint32(0))
            for c in out[1:]]


def _range_align_pid(table: Table, anchor: Table, keys: Sequence[str], *,
                     axis_name: str) -> jax.Array:
    """Destinations placing ``table``'s rows where ``anchor`` keeps equal
    keys.

    ``anchor`` is range-partitioned on ``keys`` (shard key ranges disjoint
    and ordered, equal tuples colocated — the RangePartitioning contract).
    The boundaries are re-derived from the data: boundary i = the running
    lexicographic max of shards 0..i's key tuples (an all_gather of p
    scalars per key column — no AllToAll), and a row goes to
    ``#{boundary < row}`` — rows equal to shard i's max land on shard i,
    rows beyond the global max land on the last shard (where, for a join,
    they meet no anchor rows anyway).
    """
    p = axis_size(axis_name)
    c = table.capacity
    local_max = _lex_max_key_tuple(anchor, keys)
    gathered = [jax.lax.all_gather(m, axis_name) for m in local_max]  # (p,)

    def lex_gt(a, b):  # tuple a > tuple b
        gt = jnp.zeros((), bool)
        eq = jnp.ones((), bool)
        for x, y in zip(a, b):
            gt = gt | (eq & (x > y))
            eq = eq & (x == y)
        return gt

    # running lex-max over shards (p is small and static): empty shards
    # inherit the previous boundary, keeping the boundary sequence monotone
    carry = tuple(col[0] for col in gathered)
    bounds = [carry]
    for i in range(1, p - 1):
        cand = tuple(col[i] for col in gathered)
        take = lex_gt(cand, carry)
        carry = tuple(jnp.where(take, x, y) for x, y in zip(cand, carry))
        bounds.append(carry)
    splitters = [jnp.stack([b[j] for b in bounds])
                 for j in range(len(keys))]  # each (p-1,)

    row_keys = [L.ordered_u32(table.columns[k]) for k in keys]
    pid = _lex_cascade_pid(splitters, row_keys, c, strict=True)
    return jnp.where(table.valid_mask(), pid, -1)


def dist_join(
    left: Table,
    right: Table,
    on: Sequence[str] | str,
    *,
    axis_name: str,
    bucket_capacity: int,
    how: str = "inner",
    algorithm: str = "sort",
    out_capacity: int | None = None,
    seed: int = 7,
    shuffle_seed: int | None = None,
    skip_left_shuffle: bool = False,
    skip_right_shuffle: bool = False,
    align: str | None = None,
    align_keys: Sequence[str] | None = None,
    count_truncation: bool = False,
    report: list | None = None,
    stages: int | None = None,
    shuffle_mode: str = "alltoall",
):
    """Distributed join = shuffle both sides by key hash, then local join.

    Rows with equal keys land on the same shard (same hash, same modulus),
    so the local join of the repartitioned tables is exact. A side whose
    ``skip_*_shuffle`` flag is set is trusted to already be partitioned on
    ``on`` with ``shuffle_seed`` — the co-partitioned fast path.

    ``align``: 'left' or 'right' names a side that is RANGE-partitioned on
    ``align_keys`` (a prefix of ``on`` — e.g. it just came out of
    ``dist_sort``). That side keeps its placement (its skip flag is set by
    the optimizer) and the *other* side is range-partitioned to match,
    using boundaries re-derived from the anchored side's data — one
    AllToAll for the whole join instead of two, and the sort's paid-for
    range placement survives into the join output.

    ``count_truncation``: fold the local join's ``out_capacity``
    truncation count into the right-side ShuffleStats overflow (stats
    pytree shape unchanged). Set by the plan executor whenever the cost
    model sized ``out_capacity`` from a cardinality *estimate*, so an
    underestimate triggers the overflow-retry path instead of silently
    returning a short result.
    """
    on_l = [on] if isinstance(on, str) else list(on)
    ps = seed if shuffle_seed is None else shuffle_seed
    lpid = rpid = None
    if align == "left":
        rpid = _range_align_pid(right, left, list(align_keys),
                                axis_name=axis_name)
    elif align == "right":
        lpid = _range_align_pid(left, right, list(align_keys),
                                axis_name=axis_name)
    left2, st_l = _shuffle(left, on_l, axis_name=axis_name,
                           bucket_capacity=bucket_capacity, seed=ps,
                           skip=skip_left_shuffle, report=report,
                           label="join.left", pid=lpid, stages=stages,
                           shuffle_mode=shuffle_mode)
    right2, st_r = _shuffle(right, on_l, axis_name=axis_name,
                            bucket_capacity=bucket_capacity, seed=ps,
                            skip=skip_right_shuffle, report=report,
                            label="join.right", pid=rpid, stages=stages,
                            shuffle_mode=shuffle_mode)
    if count_truncation:
        out, trunc = L.join(left2, right2, on_l, how=how,
                            algorithm=algorithm, out_capacity=out_capacity,
                            seed=seed + 1, with_overflow=True)
        st_r = st_r._replace(overflow=st_r.overflow + trunc)
    else:
        out = L.join(left2, right2, on_l, how=how, algorithm=algorithm,
                     out_capacity=out_capacity, seed=seed + 1)
    return out, (st_l, st_r)


def dist_limit(table: Table, n: int, *, axis_name: str,
               report: list | None = None):
    """True global head-n: counts prefix-scan -> per-shard take quota.

    Shard i takes ``clip(n - rows_before_i, 0, rows_i)`` of its (front-
    compacted) rows, where ``rows_before_i`` comes from an all_gather of
    the per-shard valid counts — one int32 per shard on the wire, not an
    AllToAll. Concatenating shards in order therefore yields exactly the
    first n rows of the global table: head-n in shard order on unordered
    plans, the true global top-n after ``dist_sort`` (whose shards hold
    ordered key ranges). The report record keeps Limit attributed in the
    wire accounting at 0 bytes.
    """
    p = axis_size(axis_name)
    if report is not None:
        report.append({"op": "limit", "elided": True,
                       "row_bytes": _row_bytes(table), "bucket": 0,
                       "wire_bytes": 0})
    if p == 1:
        return L.head(table, n), (zero_shuffle_stats(),)
    idx = jax.lax.axis_index(axis_name)
    counts = jax.lax.all_gather(table.row_count, axis_name)  # (p,)
    before = jnp.sum(jnp.where(jnp.arange(p) < idx, counts, 0))
    quota = jnp.clip(jnp.asarray(n, jnp.int32) - before, 0, table.row_count)
    cap = min(n, table.capacity)
    cols = {k: v[:cap] for k, v in table.columns.items()}
    return Table(cols, quota.astype(jnp.int32)), (zero_shuffle_stats(),)


def _dist_set_op(a: Table, b: Table, op, *, axis_name: str, bucket_capacity: int,
                 seed: int = 7, skip_left_shuffle: bool = False,
                 skip_right_shuffle: bool = False, report: list | None = None,
                 label: str = "set_op", stages: int | None = None,
                 shuffle_mode: str = "alltoall", **kw):
    """Shuffle by whole-row hash (paper §II-B-4) so duplicates colocate."""
    names = a.column_names
    a2, st_a = _shuffle(a, names, axis_name=axis_name,
                        bucket_capacity=bucket_capacity, seed=seed,
                        skip=skip_left_shuffle, report=report,
                        label=f"{label}.left", stages=stages,
                        shuffle_mode=shuffle_mode)
    b2, st_b = _shuffle(b, names, axis_name=axis_name,
                        bucket_capacity=bucket_capacity, seed=seed,
                        skip=skip_right_shuffle, report=report,
                        label=f"{label}.right", stages=stages,
                        shuffle_mode=shuffle_mode)
    return op(a2, b2, **kw), (st_a, st_b)


def dist_union(a: Table, b: Table, **kw):
    return _dist_set_op(a, b, L.union, label="union", **kw)


def dist_intersect(a: Table, b: Table, **kw):
    return _dist_set_op(a, b, L.intersect, label="intersect", **kw)


def dist_difference(a: Table, b: Table, *, mode: str = "symmetric", **kw):
    return _dist_set_op(a, b, lambda x, y: L.difference(x, y, mode=mode),
                        label="difference", **kw)


def dist_distinct(a: Table, *, axis_name: str, bucket_capacity: int,
                  seed: int = 7, skip_shuffle: bool = False,
                  report: list | None = None, stages: int | None = None,
                  shuffle_mode: str = "alltoall"):
    a2, st = _shuffle(a, a.column_names, axis_name=axis_name,
                      bucket_capacity=bucket_capacity, seed=seed,
                      skip=skip_shuffle, report=report, label="distinct",
                      stages=stages, shuffle_mode=shuffle_mode)
    return L.distinct(a2), (st,)


def dist_groupby(
    table: Table,
    keys: Sequence[str] | str,
    aggs,
    *,
    axis_name: str,
    bucket_capacity: int,
    strategy: str = "two_phase",
    partial_capacity: int | None = None,
    out_capacity: int | None = None,
    seed: int = 7,
    shuffle_seed: int | None = None,
    skip_shuffle: bool = False,
    report: list | None = None,
    stages: int | None = None,
    shuffle_mode: str = "alltoall",
):
    """Distributed GroupBy — both strategies of arXiv:2010.14596.

    strategy='shuffle': hash-partition raw rows by key -> AllToAll -> local
      groupby. Shuffle volume is O(rows) — every row crosses the wire.

    strategy='two_phase': local partial_groupby (<= one row per locally
      distinct key) -> hash-partition the *partials* -> AllToAll -> local
      combine + finalize. Shuffle volume is O(shards x cardinality): on
      low-cardinality keys this moves far fewer bytes, and the AllToAll's
      ``bucket_capacity`` can shrink to ~cardinality/shards.

    ``skip_shuffle``: the input is already partitioned on ``keys`` — every
    key lives on exactly one shard, so a plain local groupby IS the global
    result for either strategy (zero wire traffic).

    ``partial_capacity`` optionally trims the phase-1 partial table (must
    bound the per-shard key cardinality; overflow truncates like join).
    Both strategies produce identical results: one global row per key.
    """
    keys_l = [keys] if isinstance(keys, str) else list(keys)
    pairs = A.normalize_aggs(aggs)
    ps = seed if shuffle_seed is None else shuffle_seed
    if skip_shuffle:
        _, st = _shuffle(table, keys_l, axis_name=axis_name,
                         bucket_capacity=bucket_capacity, seed=ps, skip=True,
                         report=report, label=f"groupby.{strategy}",
                         stages=stages, shuffle_mode=shuffle_mode)
        return A.groupby(table, keys_l, pairs, out_capacity=out_capacity), (st,)
    if strategy == "shuffle":
        t2, st = _shuffle(table, keys_l, axis_name=axis_name,
                          bucket_capacity=bucket_capacity, seed=ps,
                          report=report, label="groupby.shuffle",
                          stages=stages, shuffle_mode=shuffle_mode)
        return A.groupby(t2, keys_l, pairs, out_capacity=out_capacity), (st,)
    if strategy == "two_phase":
        part = A.partial_groupby(table, keys_l, pairs,
                                 out_capacity=partial_capacity)
        part2, st = _shuffle(part, keys_l, axis_name=axis_name,
                             bucket_capacity=bucket_capacity, seed=ps,
                             report=report, label="groupby.two_phase",
                             stages=stages, shuffle_mode=shuffle_mode)
        return A.combine_groupby(part2, keys_l, pairs,
                                 out_capacity=out_capacity), (st,)
    raise ValueError(strategy)


def _fold_window_carry(gathered, by, order_by, p: int, k_of):
    """Left-to-right fold of the all-gathered trailing-group summaries.

    ``gathered`` holds every shard's :func:`ops_agg.window_summary` with a
    leading (p,) axis on each leaf. Walking shards in global sort order
    (a static python loop — p is small), the running state describes the
    trailing group of the prefix processed so far; shard i's carry is the
    state BEFORE shard i is folded in. The fold is pure scalar/(K,) math
    on already-local data: the only wire traffic was the p-sized
    all_gather of the summaries — never an AllToAll.
    """
    def at(k):
        return jax.tree.map(lambda x: x[k], gathered)

    tuple_eq = A._tuple_eq  # same comparison the local carry apply uses

    s0 = at(0)
    state = {
        "has": jnp.asarray(False),
        "key": jax.tree.map(jnp.zeros_like, s0["last_by"]),
        "last_order": jax.tree.map(jnp.zeros_like, s0["last_order"]),
        "count": jnp.zeros((), jnp.int32),
        "runs": jnp.zeros((), jnp.int32),
        "run_eq": jnp.zeros((), jnp.int32),
        "sums": jax.tree.map(jnp.zeros_like, s0["sums"]),
        "maxs": jax.tree.map(jnp.zeros_like, s0["maxs"]),
        "lag": jax.tree.map(jnp.zeros_like, s0["lag"]),
    }
    states = [state]
    for k in range(p - 1):
        sk = at(k)
        nonempty = sk["rows"] > 0
        one_group = tuple_eq(sk["first_by"], sk["last_by"])
        cont_group = state["has"] & tuple_eq(sk["first_by"], state["key"])
        # the prefix's trailing group extends through shard k only when
        # shard k is entirely ONE group continuing the carried key —
        # otherwise shard k's own trailing group replaces the state
        combine = nonempty & one_group & cont_group
        cont_run = combine & tuple_eq(sk["first_order"],
                                      state["last_order"])
        run_merge = combine & tuple_eq(sk["last_order"],
                                       state["last_order"])
        new = {
            "has": state["has"] | nonempty,
            "key": dict(sk["last_by"]),
            "last_order": dict(sk["last_order"]),
            "count": jnp.where(combine, state["count"] + sk["count"],
                               sk["count"]),
            "runs": jnp.where(combine,
                              state["runs"] + sk["runs"]
                              - cont_run.astype(jnp.int32), sk["runs"]),
            "run_eq": jnp.where(run_merge, state["run_eq"] + sk["run_eq"],
                                sk["run_eq"]),
            "sums": {n: jnp.where(combine, state["sums"][n] + v, v)
                     for n, v in sk["sums"].items()},
            "maxs": {n: jnp.where(combine, jnp.maximum(state["maxs"][n], v),
                                  v) for n, v in sk["maxs"].items()},
            "lag": {},
        }
        for col, buf in sk["lag"].items():
            kk = buf.shape[0]
            jj = jnp.arange(kk, dtype=jnp.int32)
            prev = state["lag"][col][jnp.clip(jj - sk["count"], 0, kk - 1)]
            new["lag"][col] = jnp.where(combine & (jj >= sk["count"]), prev,
                                        buf)
        # an empty shard leaves the prefix state untouched
        state = jax.tree.map(
            lambda n, o: jnp.where(nonempty, n, o), new, state)
        states.append(state)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    return jax.tree.map(lambda x: x[k_of], stacked)


def _fold_window_lead_carry(gathered, by, p: int, k_of):
    """Right-to-left fold of the heading-group summaries (the lead
    counterpart of :func:`_fold_window_carry`): shard i's state describes
    the heading group of shards i+1..p-1."""
    def at(k):
        return jax.tree.map(lambda x: x[k], gathered)

    tuple_eq = A._tuple_eq

    s0 = at(0)
    state = {"has": jnp.asarray(False),
             "key": jax.tree.map(jnp.zeros_like, s0["first_by"]),
             "head_count": jnp.zeros((), jnp.int32),
             "head": jax.tree.map(jnp.zeros_like, s0["head"])}
    states = [None] * p
    for k in reversed(range(p)):
        states[k] = state
        if k == 0:
            break
        sk = at(k)
        nonempty = sk["rows"] > 0
        one_group = tuple_eq(sk["first_by"], sk["last_by"])
        cont = state["has"] & tuple_eq(sk["last_by"], state["key"])
        combine = nonempty & one_group & cont
        new = {
            "has": state["has"] | nonempty,
            "key": dict(sk["first_by"]),
            "head_count": jnp.where(combine,
                                    sk["rows"] + state["head_count"],
                                    sk["head_count"]),
            "head": {},
        }
        for col, buf in sk["head"].items():
            kk = buf.shape[0]
            jj = jnp.arange(kk, dtype=jnp.int32)
            nxt = state["head"][col][jnp.clip(jj - sk["rows"], 0, kk - 1)]
            new["head"][col] = jnp.where(combine & (jj >= sk["rows"]), nxt,
                                         buf)
        state = jax.tree.map(
            lambda n, o: jnp.where(nonempty, n, o), new, state)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    return jax.tree.map(lambda x: x[k_of], stacked)


def dist_window(
    table: Table,
    by: Sequence[str] | str,
    funcs,
    *,
    axis_name: str,
    bucket_capacity: int,
    order_by: Sequence[str] | str = (),
    samples_per_shard: int = 64,
    skip_shuffle: bool = False,
    use_kernel=None,
    report: list | None = None,
    stages: int | None = None,
    shuffle_mode: str = "alltoall",
):
    """Distributed window functions: range partition -> local sort ->
    per-shard segment scans + cross-shard boundary carry.

    The input is range-partitioned on (by + order_by) exactly like
    ``dist_sort`` (sampled lexicographic splitters), so after the local
    sort every shard holds a contiguous slice of the globally sorted
    frame. ``skip_shuffle`` is the provenance fast path: an input already
    range-partitioned on a (by + order_by) prefix — a ``dist_sort``
    output — skips both the AllToAll and pays only the boundary exchange.

    Groups that span shard boundaries are stitched EXACTLY: each shard
    publishes its trailing-group partial state (and heading-group lead
    values) in one p-sized ``all_gather`` of scalars/(K,) buffers — no
    AllToAll — and a static fold hands every shard the combined carry of
    all preceding (resp. following) shards. Bit-identical to the
    single-host ``ops_agg.window`` on integer-valued columns.
    """
    by_l = [by] if isinstance(by, str) else list(by)
    order_l = [order_by] if isinstance(order_by, str) else list(order_by)
    keys = by_l + order_l
    pairs = A.normalize_funcs(funcs)
    p = axis_size(axis_name)

    if skip_shuffle:
        t2, st = _shuffle(table, keys, axis_name=axis_name,
                          bucket_capacity=bucket_capacity, seed=0, skip=True,
                          report=report, label="window", stages=stages,
                          shuffle_mode=shuffle_mode)
    else:
        pid = _lex_splitter_pids(table, keys, axis_name=axis_name,
                                 samples_per_shard=samples_per_shard)
        t2, st = _shuffle(table, keys, axis_name=axis_name,
                          bucket_capacity=bucket_capacity, seed=0, pid=pid,
                          report=report, label="window", stages=stages,
                          shuffle_mode=shuffle_mode)
    if t2.capacity == 0:
        t2 = Table({k: jnp.zeros((1,) + v.shape[1:], v.dtype)
                    for k, v in t2.columns.items()}, t2.row_count)
    A._window_validate(t2, by_l, order_l, pairs)
    sorted_t = L.sort_by(t2, keys)
    state = A.window_state(sorted_t, by_l, order_l)

    carry = lead_carry = None
    if p > 1:
        idx = jax.lax.axis_index(axis_name)
        summ = A.window_summary(sorted_t, state, by_l, order_l, pairs)
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_name), summ)
        carry = _fold_window_carry(gathered, by_l, order_l, p, idx)
        _, _, _, lead_req = A.carry_requirements(pairs)
        if lead_req:
            lsumm = A.window_lead_summary(sorted_t, state, by_l, pairs)
            lgathered = jax.tree.map(
                lambda x: jax.lax.all_gather(x, axis_name), lsumm)
            lead_carry = _fold_window_lead_carry(lgathered, by_l, p, idx)

    cols = A.window_sorted(sorted_t, state, by_l, order_l, pairs,
                           carry=carry, lead_carry=lead_carry,
                           use_kernel=use_kernel)
    out = Table({**sorted_t.columns, **cols}, sorted_t.row_count)
    return out, (st,)


def _lex_splitter_pids(table: Table, by: Sequence[str], *, axis_name: str,
                       samples_per_shard: int) -> jax.Array:
    """Sampled range partition over one or more key columns.

    Each key column maps through the order-preserving ``ordered_u32``
    transform; splitter *tuples* come from a global lexicographic sort of
    the per-shard samples. Row destinations generalize ``searchsorted(...,
    side='right')``: ``pid[r] = #{s : splitter_s <= row_r}`` under
    lexicographic order — computed against the (num_shards-1) splitters by
    a short comparison cascade, which sidesteps packing multi-key tuples
    into a single wide integer (no uint64 without x64 on this stack).
    """
    p = axis_size(axis_name)
    valid = table.valid_mask()
    c = table.capacity
    stride = max(1, c // samples_per_shard)

    row_keys, samples = [], []
    for k in by:
        ku = L.ordered_u32(table.columns[k])
        row_keys.append(ku)
        # stride-sample this shard's keys (max-sentinel where invalid, so
        # garbage rows sort to the tail of the global sample)
        samp = jnp.where(valid, ku, jnp.uint32(0xFFFFFFFF))
        samples.append(samp[::stride][:samples_per_shard])
    gathered = tuple(jax.lax.all_gather(s, axis_name).reshape(-1)
                     for s in samples)
    ordered = jax.lax.sort(gathered, num_keys=len(gathered))
    if not isinstance(ordered, (tuple, list)):
        ordered = (ordered,)
    # p-1 splitter tuples at even quantiles of the global sample
    n_s = ordered[0].shape[0]
    qs = (jnp.arange(1, p) * n_s) // p
    splitters = [col[qs] for col in ordered]  # each (p-1,)

    # lexicographic splitter <= row, per (splitter, row) pair
    pid = _lex_cascade_pid(splitters, row_keys, c, strict=False)
    return jnp.where(valid, pid, -1)


def dist_sort(
    table: Table,
    by: Sequence[str] | str,
    *,
    axis_name: str,
    bucket_capacity: int,
    samples_per_shard: int = 64,
    skip_shuffle: bool = False,
    report: list | None = None,
    stages: int | None = None,
    shuffle_mode: str = "alltoall",
):
    """Global sort: sampled range partition, then local sort per shard.

    ``by`` may name several key columns — splitters are then lexicographic
    tuples, so the global order is the multi-column lexicographic order.
    Output ordering: shard i holds keys <= shard i+1's keys; each shard is
    locally sorted — the standard distributed sort contract.
    """
    by_l = [by] if isinstance(by, str) else list(by)
    if skip_shuffle:  # single shard (or provably range-partitioned already)
        _, st = _shuffle(table, by_l, axis_name=axis_name,
                         bucket_capacity=bucket_capacity, seed=0, skip=True,
                         report=report, label="sort", stages=stages,
                         shuffle_mode=shuffle_mode)
        return L.sort_by(table, by_l), (st,)
    pid = _lex_splitter_pids(table, by_l, axis_name=axis_name,
                             samples_per_shard=samples_per_shard)
    out, st = _shuffle(table, by_l, axis_name=axis_name,
                       bucket_capacity=bucket_capacity, seed=0, pid=pid,
                       report=report, label="sort", stages=stages,
                       shuffle_mode=shuffle_mode)
    return L.sort_by(out, by_l), (st,)
