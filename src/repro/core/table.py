"""Columnar Table abstraction — the JAX/TPU analogue of Cylon's Arrow table.

A Table is a struct-of-arrays: every column is a ``jax.Array`` whose leading
dimension is the same static length (the *capacity*), plus a traced scalar
``row_count``. Rows ``[0, row_count)`` are valid and **compacted to the
front**; rows ``[row_count, capacity)`` are garbage. This is the
static-shape adaptation of Arrow's variable-length record batches
(DESIGN.md §2): it makes every relational operator a pure, jittable,
shardable function.

Columns may be N-D (e.g. a ``tokens`` column of shape ``(capacity, seq)``):
a row is then a record of vectors. Sort keys and hash inputs must be 1-D;
payload columns can be anything. This is how token batches and MoE
dispatch ride the same relational machinery (DESIGN.md §2 level-2).

Zero-copy interop (the paper's Fig. 5/6 story): a Table's columns ARE device
arrays — feeding them into a training step is a pytree hand-off, no copy, no
host round-trip.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

KEY_DTYPES = (jnp.int32, jnp.uint32, jnp.float32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Table:
    """Fixed-capacity columnar table. Columns share length == capacity."""

    columns: dict[str, jax.Array]
    row_count: jax.Array  # int32 scalar (traced)

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return ((tuple(self.columns[n] for n in names), self.row_count), names)

    @classmethod
    def tree_unflatten(cls, names, children):
        cols, row_count = children
        return cls(dict(zip(names, cols)), row_count)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_arrays(cls, columns: dict[str, jax.Array], row_count=None,
                    capacity: int | None = None) -> "Table":
        """Build from arrays sharing their leading length (host or device)."""
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        lens = {v.shape[0] for v in cols.values()}
        assert len(lens) == 1, f"ragged columns: { {k: v.shape for k, v in cols.items()} }"
        n = lens.pop()
        if capacity is not None and capacity != n:
            assert capacity > n, (capacity, n)
            cols = {
                k: jnp.zeros((capacity,) + v.shape[1:], v.dtype).at[:n].set(v)
                for k, v in cols.items()
            }
        rc = jnp.asarray(n if row_count is None else row_count, jnp.int32)
        return cls(cols, rc)

    @classmethod
    def empty(cls, schema: dict, capacity: int) -> "Table":
        """Pre-allocate an all-invalid table.

        ``schema`` values describe one column each: a plain dtype (1-D
        column), a ``(dtype, trailing_shape)`` tuple, or a
        ``jax.ShapeDtypeStruct`` whose shape is the per-row trailing shape —
        e.g. ``{"tokens": (jnp.int32, (128,))}`` for a token-payload column
        of shape ``(capacity, 128)``.
        """
        cols = {}
        for k, spec in schema.items():
            if isinstance(spec, jax.ShapeDtypeStruct):
                tail, dt = tuple(spec.shape), spec.dtype
            elif isinstance(spec, tuple):
                dt, tail = spec[0], tuple(spec[1])
            else:
                tail, dt = (), spec
            cols[k] = jnp.zeros((capacity,) + tail, dt)
        return cls(cols, jnp.asarray(0, jnp.int32))

    # -- introspection --------------------------------------------------------
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self) -> list[str]:
        return sorted(self.columns)

    @property
    def key_column_names(self) -> list[str]:
        """Columns usable as sort/hash/statistics keys: 1-D, key-typed.
        (N-D payload columns ride along but never drive placement or the
        cost model's cardinality sketches.)"""
        return [k for k, v in sorted(self.columns.items())
                if v.ndim == 1 and v.dtype in KEY_DTYPES]

    @property
    def schema(self) -> dict[str, jnp.dtype]:
        return {k: v.dtype for k, v in sorted(self.columns.items())}

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity) < self.row_count

    def __repr__(self) -> str:  # concrete only outside jit
        return f"Table(cols={self.column_names}, capacity={self.capacity})"

    # -- host-side materialization (the "to_pandas/to_numpy" edge) ------------
    def to_numpy(self) -> dict[str, np.ndarray]:
        """Trim to valid rows on the host. Blocks; not for use inside jit."""
        n = int(self.row_count)
        return {k: np.asarray(v[:n]) for k, v in sorted(self.columns.items())}

    def to_rows(self) -> list[tuple]:
        d = self.to_numpy()
        names = sorted(d)
        return list(zip(*(d[n] for n in names))) if names else []

    # -- functional helpers ----------------------------------------------------
    def with_columns(self, columns: dict[str, jax.Array]) -> "Table":
        return Table({**self.columns, **columns}, self.row_count)

    def rename(self, mapping: dict[str, str]) -> "Table":
        return Table({mapping.get(k, k): v for k, v in self.columns.items()},
                     self.row_count)

    def gather(self, idx: jax.Array, row_count, fill_invalid: bool = True) -> "Table":
        """Reorder rows by `idx` (len == new capacity). idx == -1 -> fill 0."""
        def g(col):
            out = col[jnp.clip(idx, 0, self.capacity - 1)]
            if fill_invalid:
                sel = idx.reshape(idx.shape + (1,) * (col.ndim - 1)) >= 0
                out = jnp.where(sel, out, jnp.zeros_like(out))
            return out
        return Table({k: g(v) for k, v in self.columns.items()},
                     jnp.asarray(row_count, jnp.int32))


def concat_tables(a: Table, b: Table) -> Table:
    """Concatenate (capacity = sum of capacities), keeping valid rows front.

    Rows of `b` are shifted to start at a.row_count via a gather, preserving
    the compacted-front invariant without a sort.
    """
    assert a.schema == b.schema, (a.schema, b.schema)
    ca, cb = a.capacity, b.capacity
    n = ca + cb
    pos = jnp.arange(n)
    from_a = pos < a.row_count
    ib = pos - a.row_count
    valid_b = (ib >= 0) & (ib < b.row_count)
    cols = {}
    for k in a.columns:
        va = a.columns[k][jnp.clip(pos, 0, ca - 1)]
        vb = b.columns[k][jnp.clip(ib, 0, cb - 1)]
        ex = (1,) * (va.ndim - 1)
        cols[k] = jnp.where(from_a.reshape((-1,) + ex), va,
                            jnp.where(valid_b.reshape((-1,) + ex), vb,
                                      jnp.zeros_like(vb)))
    return Table(cols, (a.row_count + b.row_count).astype(jnp.int32))
