"""DistContext — the CylonContext analogue (paper §II-C, Fig. 4).

Cylon's ``CylonContext::InitDistributed(mpi_config)`` binds the library to a
communicator; here the communicator is a **JAX mesh axis**. A
:class:`DistContext` owns ``(mesh, axis_name)`` and exposes the distributed
relational operators as jitted ``shard_map`` programs: the BSP worker code in
``ops_dist.py`` runs once per shard in SPMD lockstep, and the MPI AllToAll
becomes ``jax.lax.all_to_all`` over ``axis_name``.

Every operator — eager or lazy — executes through ONE path: build a logical
plan (``repro.core.plan``), compile it to a single ``shard_map`` body, run it
under ``jit`` keyed by the canonicalized plan. The eager methods below are
one-node plans (semantics identical to the pre-plan implementation: same
shuffles, same seeds, same stats); :meth:`frame` opens the lazy builder
whose ``collect()`` fuses a whole chain into one dispatch with the
optimizer's pushdowns and shuffle elisions applied.

A distributed table (:class:`DistTable`) is the global view: every column is
a device array whose leading dim is ``num_shards * local_capacity`` (sharded
over the shuffle axis), plus per-shard ``row_counts``. Shard *i* owns rows
``[i*C, i*C + row_counts[i])`` — Cylon's "each worker holds a partition of
the table" made explicit in the array layout. A table also carries an
optional static :class:`~repro.core.repartition.Partitioning` tag recording
how its rows are placed; ``ctx.frame`` threads the tag into the optimizer,
which elides shuffles the tag proves redundant.

Transport selection (paper §II-D: TCP vs Infiniband) becomes *mesh-axis
selection*: shuffling over an intra-pod axis rides ICI; an axis that spans
pods rides DCN. Same operator code, different wire — the paper's
communication-layer abstraction, preserved.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import weakref
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import faults as FLT
from repro.core import ops_agg as A
from repro.core import plan as PL
from repro.core import stats as ST
from repro.core.plan_cache import PlanCache
from repro.core.repartition import (Partitioning, RangePartitioning,
                                    fresh_range_fingerprint)
from repro.core.stats import TableStats
from repro.core.table import KEY_DTYPES, Table
from repro.kernels import ops as kops
from repro.utils import ceil_div


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DistTable:
    """Global view of a sharded Table: columns (P*C, ...) + row_counts (P,).

    ``partitioning`` is static placement metadata (not a pytree leaf): when
    set, rows satisfy ``shard == hash(keys) % num_partitions`` — the
    invariant the plan optimizer uses to elide shuffles.

    ``stats`` is static cardinality metadata (also not a leaf): exact
    :class:`~repro.core.stats.TableStats` on a table that went through
    :meth:`DistContext.analyze`, estimator-propagated stats on operator
    outputs built from analyzed inputs, None otherwise. When present the
    plan optimizer's cost model right-sizes shuffle buckets and picks
    per-node strategies from it.
    """

    columns: dict[str, jax.Array]
    row_counts: jax.Array  # (num_shards,) int32
    partitioning: Partitioning | None = None
    stats: "TableStats | None" = None

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return ((tuple(self.columns[n] for n in names), self.row_counts),
                (names, self.partitioning, self.stats))

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, partitioning, stats = aux
        cols, rc = children
        return cls(dict(zip(names, cols)), rc, partitioning, stats)

    @property
    def num_shards(self) -> int:
        return self.row_counts.shape[0]

    @property
    def local_capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0] // self.num_shards

    @property
    def column_names(self) -> list[str]:
        return sorted(self.columns)

    @property
    def schema(self) -> dict[str, jax.ShapeDtypeStruct]:
        """Per-row schema: name -> ShapeDtypeStruct of the trailing shape."""
        return {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                for k, v in sorted(self.columns.items())}

    def global_rows(self) -> jax.Array:
        return jnp.sum(self.row_counts)

    def to_table(self) -> Table:
        """Collapse to a single host-side Table (valid rows compacted)."""
        p, c = self.num_shards, self.local_capacity
        counts = np.asarray(self.row_counts)
        cols = {}
        for k, v in self.columns.items():
            a = np.asarray(v).reshape((p, c) + tuple(v.shape[1:]))
            cols[k] = np.concatenate([a[i, : counts[i]] for i in range(p)], axis=0)
        n = int(counts.sum())
        return Table.from_arrays(cols, row_count=n)


class PlanFuture:
    """Handle to an asynchronously dispatched plan execution.

    ``DistContext.submit`` returns one of these IMMEDIATELY after the XLA
    dispatch — JAX's async runtime means the computation is enqueued, not
    finished, and critically no host sync has happened yet: the overflow
    counters of a cost-sized plan stay ON DEVICE until :meth:`result`.
    That is the serving unlock — a latency-critical loop used to pay one
    blocking device round-trip per cost-sized collect just to learn that
    (almost always) nothing overflowed.

    :meth:`result` performs the deferred verification: it fetches the
    overflow counters (by which point the work has typically long
    finished), and if a cost-sized capacity DID overflow it runs the
    safe-capacity retry *late* — the never-wrong-results contract is
    preserved because the table is only observable through this method.
    Verification also happens opportunistically when a LATER ``submit``
    finds this future's counters already device-ready (folded into the
    next dispatch at zero sync cost).
    """

    def __init__(self, finalize: Callable | None,
                 overflow_arrays: tuple = ()):
        self._finalize = finalize
        self._overflow = tuple(overflow_arrays)
        self._out = None
        self._error: BaseException | None = None
        self._lock = threading.Lock()  # resolve-once under concurrent result()

    @classmethod
    def failed(cls, error: BaseException) -> "PlanFuture":
        """A future already resolved exceptionally — dispatch failed
        before anything could be enqueued. ``result()`` re-raises."""
        fut = cls(None)
        fut._error = error
        return fut

    @property
    def done(self) -> bool:
        """True once resolved — to a verified result OR exceptionally."""
        return self._out is not None or self._error is not None

    def ready(self) -> bool:
        """Best-effort: is the deferred verification now sync-free (every
        overflow counter already on host-reachable memory)? False when the
        runtime cannot tell — callers must treat this as advisory."""
        if self.done:
            return True
        try:
            return all(bool(x.is_ready()) for x in self._overflow)
        except AttributeError:
            return False

    def result_with_stats(self):
        """Verified ``(DistTable, per-shuffle stats)`` — blocks on the
        overflow check (and runs the late safe retry) the first time.

        A failed finalization resolves the future exceptionally EXACTLY
        once: the error is stored under the lock, the finalize closure
        and overflow counters are dropped (no pinned device buffers, no
        half-finalized retry on a later call), and every subsequent call
        re-raises the same error."""
        with self._lock:
            if self._error is not None:
                raise self._error
            if self._out is None:
                try:
                    self._out = self._finalize()
                except BaseException as e:
                    self._error = e
                    raise
                finally:
                    # drop plan/table refs AND the overflow counters once
                    # resolved: a retained future must not pin device
                    # buffers, and a failed one must never re-finalize
                    self._finalize = None
                    self._overflow = ()
        return self._out

    def result(self) -> DistTable:
        """The verified output table (see :meth:`result_with_stats`)."""
        return self.result_with_stats()[0]


#: Recovery counters every context tracks (beyond ``overflow_retries``,
#: kept as its own attribute for backward compatibility). Surfaced in
#: ``cache_stats()`` and, as before/after deltas, in ``ServingReport``.
_RECOVERY_KEYS = ("degraded_kernel", "degraded_shuffle", "compile_retries",
                  "generic_retries", "quarantines", "failed_queries")


class DistContext:
    """Binds the relational operators to a mesh axis (the 'communicator').

    Parameters
    ----------
    mesh: the device mesh; defaults to a 1-D mesh over all local devices.
    axis_name: the mesh axis rows shuffle over (must exist in `mesh`).
    plan_cache: the canonical-plan executable cache (fresh LRU if None).
    faults: fault injection — a ``repro.core.faults.FaultRegistry``, a
        sequence of ``FaultPlan``s, or None to arm from the
        ``REPRO_FAULTS`` env spec (inert when that is unset).
    retry_policy: bounds + backoff for the recovery ladder
        (``repro.core.faults.RetryPolicy``; the default never sleeps).
    validate: post-execution result validation (row-count/received
        invariants + NaN scan at ``result()`` time). None = auto: on
        exactly when faults are armed or ``REPRO_VALIDATE`` is set, so
        the fault-free serving path pays zero extra host syncs.
    """

    def __init__(self, mesh: Mesh | None = None, axis_name: str = "shuffle",
                 plan_cache: PlanCache | None = None,
                 faults: "FLT.FaultRegistry | Sequence[FLT.FaultPlan] | None"
                 = None,
                 retry_policy: FLT.RetryPolicy | None = None,
                 validate: bool | None = None):
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), (axis_name,))
        assert axis_name in mesh.axis_names, (axis_name, mesh.axis_names)
        self.mesh = mesh
        self.axis_name = axis_name
        # canonical-plan -> compiled-executable cache, shared by every
        # client submitting through this context (eager ops, collect,
        # collect_async/submit alike). LRU with budgets + hit/miss/evict/
        # recompile counters — see repro.core.plan_cache.
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        if faults is None:
            faults = FLT.from_env()
        elif not isinstance(faults, FLT.FaultRegistry):
            faults = FLT.FaultRegistry(tuple(faults))
        # the armed fault registry (empty = inert) — every dispatch and
        # finalization runs under its thread-local scope
        self.faults = faults if faults is not None else FLT.FaultRegistry()
        self.retry_policy = retry_policy if retry_policy is not None \
            else FLT.RetryPolicy()
        self._validate = validate
        # recovery-ladder counters (see _RECOVERY_KEYS / cache_stats)
        self.recovery = {k: 0 for k in _RECOVERY_KEYS}
        # how many cost-sized plans overflowed their estimated capacities
        # and were re-run at conservative sizes (the overflow-retry path)
        self.overflow_retries = 0
        # canonical keys of cost-sized plans whose estimates already
        # proved wrong: later collects go STRAIGHT to the safe plan (one
        # conservative execution, not a doomed sized run + retry each time)
        self._overflow_bad: set = set()
        # in-flight futures with deferred overflow verification; weakly
        # held so an abandoned future never pins its tables
        self._pending: list = []
        # guards _pending / _overflow_bad / overflow_retries: submit and
        # result() may be called from multiple client threads. Reentrant
        # because a finalize running under it may fold further bookkeeping.
        self._lock = threading.RLock()

    # -- properties ---------------------------------------------------------
    @property
    def num_shards(self) -> int:
        return self.mesh.shape[self.axis_name]

    def _sharding(self, ndim: int) -> NamedSharding:
        spec = P(self.axis_name, *([None] * (ndim - 1)))
        return NamedSharding(self.mesh, spec)

    # -- table placement ----------------------------------------------------
    def scatter(self, table: Table, *, local_capacity: int | None = None
                ) -> DistTable:
        """Round-robin-block scatter a host Table into `num_shards` shards."""
        p = self.num_shards
        if p == 1 and (local_capacity is None
                       or local_capacity == table.capacity):
            # single-shard fast path: the table IS the only partition —
            # no host round-trip / repack (the ETL hot loop rides this)
            cols = {k: jax.device_put(v, self._sharding(v.ndim))
                    for k, v in table.columns.items()}
            rc = jax.device_put(
                jnp.reshape(jnp.asarray(table.row_count, jnp.int32), (1,)),
                NamedSharding(self.mesh, P(self.axis_name)))
            return DistTable(cols, rc)
        n = int(table.row_count)
        c = local_capacity or max(1, ceil_div(table.capacity, p))
        counts = np.full((p,), n // p, np.int32)
        counts[: n % p] += 1
        assert counts.max() <= c, (counts.max(), c)
        offs = np.concatenate([[0], np.cumsum(counts)])
        cols = {}
        for k in table.column_names:
            v = np.asarray(table.columns[k])
            out = np.zeros((p, c) + v.shape[1:], v.dtype)
            for i in range(p):
                out[i, : counts[i]] = v[offs[i] : offs[i + 1]]
            cols[k] = jax.device_put(
                out.reshape((p * c,) + v.shape[1:]), self._sharding(v.ndim))
        rc = jax.device_put(jnp.asarray(counts),
                            NamedSharding(self.mesh, P(self.axis_name)))
        return DistTable(cols, rc)

    def from_local_parts(self, parts: Sequence[Table]) -> DistTable:
        """Build a DistTable from one local Table per shard (equal capacity)."""
        p = self.num_shards
        assert len(parts) == p, (len(parts), p)
        caps = {t.capacity for t in parts}
        assert len(caps) == 1, caps
        cols = {}
        for k in parts[0].column_names:
            v = np.concatenate([np.asarray(t.columns[k]) for t in parts], axis=0)
            cols[k] = jax.device_put(v, self._sharding(v.ndim))
        rc = jnp.asarray([int(t.row_count) for t in parts], jnp.int32)
        rc = jax.device_put(rc, NamedSharding(self.mesh, P(self.axis_name)))
        return DistTable(cols, rc)

    # -- statistics (the cost-model input) -----------------------------------
    def analyze(self, t: DistTable) -> DistTable:
        """Compute exact :class:`~repro.core.stats.TableStats` for ``t``
        in one cheap vectorized pass and cache them on the table.

        Stats cover the global row count, exact per-shard max, and per
        key column min/max plus an NDV sketch (hash-bitmap linear
        counting — the murmur3 kernel already on the shuffle path). Every
        plan built over the returned table is cost-sized: shuffle buckets
        shrink to estimated occupancy, GroupBy picks ``shuffle`` vs
        ``two_phase`` per node, joins budget their outputs by estimated
        match count. Idempotent: a table that already carries stats is
        returned as-is.
        """
        if t.stats is not None:
            return t
        p, c = t.num_shards, t.local_capacity
        counts = np.asarray(t.row_counts)
        rows = int(counts.sum())
        names = tuple(k for k, v in sorted(t.columns.items())
                      if v.ndim == 1 and v.dtype in KEY_DTYPES)

        def sweep(cols, rc):
            idx = jnp.arange(p * c)
            valid = (idx % c) < rc[idx // c]
            return ST.sketch_columns(cols, valid, names)

        sk = jax.jit(sweep)({n: t.columns[n] for n in names}, t.row_counts)
        cols = []
        for n in names:
            filled, lo, hi = sk[n]
            cols.append((n, ST.ColumnStats(
                ST.linear_count(int(filled), rows),
                float(np.asarray(lo)), float(np.asarray(hi)))))
        stats = TableStats(rows=float(rows), columns=tuple(cols),
                           max_shard_rows=float(counts.max(initial=0)))
        return dataclasses.replace(t, stats=stats)

    # -- the lazy builder ----------------------------------------------------
    def frame(self, table: Table | DistTable):
        """Open a :class:`~repro.core.frame.LazyFrame` over ``table``.

        Operators chained on the frame defer until ``collect()``, which
        optimizes the whole plan (predicate/projection pushdown, shuffle
        elision from the table's Partitioning tag) and runs it as ONE
        shard_map program.
        """
        from repro.core.frame import LazyFrame

        return LazyFrame.scan(self, table)

    # -- shard_map plumbing ---------------------------------------------------
    def _make_global(self, body: Callable) -> Callable:
        """Wrap a per-shard `body(*tables) -> (Table, stats)` in shard_map."""
        from repro.utils import shard_map

        axis = self.axis_name

        def local_fn(*local_tabs):
            tables = [Table(cols, rc.reshape(())) for cols, rc in local_tabs]
            out, stats = body(*tables)
            stats = jax.tree.map(lambda x: jnp.asarray(x)[None], stats)
            return out.columns, out.row_count[None], stats

        def global_fn(*args):
            # P(axis) as a pytree-prefix spec: every leaf is per-shard data
            # sharded on its leading dim (columns, row counts, stats alike).
            fn = shard_map(local_fn, mesh=self.mesh, in_specs=P(axis),
                           out_specs=P(axis))
            return fn(*args)

        return global_fn

    def cache_stats(self) -> dict:
        """Plan-cache counter snapshot (hits/misses/evictions/recompiles
        plus residency) — the serving benchmark's warm-path gate reads
        this before and after a run to assert 0 recompiles. Also carries
        the plan verifier's ``verify_runs``/``verify_findings`` counters
        (process-wide; see ``repro.core.verify``), this context's
        recovery-ladder counters (``overflow_retries``,
        ``degraded_kernel``/``degraded_shuffle``, ``compile_retries``,
        ``generic_retries``, ``quarantines``, ``failed_queries``) and the
        fault registry's ``fault_calls``/``fault_fires``."""
        from repro.core import verify as V

        with self._lock:
            rec = dict(self.recovery)
            rec["overflow_retries"] = self.overflow_retries
        return {**self.plan_cache.stats(), **V.counter_snapshot(),
                **self.faults.stats(), **rec}

    def _bump(self, counter: str, n: int = 1):
        with self._lock:
            self.recovery[counter] += n

    # -- result validation (the quarantine gate) ------------------------------
    def _validation_on(self) -> bool:
        """Finalize-time result validation costs host syncs (row counts,
        a NaN scan), so it is opt-in: explicit ``validate=``, the
        ``REPRO_VALIDATE`` env, or automatically whenever faults are
        armed (a chaos run must detect its own poison)."""
        if self._validate is not None:
            return bool(self._validate)
        return self.faults.active or \
            os.environ.get("REPRO_VALIDATE", "") not in ("", "0")

    def _validate_result(self, out: DistTable, stats,
                         tabs: Sequence[DistTable]) -> list[str]:
        """Post-execution invariants; non-empty findings quarantine the
        run (one fully-degraded re-execution). Checks: per-shard row
        counts within [0, capacity]; every shuffle's received-row total
        bounded by the rows the inputs could possibly hold (garbled
        counts decode to absurd totals); no NaN in any valid float cell
        (kernel/chunk poison). Assumes NaN-free user data — documented
        with the validation knob."""
        problems = []
        p, c = out.num_shards, out.local_capacity
        rc = np.asarray(out.row_counts)
        if (rc < 0).any() or (rc > c).any():
            problems.append(f"row_counts outside [0, {c}]: {rc.tolist()}")
        cap_total = sum(t.num_shards * t.local_capacity for t in tabs)
        for i, s in enumerate(stats):
            recv = int(np.asarray(s.received).sum())
            if recv < 0 or recv > cap_total:
                problems.append(f"shuffle {i} received {recv} rows; "
                                f"inputs hold at most {cap_total}")
        idx = np.arange(p * c)
        valid = (idx % c) < np.clip(rc, 0, c)[idx // c]
        for name, col in sorted(out.columns.items()):
            if not jnp.issubdtype(col.dtype, jnp.floating):
                continue
            # float32 staging keeps the scan clear of ml_dtypes (bf16)
            # ufunc gaps; any float NaN survives the cast
            a = np.asarray(col).astype(np.float32)
            mask = valid.reshape((-1,) + (1,) * (a.ndim - 1))
            if np.isnan(np.where(mask, a, 0.0)).any():
                problems.append(f"NaN in column {name!r}")
        return problems

    def _run(self, key, body: Callable, tabs: Sequence[DistTable]):
        """Execute per-shard `body` over DistTables under shard_map + jit.

        ``key`` controls the executable cache: None -> never cached (a
        plan neither canonical- nor content-keyable re-traces per call —
        always correct). The key's own tuples strongly pin any objects
        whose equality the lookup relies on.
        """
        global_fn = self._make_global(body)
        args = tuple((t.columns, t.row_counts) for t in tabs)
        sig = jitted = None
        if key is not None:
            sig = (key, tuple(
                tuple(sorted((k, v.shape, str(v.dtype))
                             for k, v in t.columns.items()))
                for t in tabs))
            jitted = self.plan_cache.get(sig)
        cached = jitted is not None
        if cached and FLT.check("compile") is not None:
            # injected: the cached executable is corrupt. Drop the entry
            # here so the ladder's plain retry compiles fresh.
            self.plan_cache.invalidate(sig)
            raise FLT.FaultError("compile", "cached executable corrupt")
        if jitted is None:
            jitted = jax.jit(global_fn)
        reg = FLT.current()
        fires = reg.fire_count() if reg is not None else 0
        cols, rc, stats = jitted(*args)  # first call on a miss = the trace
        poisoned = reg is not None and reg.fire_count() != fires
        if sig is not None and not cached and not poisoned:
            # admit only AFTER a successful fault-free first call: a trace
            # that raised (put never reached) or absorbed an injected
            # fault (poisoned constants baked in) must never leave a
            # broken executable behind for later cache hits
            self.plan_cache.put(sig, jitted)
        return DistTable(cols, rc), stats

    def submit(self, plan: PL.Node, tabs: Sequence[DistTable], *,
               optimize: bool = False, report: list | None = None
               ) -> PlanFuture:
        """Async dispatch: compile (or cache-hit) + enqueue the plan and
        return a :class:`PlanFuture` IMMEDIATELY — the concurrent-query
        serving path. The single execution pipeline is unchanged:
        (optionally optimized) plan -> one shard_map body -> jit keyed by
        the canonical plan in :attr:`plan_cache`; plans containing keyless
        user lambdas fall back to content keys (``PL.identity_key`` — the
        code object plus the values of its captures/defaults/referenced
        globals), so ad-hoc predicates stop re-jitting per call while a
        rebound global or changed capture still misses. Predicates that
        cannot be safely content-keyed are simply never cached.

        ``report``, when given, receives one static record per potential
        shuffle at TRACE time — a jit-cache hit leaves it empty (use
        ``LazyFrame.plan_report()`` for an always-filled dry run).

        When any input carries TableStats the cost model sizes the plan's
        capacities from cardinality ESTIMATES. Estimates can be wrong, so
        the future is the overflow-safe point: verification of the
        overflow counters is DEFERRED — no host sync happens here — until
        ``future.result()``, or until a later ``submit`` finds the
        counters already device-ready (the check folds into the next
        dispatch). If a cost-sized capacity did overflow (per-entry
        attribution via ``plan.cost_sized_stats_mask`` — overflow on a
        user-set capacity keeps the pre-existing surface-in-stats contract
        and never triggers a retry), the verification runs the safe-
        capacity recompile (``execute_plan(..., safe_capacity=True)``,
        cached under its own ``plan-safe`` key) and the future resolves to
        the retried result — never wrong results, because the table is
        only observable through ``result()``. ``self.overflow_retries``
        counts these; a plan key that failed once goes straight to the
        safe plan on later submits, and outputs of a failed-estimate run
        carry NO propagated stats, so downstream stages fall back to
        conservative sizing instead of cascading the bad numbers.

        That overflow retry is one rung of a general recovery LADDER
        (``repro.core.faults``): every execution attempt runs under
        :attr:`retry_policy` (bounded attempts, deterministic backoff)
        and a classified failure degrades the next attempt — Pallas
        kernel fault -> XLA oracle; staged/ring shuffle fault ->
        monolithic AllToAll; corrupt cached executable -> fresh compile;
        a result that fails validation (NaN / invariant violation, when
        validation is on) is quarantined and re-executed once fully
        degraded. Degraded executables cache under a ``plan-degraded``
        namespace so they never collide with the primary ones. A failure
        that exhausts the ladder resolves the future EXCEPTIONALLY — a
        dispatch-time error returns an already-failed future rather than
        raising, so one bad query can never kill a serving loop or
        poison the pending-fold list; ``result()`` re-raises for its
        owner alone.
        """
        try:
            with FLT.scope(self.faults):
                return self._submit_impl(plan, tabs, optimize=optimize,
                                         report=report)
        except Exception as e:
            self._bump("failed_queries")
            return PlanFuture.failed(e)

    def _submit_impl(self, plan: PL.Node, tabs: Sequence[DistTable], *,
                     optimize: bool, report: list | None) -> PlanFuture:
        p = self.num_shards
        logical = plan
        schemas = [t.schema for t in tabs]
        input_stats = [t.stats for t in tabs]
        have_stats = any(s is not None for s in input_stats)
        policy = self.retry_policy
        if optimize:
            plan, part = PL.optimize_with_partitioning(
                plan, schemas, p, input_stats=input_stats)
        else:
            # eager one-node plans skip the logical rewrites but still get
            # strategy resolution + capacity sizing from the cost model
            part = PL.output_partitioning(plan, schemas, p)
            plan = PL.apply_cost_model(plan, schemas, p, input_stats)
        if isinstance(part, RangePartitioning):
            # materialized tables get a unique provenance token: two
            # executions of the same plan shape over different inputs have
            # different splitters and must never fingerprint-match
            part = dataclasses.replace(
                part, fingerprint=fresh_range_fingerprint())
        key = PL.canonical_key(plan)
        if key is None:
            # content-based fallback for keyless user lambdas; None when
            # the plan cannot be safely keyed (opaque callable, unhashable
            # capture) — _run then skips the cache entirely
            ikey = PL.identity_key(plan)
            run_key = ("plan-id", ikey) if ikey is not None else None
        else:
            run_key = ("plan", key)
        sized = have_stats and PL.plan_cost_sized(plan)
        safe_memo: dict = {}  # the safe plan is derived at most once

        def run_variant(safe: bool, degrade: frozenset):
            """Execute one ladder rung: the primary or safe-capacity
            plan, further degraded per ``degrade``. Undegraded runs keep
            the pre-existing ``plan``/``plan-safe`` cache namespaces;
            degraded executables get their own ``plan-degraded`` keys."""
            if safe:
                if "plan" not in safe_memo:
                    if optimize:
                        sp, _ = PL.optimize_with_partitioning(
                            logical, schemas, p)
                    else:
                        sp = PL.apply_cost_model(logical, schemas, p, None)
                    safe_memo["plan"] = sp
                v_plan, ns = safe_memo["plan"], "plan-safe"
            else:
                v_plan, ns = plan, "plan"
            if FLT.MONO_SHUFFLE in degrade:
                v_plan = PL.degrade_shuffles(v_plan)
            v_key = PL.canonical_key(v_plan)
            if v_key is not None:
                base = (ns, v_key)
            else:
                ik = PL.identity_key(v_plan)
                base = (ns + "-id", ik) if ik is not None else None
            if base is None:
                v_run_key = None
            elif degrade:
                v_run_key = ("plan-degraded", tuple(sorted(degrade))) + base
            else:
                v_run_key = base

            def body(*tables):
                return PL.execute_plan(
                    v_plan, tables, axis_name=self.axis_name, num_shards=p,
                    report=report if not (safe or degrade) else None,
                    safe_capacity=safe)

            if FLT.ORACLE_KERNEL in degrade:
                with kops.oracle_scope():
                    return self._run(v_run_key, body, tabs)
            return self._run(v_run_key, body, tabs)

        def run_with_recovery(safe: bool, degrade: frozenset = frozenset()):
            """Walk the ladder: execute, classify the failure, degrade
            the next attempt — bounded by the retry policy. Only injected
            ``FaultError``s ride the ladder; genuine programming errors
            propagate immediately (retrying them is noise)."""
            degrade = set(degrade)
            last = None
            for attempt in range(1, max(1, policy.max_attempts) + 1):
                if attempt > 1:
                    policy.sleep(attempt - 1)
                try:
                    out, stats = run_variant(safe, frozenset(degrade))
                    return out, stats, frozenset(degrade)
                except FLT.FaultError as e:
                    last = e
                    rung = FLT.rung_for(e)
                    if rung == FLT.ORACLE_KERNEL:
                        degrade.add(FLT.ORACLE_KERNEL)
                        self._bump("degraded_kernel")
                    elif rung == FLT.MONO_SHUFFLE:
                        degrade.add(FLT.MONO_SHUFFLE)
                        self._bump("degraded_shuffle")
                    elif rung == "recompile":
                        # _run already invalidated the corrupt entry; the
                        # plain retry recompiles fresh
                        self._bump("compile_retries")
                    else:
                        self._bump("generic_retries")
            raise RuntimeError(
                f"plan failed after {policy.max_attempts} attempts "
                f"(degradations tried: {sorted(degrade)})") from last

        with self._lock:
            bad_estimates = sized and run_key is not None \
                and run_key in self._overflow_bad
        # this plan's estimates already failed once -> straight to safe
        out, stats, degraded = run_with_recovery(safe=bad_estimates)

        def finalize_inner():
            nonlocal out, stats, bad_estimates, degraded
            if sized and not bad_estimates:
                mask = PL.cost_sized_stats_mask(plan)
                if len(mask) != len(stats):  # defensive: never mis-attribute
                    mask = [True] * len(stats)
                overflow = sum(int(np.asarray(s.overflow).sum())
                               for s, m in zip(stats, mask) if m)
                if overflow > 0:  # late safe-capacity retry
                    bad_estimates = True
                    with self._lock:
                        self.overflow_retries += 1
                        if run_key is not None:
                            self._overflow_bad.add(run_key)
                    out, stats, degraded = run_with_recovery(
                        safe=True, degrade=degraded)
            if self._validation_on():
                problems = self._validate_result(out, stats, tabs)
                if problems:
                    # quarantine: drop the suspect result, re-execute once
                    # fully degraded (oracle kernels + monolithic
                    # shuffles — every rung that changes the program)
                    self._bump("quarantines")
                    out, stats, degraded = run_with_recovery(
                        safe=bad_estimates,
                        degrade=frozenset((FLT.ORACLE_KERNEL,
                                           FLT.MONO_SHUFFLE)))
                    problems = self._validate_result(out, stats, tabs)
                    if problems:
                        raise RuntimeError(
                            "result failed validation after degraded "
                            "re-execution: " + "; ".join(problems))
            est = None
            if have_stats and not bad_estimates:
                est = PL.estimate_output_stats(plan, schemas, input_stats)
            final = dataclasses.replace(out, partitioning=part, stats=est)
            return final, stats

        def finalize():
            try:
                with FLT.scope(self.faults):
                    return finalize_inner()
            except Exception:
                self._bump("failed_queries")
                raise

        # only a cost-sized first pass has anything to verify: everything
        # else resolves without ever touching the host
        overflow_arrays = tuple(s.overflow for s in stats) \
            if sized and not bad_estimates else ()
        fut = PlanFuture(finalize, overflow_arrays)
        self._fold_pending(skip=fut)
        if overflow_arrays or self._validation_on():
            with self._lock:
                self._pending.append(weakref.ref(fut))
        return fut

    def _fold_pending(self, skip: PlanFuture | None = None):
        """Verify earlier futures whose overflow counters are already
        device-ready — the deferred check folded into this dispatch at
        zero sync cost. Dropped or resolved futures fall out of the list;
        a future whose counters are still in flight stays deferred.
        The pending list is swapped out under the lock and resolved
        outside it (resolution may itself dispatch a safe retry)."""
        with self._lock:
            pending, self._pending = self._pending, []
        still = []
        for ref in pending:
            f = ref()
            if f is None or f.done or f is skip:
                continue
            if f.ready():
                try:
                    f.result_with_stats()
                except Exception:
                    # the error is stored on the future for its OWNER to
                    # re-raise from result(); a background fold must not
                    # let one bad query abort an unrelated dispatch
                    pass
            else:
                still.append(ref)
        with self._lock:
            self._pending.extend(still)

    def drain(self, raise_errors: bool = True):
        """Block until every outstanding future is verified (the explicit
        end-of-batch sync for fire-and-forget submitters). Every future is
        resolved even when some fail; the collected errors are returned,
        and the first is re-raised unless ``raise_errors=False``."""
        with self._lock:
            pending, self._pending = self._pending, []
        errors = []
        for ref in pending:
            f = ref()
            if f is not None:
                try:
                    f.result_with_stats()
                except Exception as e:
                    errors.append(e)
        if errors and raise_errors:
            raise errors[0]
        return errors

    def _run_plan(self, plan: PL.Node, tabs: Sequence[DistTable], *,
                  optimize: bool = False, report: list | None = None):
        """Synchronous execution: :meth:`submit` + immediate verification.
        Every eager operator and ``LazyFrame.collect`` rides this; the
        semantics (overflow-safe retry, stats propagation, partitioning
        tags) live in :meth:`submit`'s future."""
        return self.submit(plan, tabs, optimize=optimize,
                           report=report).result_with_stats()

    # -- pleasingly parallel operators (no network; paper §II-B-1/2) ----------
    def select(self, t: DistTable, predicate: Callable[[dict], jax.Array],
               *, key=None, report: list | None = None) -> DistTable:
        """Filter rows by `predicate`. ``key``: optional hashable cache key
        for the predicate — without it every call recompiles (a fresh
        lambda can't be canonicalized). The key must cover any values the
        predicate CAPTURES (e.g. ``key=("q>", threshold)``); differing
        predicate code under the same key is caught by a bytecode
        fingerprint, captured values are not."""
        plan = PL.Select(PL.Scan(0), predicate, key=key)
        out, _ = self._run_plan(plan, [t], report=report)
        return out

    def project(self, t: DistTable, columns: Sequence[str],
                *, report: list | None = None) -> DistTable:
        plan = PL.Project(PL.Scan(0), tuple(columns))
        out, _ = self._run_plan(plan, [t], report=report)
        return out

    # -- shuffle-based operators (paper §II-B-3..6, Fig. 3) -------------------
    def partition_by(self, t: DistTable, keys, *, seed: int = 7,
                     bucket_capacity=None, stages: int | None = None,
                     shuffle_mode: str = "alltoall",
                     report: list | None = None):
        """Explicitly hash-repartition ``t`` on ``keys`` and tag the result.

        Pre-partition a dimension table once; every later join/groupby on
        ``keys`` (same seed) through :meth:`frame` elides its shuffle.
        ``stages``/``shuffle_mode`` tune the shuffle pipeline (bit-
        identical results for every setting; None = cost-model pick).
        """
        keys_t = (keys,) if isinstance(keys, str) else tuple(keys)
        plan = PL.Repartition(PL.Scan(0), keys_t, seed=seed,
                              bucket_capacity=bucket_capacity,
                              stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [t], report=report)

    def join(self, left: DistTable, right: DistTable, on, *, how="inner",
             algorithm="sort", bucket_capacity=None, out_capacity=None,
             seed: int = 7, stages: int | None = None,
             shuffle_mode: str = "alltoall", report: list | None = None):
        on_t = (on,) if isinstance(on, str) else tuple(on)
        plan = PL.Join(PL.Scan(0), PL.Scan(1), on_t, how=how,
                       algorithm=algorithm, bucket_capacity=bucket_capacity,
                       out_capacity=out_capacity, seed=seed,
                       stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [left, right], report=report)

    def union(self, a: DistTable, b: DistTable, *, bucket_capacity=None,
              seed: int = 7, stages: int | None = None,
              shuffle_mode: str = "alltoall", report: list | None = None):
        plan = PL.Union(PL.Scan(0), PL.Scan(1),
                        bucket_capacity=bucket_capacity, seed=seed,
                        stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [a, b], report=report)

    def intersect(self, a: DistTable, b: DistTable, *, bucket_capacity=None,
                  seed: int = 7, stages: int | None = None,
                  shuffle_mode: str = "alltoall", report: list | None = None):
        plan = PL.Intersect(PL.Scan(0), PL.Scan(1),
                            bucket_capacity=bucket_capacity, seed=seed,
                            stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [a, b], report=report)

    def difference(self, a: DistTable, b: DistTable, *, mode="symmetric",
                   bucket_capacity=None, seed: int = 7,
                   stages: int | None = None,
                   shuffle_mode: str = "alltoall",
                   report: list | None = None):
        plan = PL.Difference(PL.Scan(0), PL.Scan(1),
                             bucket_capacity=bucket_capacity, seed=seed,
                             mode=mode, stages=stages,
                             shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [a, b], report=report)

    def distinct(self, a: DistTable, *, bucket_capacity=None, seed: int = 7,
                 stages: int | None = None, shuffle_mode: str = "alltoall",
                 report: list | None = None):
        plan = PL.Distinct(PL.Scan(0), bucket_capacity=bucket_capacity,
                           seed=seed, stages=stages,
                           shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [a], report=report)

    def groupby(self, t: DistTable, keys, aggs, *, strategy: str = "auto",
                bucket_capacity=None, partial_capacity: int | None = None,
                out_capacity: int | None = None, seed: int = 7,
                stages: int | None = None, shuffle_mode: str = "alltoall",
                report: list | None = None):
        """Distributed GroupBy (strategy='auto' | 'two_phase' | 'shuffle').

        'two_phase' (arXiv:2010.14596): per-shard partial aggregates
        shuffle instead of raw rows — on low-cardinality keys this moves
        ~cardinality rows per shard instead of every raw row. 'shuffle'
        repartitions raw rows first. 'auto' (default) lets the cost model
        pick per node from the key-NDV-vs-rows crossover when ``t``
        carries stats (:meth:`analyze`), falling back to 'two_phase'
        otherwise; with stats the AllToAll ``bucket_capacity`` is also
        right-sized automatically instead of needing hand tuning.
        """
        keys_t = (keys,) if isinstance(keys, str) else tuple(keys)
        pairs = A.normalize_aggs(aggs)  # canonical form: the jit-cache key
        plan = PL.GroupBy(PL.Scan(0), keys_t, pairs, strategy=strategy,
                          bucket_capacity=bucket_capacity,
                          partial_capacity=partial_capacity,
                          out_capacity=out_capacity, seed=seed,
                          stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [t], report=report)

    def sort(self, a: DistTable, by, *, bucket_capacity=None,
             samples_per_shard: int = 64, stages: int | None = None,
             shuffle_mode: str = "alltoall", report: list | None = None):
        """Global sort by one or more key columns (lexicographic order).

        The result carries a :class:`RangePartitioning` tag (splitter
        provenance): feeding it back through :meth:`frame` lets the
        optimizer elide the shuffle of a downstream sort/groupby/join on a
        key prefix — the sort-merge fast path.
        """
        by_t = (by,) if isinstance(by, str) else tuple(by)
        plan = PL.Sort(PL.Scan(0), by_t, bucket_capacity=bucket_capacity,
                       samples_per_shard=samples_per_shard,
                       stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [a], report=report)

    def window(self, t: DistTable, by, funcs, *, order_by=(),
               bucket_capacity=None, samples_per_shard: int = 64,
               stages: int | None = None, shuffle_mode: str = "alltoall",
               report: list | None = None):
        """Distributed window functions (rank/lag/running aggregates).

        Range-partitions on (by + order_by) like :meth:`sort`, then
        computes every function with per-shard segment scans plus a
        boundary-carry ``all_gather`` (p scalars per carried partial —
        no AllToAll) for groups spanning shards. A table already range-
        partitioned on a matching key prefix (a :meth:`sort` output fed
        back through the one-node plan) skips the shuffle entirely. The
        result carries a :class:`RangePartitioning` tag on (by +
        order_by), so downstream sorts/groupbys/joins elide shuffles off
        it just like a sort output.
        """
        by_t = (by,) if isinstance(by, str) else tuple(by)
        order_t = (order_by,) if isinstance(order_by, str) \
            else tuple(order_by)
        pairs = A.normalize_funcs(funcs)
        plan = PL.Window(PL.Scan(0), by_t, order_t, pairs,
                         bucket_capacity=bucket_capacity,
                         samples_per_shard=samples_per_shard,
                         stages=stages, shuffle_mode=shuffle_mode)
        return self._run_plan(plan, [t], report=report)

    def limit(self, t: DistTable, n: int, *, report: list | None = None
              ) -> DistTable:
        """True global head-n (counts prefix-scan -> per-shard quota).

        Returns exactly the first ``min(n, total)`` rows in shard order —
        after :meth:`sort`, the global top-n. Rides the same one-node-plan
        path as every other eager operator.
        """
        plan = PL.Limit(PL.Scan(0), int(n))
        out, _ = self._run_plan(plan, [t], report=report)
        return out
