"""DistContext — the CylonContext analogue (paper §II-C, Fig. 4).

Cylon's ``CylonContext::InitDistributed(mpi_config)`` binds the library to a
communicator; here the communicator is a **JAX mesh axis**. A
:class:`DistContext` owns ``(mesh, axis_name)`` and exposes the distributed
relational operators as jitted ``shard_map`` programs: the BSP worker code in
``ops_dist.py`` runs once per shard in SPMD lockstep, and the MPI AllToAll
becomes ``jax.lax.all_to_all`` over ``axis_name``.

A distributed table (:class:`DistTable`) is the global view: every column is
a device array whose leading dim is ``num_shards * local_capacity`` (sharded
over the shuffle axis), plus per-shard ``row_counts``. Shard *i* owns rows
``[i*C, i*C + row_counts[i])`` — Cylon's "each worker holds a partition of
the table" made explicit in the array layout.

Transport selection (paper §II-D: TCP vs Infiniband) becomes *mesh-axis
selection*: shuffling over an intra-pod axis rides ICI; an axis that spans
pods rides DCN. Same operator code, different wire — the paper's
communication-layer abstraction, preserved.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ops_dist as D
from repro.core import ops_local as L
from repro.core.repartition import ShuffleStats, default_bucket_capacity
from repro.core.table import Table
from repro.utils import ceil_div


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DistTable:
    """Global view of a sharded Table: columns (P*C, ...) + row_counts (P,)."""

    columns: dict[str, jax.Array]
    row_counts: jax.Array  # (num_shards,) int32

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return ((tuple(self.columns[n] for n in names), self.row_counts), names)

    @classmethod
    def tree_unflatten(cls, names, children):
        cols, rc = children
        return cls(dict(zip(names, cols)), rc)

    @property
    def num_shards(self) -> int:
        return self.row_counts.shape[0]

    @property
    def local_capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0] // self.num_shards

    @property
    def column_names(self) -> list[str]:
        return sorted(self.columns)

    def global_rows(self) -> jax.Array:
        return jnp.sum(self.row_counts)

    def to_table(self) -> Table:
        """Collapse to a single host-side Table (valid rows compacted)."""
        p, c = self.num_shards, self.local_capacity
        counts = np.asarray(self.row_counts)
        cols = {}
        for k, v in self.columns.items():
            a = np.asarray(v).reshape((p, c) + tuple(v.shape[1:]))
            cols[k] = np.concatenate([a[i, : counts[i]] for i in range(p)], axis=0)
        n = int(counts.sum())
        return Table.from_arrays(cols, row_count=n)


class DistContext:
    """Binds the relational operators to a mesh axis (the 'communicator').

    Parameters
    ----------
    mesh: the device mesh; defaults to a 1-D mesh over all local devices.
    axis_name: the mesh axis rows shuffle over (must exist in `mesh`).
    """

    def __init__(self, mesh: Mesh | None = None, axis_name: str = "shuffle"):
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), (axis_name,))
        assert axis_name in mesh.axis_names, (axis_name, mesh.axis_names)
        self.mesh = mesh
        self.axis_name = axis_name
        self._cache: dict = {}

    # -- properties ---------------------------------------------------------
    @property
    def num_shards(self) -> int:
        return self.mesh.shape[self.axis_name]

    def _sharding(self, ndim: int) -> NamedSharding:
        spec = P(self.axis_name, *([None] * (ndim - 1)))
        return NamedSharding(self.mesh, spec)

    # -- table placement ----------------------------------------------------
    def scatter(self, table: Table, *, local_capacity: int | None = None
                ) -> DistTable:
        """Round-robin-block scatter a host Table into `num_shards` shards."""
        p = self.num_shards
        n = int(table.row_count)
        c = local_capacity or max(1, ceil_div(table.capacity, p))
        counts = np.full((p,), n // p, np.int32)
        counts[: n % p] += 1
        assert counts.max() <= c, (counts.max(), c)
        offs = np.concatenate([[0], np.cumsum(counts)])
        cols = {}
        for k in table.column_names:
            v = np.asarray(table.columns[k])
            out = np.zeros((p, c) + v.shape[1:], v.dtype)
            for i in range(p):
                out[i, : counts[i]] = v[offs[i] : offs[i + 1]]
            cols[k] = jax.device_put(
                out.reshape((p * c,) + v.shape[1:]), self._sharding(v.ndim))
        rc = jax.device_put(jnp.asarray(counts),
                            NamedSharding(self.mesh, P(self.axis_name)))
        return DistTable(cols, rc)

    def from_local_parts(self, parts: Sequence[Table]) -> DistTable:
        """Build a DistTable from one local Table per shard (equal capacity)."""
        p = self.num_shards
        assert len(parts) == p, (len(parts), p)
        caps = {t.capacity for t in parts}
        assert len(caps) == 1, caps
        cols = {}
        for k in parts[0].column_names:
            v = np.concatenate([np.asarray(t.columns[k]) for t in parts], axis=0)
            cols[k] = jax.device_put(v, self._sharding(v.ndim))
        rc = jnp.asarray([int(t.row_count) for t in parts], jnp.int32)
        rc = jax.device_put(rc, NamedSharding(self.mesh, P(self.axis_name)))
        return DistTable(cols, rc)

    # -- shard_map plumbing ---------------------------------------------------
    def _run(self, key, body: Callable, tabs: Sequence[DistTable]):
        """Execute per-shard `body` over DistTables under shard_map + jit.

        `key` controls the jit cache (None -> no caching, e.g. user lambdas).
        """
        from repro.utils import shard_map

        axis = self.axis_name

        def local_fn(*local_tabs):
            tables = [Table(cols, rc.reshape(())) for cols, rc in local_tabs]
            out, stats = body(*tables)
            stats = jax.tree.map(lambda x: jnp.asarray(x)[None], stats)
            return out.columns, out.row_count[None], stats

        def global_fn(*args):
            # P(axis) as a pytree-prefix spec: every leaf is per-shard data
            # sharded on its leading dim (columns, row counts, stats alike).
            fn = shard_map(local_fn, mesh=self.mesh, in_specs=P(axis),
                           out_specs=P(axis))
            return fn(*args)

        args = tuple((t.columns, t.row_counts) for t in tabs)
        if key is not None:
            sig = (key, tuple(
                tuple(sorted((k, v.shape, str(v.dtype))
                             for k, v in t.columns.items()))
                for t in tabs))
            jitted = self._cache.get(sig)
            if jitted is None:
                jitted = jax.jit(global_fn)
                self._cache[sig] = jitted
            cols, rc, stats = jitted(*args)
        else:
            cols, rc, stats = jax.jit(global_fn)(*args)
        return DistTable(cols, rc), stats

    def _bucket_cap(self, t: DistTable, bucket_capacity: int | None,
                    slack: float = 2.0) -> int:
        if bucket_capacity is not None:
            return bucket_capacity
        return default_bucket_capacity(t.local_capacity, self.num_shards, slack)

    # -- pleasingly parallel operators (no network; paper §II-B-1/2) ----------
    def select(self, t: DistTable, predicate: Callable[[dict], jax.Array]
               ) -> DistTable:
        out, _ = self._run(None, lambda a: (L.select(a, predicate), ()), [t])
        return out

    def project(self, t: DistTable, columns: Sequence[str]) -> DistTable:
        cols = tuple(columns)
        out, _ = self._run(("project", cols),
                           lambda a: (L.project(a, cols), ()), [t])
        return out

    # -- shuffle-based operators (paper §II-B-3..6, Fig. 3) -------------------
    def join(self, left: DistTable, right: DistTable, on, *, how="inner",
             algorithm="sort", bucket_capacity=None, out_capacity=None,
             seed: int = 7):
        on_t = (on,) if isinstance(on, str) else tuple(on)
        cb_l = self._bucket_cap(left, bucket_capacity)
        cb_r = self._bucket_cap(right, bucket_capacity)
        cb = max(cb_l, cb_r)

        def body(a, b):
            return D.dist_join(a, b, list(on_t), axis_name=self.axis_name,
                               bucket_capacity=cb, how=how, algorithm=algorithm,
                               out_capacity=out_capacity, seed=seed)

        key = ("join", on_t, how, algorithm, cb, out_capacity, seed)
        return self._run(key, body, [left, right])

    def union(self, a: DistTable, b: DistTable, *, bucket_capacity=None,
              seed: int = 7):
        cb = max(self._bucket_cap(a, bucket_capacity),
                 self._bucket_cap(b, bucket_capacity))
        body = lambda x, y: D.dist_union(
            x, y, axis_name=self.axis_name, bucket_capacity=cb, seed=seed)
        return self._run(("union", cb, seed), body, [a, b])

    def intersect(self, a: DistTable, b: DistTable, *, bucket_capacity=None,
                  seed: int = 7):
        cb = max(self._bucket_cap(a, bucket_capacity),
                 self._bucket_cap(b, bucket_capacity))
        body = lambda x, y: D.dist_intersect(
            x, y, axis_name=self.axis_name, bucket_capacity=cb, seed=seed)
        return self._run(("intersect", cb, seed), body, [a, b])

    def difference(self, a: DistTable, b: DistTable, *, mode="symmetric",
                   bucket_capacity=None, seed: int = 7):
        cb = max(self._bucket_cap(a, bucket_capacity),
                 self._bucket_cap(b, bucket_capacity))
        body = lambda x, y: D.dist_difference(
            x, y, mode=mode, axis_name=self.axis_name, bucket_capacity=cb,
            seed=seed)
        return self._run(("difference", mode, cb, seed), body, [a, b])

    def distinct(self, a: DistTable, *, bucket_capacity=None, seed: int = 7):
        cb = self._bucket_cap(a, bucket_capacity)
        body = lambda x: D.dist_distinct(
            x, axis_name=self.axis_name, bucket_capacity=cb, seed=seed)
        return self._run(("distinct", cb, seed), body, [a])

    def groupby(self, t: DistTable, keys, aggs, *, strategy: str = "two_phase",
                bucket_capacity=None, partial_capacity: int | None = None,
                out_capacity: int | None = None, seed: int = 7):
        """Distributed GroupBy (strategy='two_phase' | 'shuffle').

        Two-phase (default, arXiv:2010.14596): per-shard partial aggregates
        shuffle instead of raw rows — on low-cardinality keys pass a small
        ``bucket_capacity`` (~cardinality x slack / shards) to shrink the
        AllToAll wire volume accordingly. 'shuffle' moves every row.
        """
        from repro.core import ops_agg as A

        keys_t = (keys,) if isinstance(keys, str) else tuple(keys)
        pairs = A.normalize_aggs(aggs)  # canonical form: the jit-cache key
        cb = self._bucket_cap(t, bucket_capacity)

        def body(x):
            # pass the canonical pairs through; dist_groupby's own
            # normalize_aggs is idempotent on them
            return D.dist_groupby(
                x, list(keys_t), pairs, axis_name=self.axis_name,
                bucket_capacity=cb, strategy=strategy,
                partial_capacity=partial_capacity, out_capacity=out_capacity,
                seed=seed)

        key = ("groupby", keys_t, pairs, strategy, cb, partial_capacity,
               out_capacity, seed)
        return self._run(key, body, [t])

    def sort(self, a: DistTable, by: str, *, bucket_capacity=None,
             samples_per_shard: int = 64):
        cb = self._bucket_cap(a, bucket_capacity, slack=4.0)
        body = lambda x: D.dist_sort(
            x, by, axis_name=self.axis_name, bucket_capacity=cb,
            samples_per_shard=samples_per_shard)
        return self._run(("sort", by, cb, samples_per_shard), body, [a])
