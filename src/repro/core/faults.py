"""Deterministic fault injection + the layered recovery policy.

Cylon's pitch is data engineering *everywhere*, and everywhere means
transient faults: flaky transports, capacity misses, kernel miscompiles
on new backends. This module is the half of robustness you can schedule:
a seeded registry of **named fault sites** threaded through the
execution stack, and the :class:`RetryPolicy` + degradation-ladder
machinery ``DistContext`` uses to recover from them.

Fault sites (each checked by the code that owns it):

==================  =====================================================
``shuffle.chunk``   ``repartition.py``: raise during a staged/ring
                    exchange, or garble a received chunk (NaN-pattern
                    poison — a dropped chunk surfaces the same way, as
                    corrupt counts/data). Ladder: monolithic AllToAll.
``kernel.dispatch`` ``kernels/ops.py``: raise at kernel dispatch, or
                    NaN-poison the kernel output. Ladder: XLA oracle.
``stats.estimate``  ``stats.py``: forced under-estimate of a sized
                    capacity. Ladder: the overflow safe-capacity retry.
``cache.admission`` ``plan_cache.py``: spurious miss/evict. No ladder
                    needed — the natural recompile is the recovery.
``compile``         ``context.py``: a cache-hit executable raises as if
                    corrupt. Ladder: invalidate + fresh compile.
==================  =====================================================

Everything is deterministic: a fault fires on the ``nth`` eligible call
of its site, or by a seeded per-call hash when ``probability`` is set —
never ``random``/wall-clock, so a chaos run replays bit-identically.
Faults are scoped per-``DistContext`` (armed via ``FaultPlan``s or the
``REPRO_FAULTS`` env spec) and consulted through a thread-local
:func:`scope`; with no scope armed every check is a dict-free no-op.

``REPRO_FAULTS`` spec grammar (``;``-separated sites)::

    site:key=val,key=val[;site2:...]
    e.g.  REPRO_FAULTS="shuffle.chunk:mode=garble,nth=2;compile:nth=1"

Trace-time semantics: operators run inside ONE fused jitted shard_map
program, so a fault can only act while that program is being *traced* —
raises abort the compile, poison modes bake NaNs into the executable.
``DistContext._run`` therefore never admits an executable whose trace
fired a fault into the plan cache, and result validation (NaN scan +
row-count/received invariants) catches poison at finalize time.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from contextlib import contextmanager
from typing import Iterator, Sequence

SITES = (
    "shuffle.chunk",
    "kernel.dispatch",
    "stats.estimate",
    "cache.admission",
    "compile",
)

#: What an armed site does when its FaultPlan names no explicit mode.
DEFAULT_MODES = {
    "shuffle.chunk": "garble",    # or "raise"
    "kernel.dispatch": "raise",   # or "nan"
    "stats.estimate": "under",
    "cache.admission": "miss",    # or "evict"
    "compile": "raise",
}


class FaultError(RuntimeError):
    """An injected failure, tagged with the site that raised it — the
    recovery ladder routes on ``site`` (:func:`rung_for`)."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}"
                         + (f": {detail}" if detail else ""))
        self.site = site


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One armed site: when it fires and what it does.

    ``nth`` (1-based) fires on exactly that eligible call; otherwise
    ``probability`` draws a deterministic seeded per-call coin. A plan
    stops firing after ``max_fires`` total fires (<= 0 = unlimited) —
    the default of 1 models a transient fault the retry must outlive.
    ``factor`` is the ``stats.estimate`` derate divisor.
    """

    site: str
    mode: str | None = None
    nth: int | None = None
    probability: float = 0.0
    seed: int = 0
    max_fires: int = 1
    factor: float = 8.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {SITES}")

    @property
    def effective_mode(self) -> str:
        return self.mode if self.mode is not None \
            else DEFAULT_MODES[self.site]


def _unit(seed: int, tag: str, n: int) -> float:
    """Deterministic value in [0, 1) from (seed, tag, call index) — the
    seeded coin behind probability firing and retry jitter.

    crc32 alone is GF(2)-linear: two seeds hashing equal-length strings
    differ by a CONSTANT xor across every call, so bit-threshold tests
    (probability=0.5 reads the top bit) could coincide for all n. The
    splitmix-style finalizer breaks that linearity."""
    x = zlib.crc32(f"{seed}:{tag}:{n}".encode())
    x = ((x ^ (x >> 16)) * 0x7FEB352D) & 0xFFFFFFFF
    x = ((x ^ (x >> 15)) * 0x846CA68B) & 0xFFFFFFFF
    x ^= x >> 16
    return x / 2.0 ** 32


class FaultRegistry:
    """Armed FaultPlans + per-site call/fire counters (thread-safe).

    ``check(site)`` counts an eligible call and returns the plan when it
    fires (None otherwise). One registry per ``DistContext``; an empty
    registry is inert and free.
    """

    def __init__(self, plans: Sequence[FaultPlan] = ()):
        self._plans: dict[str, FaultPlan] = {}
        for p in plans:
            if p.site in self._plans:
                raise ValueError(f"duplicate FaultPlan for {p.site!r}")
            self._plans[p.site] = p
        self._lock = threading.Lock()
        self._calls: dict[str, int] = {}
        self._fires: dict[str, int] = {}

    @property
    def active(self) -> bool:
        return bool(self._plans)

    @property
    def plans(self) -> tuple[FaultPlan, ...]:
        return tuple(self._plans.values())

    def plan(self, site: str) -> FaultPlan | None:
        return self._plans.get(site)

    def check(self, site: str) -> FaultPlan | None:
        p = self._plans.get(site)
        if p is None:
            return None
        with self._lock:
            n = self._calls.get(site, 0) + 1
            self._calls[site] = n
            fires = self._fires.get(site, 0)
            if p.max_fires > 0 and fires >= p.max_fires:
                return None
            if p.nth is not None:
                fire = n == p.nth
            else:
                fire = _unit(p.seed, site, n) < p.probability
            if not fire:
                return None
            self._fires[site] = fires + 1
        return p

    def fire_count(self) -> int:
        with self._lock:
            return sum(self._fires.values())

    def stats(self) -> dict:
        """Flat counter snapshot (merged into ``ctx.cache_stats()``)."""
        with self._lock:
            return {"fault_calls": sum(self._calls.values()),
                    "fault_fires": sum(self._fires.values())}

    def fires_by_site(self) -> dict[str, int]:
        with self._lock:
            return dict(self._fires)

    def reset(self):
        with self._lock:
            self._calls.clear()
            self._fires.clear()


# -- the thread-local scope ---------------------------------------------------
# Fault checks happen deep in library code (kernels, repartition, the plan
# cache) that has no DistContext handle; the context arms its registry
# around dispatch/finalize and the sites consult the innermost scope.

_scope = threading.local()


def current() -> FaultRegistry | None:
    stack = getattr(_scope, "stack", None)
    return stack[-1] if stack else None


@contextmanager
def scope(registry: FaultRegistry | None) -> Iterator[None]:
    """Arm ``registry`` for fault checks on this thread. Inert (zero
    bookkeeping beyond a list push) when the registry is None/empty."""
    if registry is None or not registry.active:
        yield
        return
    stack = getattr(_scope, "stack", None)
    if stack is None:
        stack = _scope.stack = []
    stack.append(registry)
    try:
        yield
    finally:
        stack.pop()


def check(site: str) -> FaultPlan | None:
    """Does an armed fault fire at ``site`` for this call? The universal
    site hook: returns None (and costs one attribute read) when no
    registry is in scope."""
    reg = current()
    return reg.check(site) if reg is not None else None


# -- the REPRO_FAULTS env spec ------------------------------------------------

_FIELD_TYPES = {"mode": str, "nth": int, "probability": float,
                "prob": float, "seed": int, "max_fires": int,
                "factor": float}


def parse_spec(spec: str) -> list[FaultPlan]:
    """Parse ``site:k=v,k=v;site2:...`` into FaultPlans (see module doc)."""
    plans = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        site, _, rest = part.partition(":")
        kwargs = {}
        for item in rest.split(","):
            item = item.strip()
            if not item:
                continue
            k, sep, v = item.partition("=")
            k = k.strip()
            if not sep or k not in _FIELD_TYPES:
                raise ValueError(
                    f"bad REPRO_FAULTS field {item!r} (known: "
                    f"{sorted(_FIELD_TYPES)})")
            key = "probability" if k == "prob" else k
            kwargs[key] = _FIELD_TYPES[k](v.strip())
        plans.append(FaultPlan(site.strip(), **kwargs))
    return plans


def from_env(environ=os.environ) -> FaultRegistry | None:
    """Registry armed from ``REPRO_FAULTS``, or None when unset/empty."""
    spec = environ.get("REPRO_FAULTS", "")
    plans = parse_spec(spec) if spec else []
    return FaultRegistry(plans) if plans else None


# -- retry + degradation ------------------------------------------------------

#: Degradation kinds (the ladder rungs that change the executed program).
ORACLE_KERNEL = "oracle-kernel"   # Pallas kernel -> XLA oracle fallback
MONO_SHUFFLE = "mono-shuffle"     # staged/ring shuffle -> monolithic AllToAll


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded attempts with exponential backoff + deterministic jitter.

    ``max_attempts`` bounds TOTAL executions of one query (first try
    included). Delay before retry k (k >= 1) is ``base_delay_s *
    backoff**(k-1)``, perturbed by ±``jitter`` fraction via the seeded
    hash — deterministic, so a replayed chaos run sleeps identically.
    The default base delay is 0: tests and CI never sleep unless a
    caller opts into real backoff.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.0
    backoff: float = 2.0
    jitter: float = 0.25
    seed: int = 0

    def delay_s(self, attempt: int) -> float:
        if self.base_delay_s <= 0:
            return 0.0
        d = self.base_delay_s * self.backoff ** max(attempt - 1, 0)
        return d * (1.0 + self.jitter * (2.0 * _unit(self.seed, "retry",
                                                     attempt) - 1.0))

    def sleep(self, attempt: int):
        d = self.delay_s(attempt)
        if d > 0:
            time.sleep(d)


def rung_for(exc: BaseException) -> str:
    """Map a failure to its recovery rung: which degradation (if any) the
    next attempt applies. ``retry`` = re-dispatch unchanged (the fresh-
    compile rung: ``compile`` faults invalidate their cache entry before
    raising, so the plain retry recompiles)."""
    if isinstance(exc, FaultError):
        if exc.site == "kernel.dispatch":
            return ORACLE_KERNEL
        if exc.site == "shuffle.chunk":
            return MONO_SHUFFLE
        if exc.site == "compile":
            return "recompile"
    return "retry"
