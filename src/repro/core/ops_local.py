"""Local relational operators (Cylon §II-B) as pure, jittable JAX functions.

Every operator preserves the Table invariant (valid rows compacted to the
front, static capacity) and matches a NumPy oracle exactly — see
tests/test_relational_oracle.py (hypothesis property tests).

Cylon's operator set:   Select, Project, Join (inner/left/right/full-outer;
hash & sort algorithms), Union, Intersect, Difference (+ the local building
blocks Sort, Merge, HashPartition, Distinct).

TPU adaptation notes
--------------------
* Variable-size outputs become (capacity, row_count) with compaction — a
  stable argsort on validity, i.e. O(C log C) dense vector work instead of
  pointer chasing.
* The *sort* join sorts raw keys (exact). The *hash* join hashes the key
  columns with the Pallas murmur3 kernel and sorts 32-bit hashes —
  candidates are verified against the real keys, so collisions cost only
  capacity, never correctness (incl. outer joins, via the rescue segment).
* Set ops hash whole rows for partitioning but compare real columns for
  equality (lexicographic multi-operand lax.sort), so they are exact.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.table import Table, concat_tables
from repro.kernels import ops as kops

# ---------------------------------------------------------------------------
# compaction / select / project
# ---------------------------------------------------------------------------


def compact(table: Table, keep: jax.Array) -> Table:
    """Keep rows where `keep & valid`, compacted to the front (stable)."""
    keep = keep & table.valid_mask()
    order = jnp.argsort(~keep, stable=True)
    return table.gather(order, jnp.sum(keep), fill_invalid=False)


def select(table: Table, predicate: Callable[[dict], jax.Array]) -> Table:
    """Cylon Select: filter rows by a user predicate over the columns dict.

    Pleasingly parallel — no communication in the distributed version.
    """
    return compact(table, predicate(table.columns))


def project(table: Table, columns: Sequence[str]) -> Table:
    """Cylon Project: keep a subset of columns (row-count preserved)."""
    return Table({k: table.columns[k] for k in columns}, table.row_count)


def head(table: Table, n: int) -> Table:
    cols = {k: v[:n] for k, v in table.columns.items()}
    return Table(cols, jnp.minimum(table.row_count, n))


# ---------------------------------------------------------------------------
# sort / merge
# ---------------------------------------------------------------------------


def ordered_u32(x: jax.Array) -> jax.Array:
    """Order-preserving map to uint32 (for the bitonic kernel path)."""
    if x.dtype == jnp.uint32:
        return x
    if x.dtype == jnp.int32:
        return x.astype(jnp.uint32) ^ jnp.uint32(0x80000000)
    if x.dtype == jnp.float32:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
        flip = jnp.where(
            (u >> 31) == 1, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000)
        )
        return u ^ flip
    raise TypeError(f"unsupported sort key dtype {x.dtype}")


def sort_permutation(
    table: Table, by: Sequence[str], *, algorithm: str = "auto"
) -> jax.Array:
    """Permutation sorting valid rows ascending by `by`, invalid rows last.

    algorithm: 'auto' | 'xla' | 'bitonic'. The bitonic path (single key,
    capacity <= one VMEM tile) runs the Pallas comparator-network kernel;
    'auto' picks it when applicable.
    """
    c = table.capacity
    invalid = (~table.valid_mask()).astype(jnp.int32)
    keys = [table.columns[k] for k in by]
    use_bitonic = algorithm == "bitonic" or (
        algorithm == "auto" and len(keys) == 1 and c <= 2048
        and keys[0].dtype in (jnp.int32, jnp.uint32, jnp.float32)
    )
    if use_bitonic and len(keys) == 1:
        ku = ordered_u32(keys[0])
        # invalid rows -> max sentinel; the kernel's (key, iota) lexicographic
        # tie-break sorts them after valid max-key rows (front-compaction
        # guarantees invalid rows have larger original indices).
        ku = jnp.where(invalid == 1, jnp.uint32(0xFFFFFFFF), ku)
        _, perm = kops.sort_pairs(ku, jnp.arange(c, dtype=jnp.int32))
        return perm
    ops = (invalid, *keys, jnp.arange(c, dtype=jnp.int32))
    out = jax.lax.sort(ops, num_keys=1 + len(keys))
    return out[-1]


def sort_by(table: Table, by: Sequence[str] | str, *, algorithm: str = "auto") -> Table:
    by = [by] if isinstance(by, str) else list(by)
    perm = sort_permutation(table, by, algorithm=algorithm)
    return table.gather(perm, table.row_count, fill_invalid=False)


def merge(a: Table, b: Table, by: Sequence[str] | str) -> Table:
    """Merge two tables sorted by `by` into one sorted table.

    (Concat + sort; XLA's sort lowering on pre-sorted runs is the merge
    network — a dedicated 2-way bitonic merge pass is a kernel TODO.)
    """
    return sort_by(concat_tables(a, b), by)


# ---------------------------------------------------------------------------
# hash partition
# ---------------------------------------------------------------------------


def hash_partition(
    table: Table, key_columns: Sequence[str], num_partitions: int, *, seed: int = 0
):
    """Cylon HashPartition: per-row destination + per-bucket histogram.

    Returns (part_id (capacity,) int32 with -1 on invalid rows,
             histogram (num_partitions,) int32).
    """
    h = kops.hash_columns([table.columns[k] for k in key_columns], seed=seed)
    pid = (h % jnp.uint32(num_partitions)).astype(jnp.int32)
    pid = jnp.where(table.valid_mask(), pid, -1)
    hist = kops.bucket_histogram(pid, num_partitions)
    return pid, hist


# ---------------------------------------------------------------------------
# distinct & set operators (union / intersect / difference)
# ---------------------------------------------------------------------------


def _lex_sorted_with_tags(table: Table, tag: jax.Array):
    """Sort rows lexicographically over all columns (valid first)."""
    names = table.column_names
    invalid = (~table.valid_mask()).astype(jnp.int32)
    ops = (
        invalid,
        *[table.columns[k] for k in names],
        tag,
        jnp.arange(table.capacity, dtype=jnp.int32),
    )
    out = jax.lax.sort(ops, num_keys=1 + len(names) + 1)  # ... , tag as key
    sorted_cols = dict(zip(names, out[1 : 1 + len(names)]))
    return sorted_cols, out[-2], out[-1], out[0]  # cols, tags, perm, invalid


def _rows_equal(cols: dict, j_shift: int) -> jax.Array:
    """Row i equals row i+j_shift (element-wise over all columns; wraps)."""
    eq = None
    for v in cols.values():
        e = v == jnp.roll(v, -j_shift)
        eq = e if eq is None else (eq & e)
    return eq


def distinct(table: Table) -> Table:
    """Drop duplicate rows (whole-row equality), keep first occurrence."""
    zero_tag = jnp.zeros((table.capacity,), jnp.int32)
    cols, _, perm, invalid = _lex_sorted_with_tags(table, zero_tag)
    eq_prev = jnp.roll(_rows_equal(cols, 1), 1).at[0].set(False)
    valid = invalid == 0
    keep_sorted = valid & ~(eq_prev & jnp.roll(valid, 1))
    # map keep flags back to original order, then compact stably
    keep = jnp.zeros((table.capacity,), bool).at[perm].set(keep_sorted)
    return compact(table, keep)


def _set_op(a: Table, b: Table, keep_rule: str) -> Table:
    """Shared machinery: distinct each side, tag, lex-sort, neighbor tests."""
    assert a.schema == b.schema, "set ops need identical schemas"
    da, db = distinct(a), distinct(b)
    t = concat_tables(da, db)
    # concat_tables places b's valid rows right after a's valid rows.
    pos = jnp.arange(t.capacity)
    tag = ((pos >= da.row_count) & (pos < da.row_count + db.row_count)).astype(jnp.int32)
    cols, tags, perm, invalid = _lex_sorted_with_tags(t, tag)
    valid = invalid == 0
    eq_next = _rows_equal(cols, 1) & valid & jnp.roll(valid, -1)
    eq_next = eq_next.at[-1].set(False)
    eq_prev = jnp.roll(eq_next, 1).at[0].set(False)
    # after per-side distinct, an equal-run has length <= 2 (one per side),
    # with the tag-0 (a) row first because tag is a sort key.
    if keep_rule == "intersect":
        keep_sorted = valid & (tags == 0) & eq_next
    elif keep_rule == "difference_symmetric":
        keep_sorted = valid & ~eq_next & ~eq_prev
    elif keep_rule == "difference_left":
        keep_sorted = valid & (tags == 0) & ~eq_next
    else:
        raise ValueError(keep_rule)
    keep = jnp.zeros((t.capacity,), bool).at[perm].set(keep_sorted)
    return compact(t, keep)


def union(a: Table, b: Table) -> Table:
    """Cylon Union: all rows from both tables, duplicates removed."""
    assert a.schema == b.schema, "union needs identical schemas"
    return distinct(concat_tables(a, b))


def intersect(a: Table, b: Table) -> Table:
    """Cylon Intersect: rows present in both tables (set semantics)."""
    return _set_op(a, b, "intersect")


def difference(a: Table, b: Table, *, mode: str = "symmetric") -> Table:
    """Cylon Difference (paper Table I: symmetric). mode='left' for SQL EXCEPT."""
    return _set_op(a, b, f"difference_{mode}")


# ---------------------------------------------------------------------------
# join
# ---------------------------------------------------------------------------


def _sorted_keys(table: Table, key: jax.Array):
    """(sorted key w/ max-sentinel on invalid rows, permutation)."""
    sentinel = kops.key_max(key.dtype)
    k = jnp.where(table.valid_mask(), key, sentinel)
    perm = jnp.argsort(k, stable=True)  # invalid rows are last (stable + front-compaction)
    return k[perm], perm


def join(
    left: Table,
    right: Table,
    on: Sequence[str] | str,
    *,
    how: str = "inner",
    algorithm: str = "sort",
    out_capacity: int | None = None,
    suffix: str = "_r",
    seed: int = 0,
    with_overflow: bool = False,
    _hash_fn=None,
) -> Table:
    """Cylon Join — all four semantics, both paper algorithms.

    algorithm='sort': exact sort-merge on the raw key (single numeric key).
    algorithm='hash': murmur3 hash of the key column(s) (Pallas kernel),
      sort/search on 32-bit hashes, verify candidates on real keys.
      Required for multi-column keys.

    Output columns: all left columns + right columns (clashes suffixed).
    Unmatched side fills with 0 (static-shape NULL analog; see DESIGN.md).

    ``with_overflow``: also return an int32 scalar counting result rows
    the ``out_capacity`` budget truncated (0 = exact). The cost model
    sizes out_capacity from cardinality estimates; this counter is what
    makes an underestimate loud (it feeds the distributed overflow-retry
    path) instead of a silently short result.
    """
    on = [on] if isinstance(on, str) else list(on)
    assert how in ("inner", "left", "right", "full"), how

    def _min_cap1(t: Table) -> Table:
        if t.capacity > 0:
            return t
        return Table({k: jnp.zeros((1,) + v.shape[1:], v.dtype)
                      for k, v in t.columns.items()}, t.row_count)

    left, right = _min_cap1(left), _min_cap1(right)
    c_l, c_r = left.capacity, right.capacity
    if out_capacity is None:
        out_capacity = c_l + c_r

    if algorithm == "sort":
        assert len(on) == 1, "sort join supports a single key column (use hash)"
        key_l, key_r = left.columns[on[0]], right.columns[on[0]]
        assert key_l.dtype == key_r.dtype, (key_l.dtype, key_r.dtype)
        verify = False
    elif algorithm == "hash":
        hf = _hash_fn or (lambda cols: kops.hash_columns(cols, seed=seed))
        key_l = hf([left.columns[k] for k in on])
        key_r = hf([right.columns[k] for k in on])
        verify = True
    else:
        raise ValueError(algorithm)

    lk, lperm = _sorted_keys(left, key_l)
    rk, rperm = _sorted_keys(right, key_r)
    n_l, n_r = left.row_count, right.row_count

    start = jnp.minimum(jnp.searchsorted(rk, lk, side="left"), n_r)
    end = jnp.minimum(jnp.searchsorted(rk, lk, side="right"), n_r)
    l_valid = jnp.arange(c_l) < n_l
    counts = jnp.where(l_valid, end - start, 0)

    # --- primary segment: candidate pair expansion (slot -> (li, ri)) -----
    off = jnp.cumsum(counts) - counts
    total = jnp.sum(counts)
    t = jnp.arange(out_capacity)
    li = jnp.clip(jnp.searchsorted(off, t, side="right") - 1, 0, c_l - 1)
    j = t - off[li]
    ri = jnp.clip(start[li] + j, 0, c_r - 1)
    slot_valid = t < total

    l_orig = lperm[li]
    r_orig = rperm[ri]

    if verify:
        eq = jnp.ones((out_capacity,), bool)
        for k in on:
            eq &= left.columns[k][l_orig] == right.columns[k][r_orig]
        slot_valid &= eq

    def out_table(l_idx, r_idx, n):
        def take(col, idx, cap):
            v = col[jnp.clip(idx, 0, cap - 1)]
            sel = idx.reshape(idx.shape + (1,) * (col.ndim - 1)) >= 0
            return jnp.where(sel, v, jnp.zeros_like(v))

        cols = {}
        for k in left.column_names:
            cols[k] = take(left.columns[k], l_idx, c_l)
        for k in right.column_names:
            name = k + suffix if k in left.columns else k
            cols[name] = take(right.columns[k], r_idx, c_r)
        return Table(cols, jnp.asarray(n, jnp.int32))

    primary = compact(
        out_table(jnp.where(slot_valid, l_orig, -1), jnp.where(slot_valid, r_orig, -1),
                  out_capacity),
        slot_valid,
    )
    segments = [primary]
    # rows the result WOULD hold with unbounded capacity: the true match
    # count (`total` is computed before slot enumeration; under the hash
    # algorithm it includes collision candidates — a conservative over-
    # count) plus any unmatched-side rows accumulated below
    want_rows = total.astype(jnp.int32)

    if how in ("left", "full"):
        # true-match count per (sorted) left row; rows with none emit unmatched
        true_cnt = jnp.zeros((c_l,), jnp.int32).at[li].add(
            slot_valid.astype(jnp.int32), mode="drop"
        )
        l_unmatched = l_valid & (true_cnt == 0)
        want_rows = want_rows + jnp.sum(l_unmatched.astype(jnp.int32))
        seg = compact(
            out_table(jnp.where(l_unmatched, lperm, -1),
                      jnp.full((c_l,), -1, jnp.int32), c_l),
            l_unmatched,
        )
        segments.append(seg)

    if how in ("right", "full"):
        matched_r = jnp.zeros((c_r,), jnp.int32).at[
            jnp.where(slot_valid, ri, c_r)
        ].add(1, mode="drop")
        r_valid = jnp.arange(c_r) < n_r
        r_unmatched = r_valid & (matched_r == 0)
        want_rows = want_rows + jnp.sum(r_unmatched.astype(jnp.int32))
        seg = compact(
            out_table(jnp.full((c_r,), -1, jnp.int32),
                      jnp.where(r_unmatched, rperm, -1), c_r),
            r_unmatched,
        )
        segments.append(seg)

    result = segments[0]
    for seg in segments[1:]:
        result = concat_tables(result, seg)
    # trim back to the requested capacity (valid rows are front-compacted)
    if result.capacity > out_capacity:
        result = Table(
            {k: v[:out_capacity] for k, v in result.columns.items()},
            jnp.minimum(result.row_count, out_capacity),
        )
    if with_overflow:
        overflow = jnp.maximum(want_rows - out_capacity, 0).astype(jnp.int32)
        return result, overflow
    return result
