"""Canonical-plan -> compiled-executable cache (the serving-path jit cache).

Concurrent-query serving (ROADMAP north star: many clients, not single-query
wall time) lives or dies on never recompiling a plan shape a client has
already run. ``DistContext`` used to keep an ad-hoc unbounded ``dict`` from
``(canonical plan, input signature)`` to the jitted executable; this module
makes that cache first-class:

* **LRU admission with budgets** — ``max_entries`` bounds the executable
  count and ``max_weight`` bounds a caller-supplied weight sum (entries
  default to weight 1), so a long-lived serving session over an open-ended
  query mix cannot grow without bound. Reuse refreshes recency.
* **Counters** — ``hits`` / ``misses`` / ``evictions`` / ``recompiles``
  (a miss on a key that was cached before and has since been evicted —
  the signal that the budgets are too small for the working set), surfaced
  through :meth:`stats` and re-exported as
  ``DistContext.cache_stats()`` for the serving benchmark's warm-path
  "0 recompiles" gate. Recompile detection keeps a bounded set of key
  HASHES (not the keys themselves — a full key retains the whole nested
  canonical-plan tuple), so the accounting side-structure cannot leak
  over an open-ended key mix; rare hash collisions only perturb a
  counter, never a lookup.
* **Content-keyed keyless plans** — plans containing keyless user lambdas
  cannot be canonicalized; ``plan.identity_key`` keys them by the CONTENT
  of the code object and every value the predicate's behavior depends on
  (captures, defaults, referenced globals). The key tuple itself strongly
  pins those objects while the entry is resident, so equality stays
  meaningful for the entry's lifetime; plans that cannot be safely
  content-keyed are never cached at all. ``guards=`` remains available
  for callers that key on object identity explicitly: guard objects are
  pinned while cached and a weakref callback invalidates the entry
  should a guard die while resident.

Safe-capacity recompiles are cached under their own namespace by the
caller (``("plan-safe", ...)`` vs ``("plan", ...)``), so the sized and
conservative executables of one logical plan never collide.

Correctness backstop: the cache only ever replays what ``optimize()``
produced, and under ``REPRO_VERIFY_PLANS`` every such plan has passed the
``repro.core.verify`` static rule registry (schema/partitioning/pushdown/
cost-sizing/idempotence — the idempotence rule also checks the
``canonical_key`` used here is stable under re-optimization). The
verifier's ``verify_runs``/``verify_findings`` counters ride alongside
this cache's counters in ``DistContext.cache_stats()``.

All mutating operations take an internal re-entrant lock, so concurrent
client threads sharing one ``DistContext`` cannot corrupt the LRU order
or the counters (two racing misses may both compile; the second ``put``
wins — wasted work, never a wrong result).
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from typing import Iterable

# recompile accounting remembers at most this many distinct key hashes;
# keys seen beyond the cap simply stop counting as recompiles on re-miss
_EVER_CAP = 1 << 16


class _Entry:
    __slots__ = ("value", "weight", "guards", "refs")

    def __init__(self, value, weight: int, guards: tuple):
        self.value = value
        self.weight = weight
        self.guards = guards  # strong pins: ids stay valid while cached
        self.refs: list = []  # weakrefs guarding against external decay


class PlanCache:
    """LRU map from hashable plan keys to compiled executables."""

    def __init__(self, max_entries: int = 256,
                 max_weight: float | None = None):
        assert max_entries >= 1, max_entries
        self.max_entries = max_entries
        self.max_weight = max_weight
        self._entries: OrderedDict[object, _Entry] = OrderedDict()
        self._weight = 0
        self._ever: set[int] = set()  # hashes of keys admitted at least once
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.recompiles = 0

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:  # no counter side effects
        return key in self._entries

    @property
    def weight(self) -> int:
        return self._weight

    def keys(self) -> Iterable:
        with self._lock:
            return list(self._entries.keys())

    def stats(self) -> dict:
        """Counter snapshot (plain ints — JSON-serializable)."""
        with self._lock:
            return {"entries": len(self._entries), "weight": self._weight,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "recompiles": self.recompiles}

    # -- the cache protocol --------------------------------------------------
    def get(self, key):
        """The cached executable, or None. Counts hit/miss and refreshes
        recency; a miss on a previously-admitted key counts a recompile.

        The ``cache.admission`` fault site fires here: a spurious miss
        (or miss + eviction, mode ``evict``) on a key that IS resident.
        No recovery ladder — the caller recompiles as for any miss, and
        the recompile counter records it; injected correctness impact
        must be nil (the chaos-suite assertion for this site).
        """
        from repro.core import faults as FLT

        fp = FLT.check("cache.admission")
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and fp is not None:
                if fp.effective_mode == "evict":
                    self._entries.pop(key)
                    self._weight -= entry.weight
                    self.evictions += 1
                entry = None  # spurious miss either way
            if entry is None:
                self.misses += 1
                if hash(key) in self._ever:
                    self.recompiles += 1
                return None
            self.hits += 1
            self._entries.move_to_end(key)
            return entry.value

    def put(self, key, value, *, weight: int = 1, guards: tuple = ()):
        """Admit ``value`` under ``key``, evicting LRU entries over budget.

        ``guards``: objects whose identity the key depends on. They are
        pinned while the entry is resident and the entry dies with them —
        never a stale-id hit. (Content-keyed plans need no guards: the
        key tuple itself pins its values.)
        """
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._weight -= old.weight
            entry = _Entry(value, weight, tuple(guards))
            self._entries[key] = entry
            self._weight += weight
            if len(self._ever) < _EVER_CAP:
                self._ever.add(hash(key))
            for g in entry.guards:
                try:
                    entry.refs.append(
                        weakref.ref(g, lambda _, k=key: self.invalidate(k)))
                except TypeError:  # not weakref-able: the strong pin suffices
                    pass
            self._evict_over_budget(keep=key)

    def invalidate(self, key) -> bool:
        """Drop ``key`` if resident (guard death / explicit flush)."""
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is None:
                return False
            self._weight -= entry.weight
            self.evictions += 1
            return True

    def clear(self):
        """Explicit flush: drops every entry AND the recompile-accounting
        hash set (a fresh cache starts with fresh accounting)."""
        with self._lock:
            self.evictions += len(self._entries)
            self._entries.clear()
            self._weight = 0
            self._ever.clear()

    def _evict_over_budget(self, keep):
        while len(self._entries) > self.max_entries or (
                self.max_weight is not None
                and self._weight > self.max_weight
                and len(self._entries) > 1):
            key = next(iter(self._entries))
            if key == keep and len(self._entries) == 1:
                break  # never evict the entry just admitted
            entry = self._entries.pop(key)
            self._weight -= entry.weight
            self.evictions += 1
