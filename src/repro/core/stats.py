"""Cardinality statistics + sizing math for cost-model-driven planning.

Cylon's performance edge comes from choosing the right distributed
algorithm per operator and keeping buffers tight (paper §III); the
follow-up aggregation paper (arXiv:2010.14596) shows the shuffle-vs-
two-phase choice flips with key cardinality. This module supplies the
*numbers* that drive those choices in ``repro.core.plan``:

* :class:`TableStats` — row count plus per-key-column min/max and an NDV
  (number-of-distinct-values) estimate, computed by one cheap vectorized
  pass (:func:`sketch_columns`): hash each key column (the murmur3 kernel
  already on the shuffle path), scatter into a fixed bitmap, and apply
  linear counting ``ndv = -m * ln(1 - occupied/m)``. Cached on
  ``DistTable`` (``ctx.analyze``) and propagated through plan nodes by
  the per-operator estimators in ``plan.py``.

* Sizing math — AllToAll send buckets are static per-(source, dest) slot
  budgets; the cost model sizes them from *estimated occupancy* instead
  of a fixed multiple of table capacity. :func:`with_skew_margin` models
  hash placement as Poisson: budget = mean + 4*sqrt(mean) + 4, i.e. the
  mean plus ~4 standard deviations plus a small-count floor. Estimates
  can still be wrong (selectivity defaults, skewed multiplicity), so
  every stats-sized capacity is *overflow-safe*: the shuffle's overflow
  counter (and the join truncation counter it feeds) triggers a single
  recompile-with-conservative-capacity retry in ``DistContext._run_plan``
  rather than wrong results.

* ``FALLBACK_SLACK`` — THE no-stats constant. Without stats every bucket
  falls back to ``capacity * FALLBACK_SLACK / num_shards`` (the
  pre-cost-model behavior, byte-compatible). The sort path multiplies it
  by :data:`SORT_SLACK_FACTOR` because sampled range splitters miss true
  quantiles; the join output budget doubles it for the same reason the
  eager chain did (two shuffled operands land in one output). All three
  derive from the one constant below instead of scattered literals.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# slack constants (the no-stats fallback path)
# --------------------------------------------------------------------------

#: The single fallback slack for every capacity derived WITHOUT statistics:
#: bucket = ceil(capacity * FALLBACK_SLACK / num_shards). Documented here,
#: referenced everywhere (plan executor, repartition defaults).
FALLBACK_SLACK = 2.0

#: Sort range-partitions by sampled splitters; quantile error concentrates
#: rows beyond hash-uniformity, so the no-stats sort bucket uses
#: FALLBACK_SLACK * SORT_SLACK_FACTOR (== the pre-cost-model 4.0).
SORT_SLACK_FACTOR = 2.0

#: No-stats join output budget: 2 * p * bucket — both shuffled operands
#: land in one output table (the historical 2x on top of FALLBACK_SLACK).
JOIN_OUT_FACTOR = 2.0

#: Selectivity assumed for a Select whose predicate we cannot evaluate
#: statically (all of them, today): the classic System R default.
DEFAULT_SELECTIVITY = 0.5

#: Multiplier on estimated mean occupancy for stats-sized SORT buckets
#: (sampled-splitter error) and range-aligned join sends.
RANGE_SIZING_FACTOR = 2.0

#: Multiplier on the estimated per-shard join match count (key
#: multiplicity concentrates matches beyond the Poisson model).
JOIN_OUT_SIZING_FACTOR = 1.5

#: Linear-counting bitmap width for the NDV sketch. Error ~ sqrt(m) *
#: exp(ndv/m) / ndv: under 3% up to ndv ~ m, degrading gracefully above.
SKETCH_BUCKETS = 4096

#: A shuffle below this wire-byte estimate runs as one collective (S=1):
#: per-collective launch overhead would swamp any comm/compute overlap.
STAGE_WIRE_THRESHOLD = 1 << 20

#: Staging ceiling — chunks beyond this buy no extra overlap (there are
#: only ~2 neighbours to hide a chunk's wire time behind) and each one is
#: another collective launch.
MAX_SHUFFLE_STAGES = 4


def pick_stages(wire_bytes: float, bucket_capacity: int) -> int:
    """Pipeline depth for a shuffle moving ``wire_bytes`` over the wire.

    S=1 below :data:`STAGE_WIRE_THRESHOLD` (small shuffles pay zero extra
    collectives), then doubles with the wire volume up to
    :data:`MAX_SHUFFLE_STAGES`, clamped so each chunk keeps at least one
    capacity slot. Every S is bit-identical; this only trades collective
    launches against comm/compute overlap.
    """
    if bucket_capacity <= 1 or wire_bytes <= STAGE_WIRE_THRESHOLD:
        return 1
    s = 2
    while s < MAX_SHUFFLE_STAGES and wire_bytes >= (2 * s) * STAGE_WIRE_THRESHOLD:
        s *= 2
    return min(s, bucket_capacity)


# --------------------------------------------------------------------------
# statistics containers
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Per-column statistics: NDV estimate + value range (as floats)."""

    ndv: float
    lo: float | None = None
    hi: float | None = None


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Table-level statistics (hashable; static planner metadata).

    ``rows`` is exact on analyzed tables and an estimate after operator
    propagation. ``columns`` holds ColumnStats for the 1-D key-typed
    columns only (payload columns don't drive planning decisions).
    ``max_shard_rows`` is the exact per-shard max on analyzed tables
    (None once an operator has redistributed rows).
    """

    rows: float
    columns: tuple[tuple[str, ColumnStats], ...] = ()
    max_shard_rows: float | None = None

    def col(self, name: str) -> ColumnStats | None:
        for k, cs in self.columns:
            if k == name:
                return cs
        return None

    def ndv(self, keys: Sequence[str]) -> float | None:
        """Joint NDV of a key tuple: product of per-column NDVs capped by
        the row count (the standard independence upper bound). None when
        any key column has no statistics."""
        out = 1.0
        cap = max(self.rows, 1.0)
        for k in keys:
            cs = self.col(k)
            if cs is None:
                return None
            out *= max(cs.ndv, 1.0)
            if out >= cap:
                return cap
        return min(out, cap)

    def shard_rows(self, p: int) -> float:
        """Per-source-shard row estimate (exact max when known)."""
        if self.max_shard_rows is not None:
            return self.max_shard_rows
        return self.rows / max(p, 1)


def cap_rows(stats: TableStats, rows: float,
             keep: Sequence[str] | None = None) -> TableStats:
    """Derive propagated stats: new row count, per-column NDVs capped at
    it (a table of r rows has at most r distinct values per column), and
    optionally only the ``keep`` columns surviving."""
    rows = max(rows, 0.0)
    cols = []
    for k, cs in stats.columns:
        if keep is not None and k not in keep:
            continue
        cols.append((k, ColumnStats(min(cs.ndv, max(rows, 1.0)),
                                    cs.lo, cs.hi)))
    return TableStats(rows=rows, columns=tuple(cols), max_shard_rows=None)


# --------------------------------------------------------------------------
# bucket sizing (the Poisson skew model)
# --------------------------------------------------------------------------


def with_skew_margin(mean: float) -> int:
    """Slot budget for an expected occupancy of ``mean`` rows: the mean
    plus ~4 Poisson standard deviations plus a small-count floor. Tighter
    than a fixed multiple at scale, safe at small counts — and every
    consumer is backed by the overflow-retry path regardless.

    The ``stats.estimate`` fault site lives here: an armed fault derates
    the budget (divides by ``FaultPlan.factor``), modeling a badly wrong
    cardinality estimate — the chaos probe for the overflow-retry rung.
    """
    mean = max(mean, 0.0)
    budget = max(1, math.ceil(mean + 4.0 * math.sqrt(mean) + 4.0))
    from repro.core import faults as FLT

    fp = FLT.check("stats.estimate")
    if fp is not None:
        budget = max(1, int(budget // max(fp.factor, 1.0)))
    return budget


def size_bucket(source_rows: float, p: int, factor: float = 1.0) -> int:
    """Per-(source, dest) send-slot budget given ``source_rows`` rows per
    source shard hashed over ``p`` destinations. ``factor`` scales the
    mean for skew-prone placements (range partition: sampling error)."""
    return with_skew_margin(factor * max(source_rows, 0.0) / max(p, 1))


def size_output(rows: float, p: int, factor: float = 1.0) -> int:
    """Per-shard output budget for ``rows`` estimated global result rows
    hash-spread over ``p`` shards."""
    return with_skew_margin(factor * max(rows, 0.0) / max(p, 1))


# --------------------------------------------------------------------------
# the analysis pass (one vectorized sweep per table)
# --------------------------------------------------------------------------


def _sketch_one(col: jax.Array, valid: jax.Array):
    """(occupied-bitmap-count, min, max) of a 1-D key column as f32/i32
    scalars — traced; the host wrapper turns them into ColumnStats."""
    from repro.kernels import ops as kops

    h = kops.hash32(col, seed=5)
    b = jnp.where(valid, (h % jnp.uint32(SKETCH_BUCKETS)).astype(jnp.int32),
                  SKETCH_BUCKETS)
    occ = jnp.zeros((SKETCH_BUCKETS,), jnp.int32).at[b].set(1, mode="drop")
    filled = jnp.sum(occ)
    if jnp.issubdtype(col.dtype, jnp.floating):
        lo_s, hi_s = jnp.inf, -jnp.inf
    else:
        info = jnp.iinfo(col.dtype)
        lo_s, hi_s = info.max, info.min
    lo = jnp.min(jnp.where(valid, col, jnp.asarray(lo_s, col.dtype)))
    hi = jnp.max(jnp.where(valid, col, jnp.asarray(hi_s, col.dtype)))
    return filled, lo, hi


def linear_count(filled: int, rows: float,
                 buckets: int = SKETCH_BUCKETS) -> float:
    """Linear-counting NDV from bitmap occupancy, clamped to [0, rows]."""
    if rows <= 0 or filled <= 0:
        return 0.0
    if filled >= buckets:  # saturated sketch: every value looks distinct
        return float(rows)
    ndv = -buckets * math.log1p(-filled / buckets)
    return float(min(max(ndv, 1.0), rows))


def sketch_columns(columns: Mapping[str, jax.Array], valid: jax.Array,
                   names: Sequence[str]):
    """Traced sketch of ``names`` columns under ``valid``: name ->
    (filled, lo, hi). Composable under jit; host wrappers finish it."""
    return {n: _sketch_one(columns[n], valid) for n in names}


def analyze_table(table) -> TableStats:
    """Host-side TableStats of a local :class:`~repro.core.table.Table`
    (the same sweep ``DistContext.analyze`` runs over a global view)."""
    names = tuple(table.key_column_names)
    rows = int(table.row_count)

    sk = jax.jit(lambda cols, valid: sketch_columns(cols, valid, names))(
        {n: table.columns[n] for n in names}, table.valid_mask())
    cols = []
    for n in names:
        filled, lo, hi = sk[n]
        cols.append((n, ColumnStats(linear_count(int(filled), rows),
                                    float(np.asarray(lo)),
                                    float(np.asarray(hi)))))
    return TableStats(rows=float(rows), columns=tuple(cols),
                      max_shard_rows=float(rows))
