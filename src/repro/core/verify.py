"""Static plan verifier + dynamic collective auditor.

The optimizer (`repro.core.plan`) applies five interacting rewrite passes
— predicate/projection/limit pushdown, provenance-tag shuffle elision,
cost sizing + stage picking — and the plan cache replays whatever they
produce. Nothing in that pipeline re-checks that a rewritten plan is
still the plan the user wrote. This module is that check: a rule
registry of static invariants run over every (logical, optimized) pair,
failing loudly (``PlanVerificationError``) on violation. The rules
mirror the operator-algebra contract of the dataframe-pattern follow-up
(arXiv:2209.06146): each pattern's pre/post schema and partitioning laws
enforced mechanically.

Registered rules:

- ``schema``        — optimized output schema == logical output schema
                      (names, order, dtypes, trailing shapes).
- ``partitioning``  — every ``skip_*_shuffle`` elision is justified by a
                      matching hash/Range provenance tag derived
                      INDEPENDENTLY from the optimized tree (including
                      fingerprint provenance for range-range joins, and
                      a forged-fingerprint check across Scan tags).
- ``pushdown``      — rewrites never orphan a column reference: Select
                      predicates, projections, join keys, groupby keys,
                      sort/window keys all resolve against their input;
                      a Limit's non-Project descendant multiset is
                      unchanged (Project is the only node a Limit may
                      legally cross).
- ``cost-sizing``   — ``sized``/``out_sized`` marks imply estimates were
                      present AND the capacity is actually set; ``auto``
                      strategies are resolved; stage counts lie in
                      ``[1, MAX_SHUFFLE_STAGES]`` and never exceed the
                      bucket; ``cost_sized_stats_mask`` arity matches an
                      independently-maintained stats-arity table.
- ``idempotence``   — ``optimize(optimize(p))`` is a no-op and preserves
                      ``canonical_key`` (cache-key stability).

Verification is wired into ``optimize()`` behind the
``REPRO_VERIFY_PLANS`` env var (default-on under pytest via
``tests/conftest.py``); ``LazyFrame.explain(verify=True)`` appends the
findings, and ``DistContext.cache_stats()`` reports run/finding
counters.

The dynamic half, :func:`audit_collectives`, traces the fused shard_map
program and asserts the ACTUAL ``all_to_all``/``ppermute``/``all_gather``
counts in the jaxpr match the static accounting derived from
``plan_report`` records — the shared home of the jaxpr counting
``benchmarks/bench_shuffle.py`` previously did ad hoc.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from dataclasses import dataclass
from typing import Callable, Sequence

from repro.core import plan as PL
from repro.core import stats as S
from repro.core.repartition import (Partitioning, RangePartitioning,
                                    _chunk_bounds, range_prefix_matches)

ENV_FLAG = "REPRO_VERIFY_PLANS"


def verification_enabled() -> bool:
    """The ``REPRO_VERIFY_PLANS`` gate (default off; conftest turns it on
    for the test suite so every ``optimize()`` is checked)."""
    return os.environ.get(ENV_FLAG, "0").strip().lower() \
        not in ("", "0", "false", "off", "no")


# ---------------------------------------------------------------------------
# findings + counters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One invariant violation: the rule that fired, the offending node
    (short head form), and what broke."""

    rule: str
    node: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.node}: {self.message}"


class PlanVerificationError(AssertionError):
    """Raised by :func:`verify_or_raise`; carries the findings list."""

    def __init__(self, findings: Sequence[Finding]):
        self.findings = list(findings)
        lines = "\n".join(f"  - {f}" for f in self.findings)
        super().__init__(
            f"plan verification failed "
            f"({len(self.findings)} finding(s)):\n{lines}")


_counters_lock = threading.Lock()
_counters = {"verify_runs": 0, "verify_findings": 0}


def counter_snapshot() -> dict:
    """Verifier counters (merged into ``DistContext.cache_stats()``)."""
    with _counters_lock:
        return dict(_counters)


def reset_counters() -> None:
    with _counters_lock:
        for k in _counters:
            _counters[k] = 0


def _head(node) -> str:
    """Short display form of a node for findings: type + first key field."""
    name = type(node).__name__
    for attr in ("keys", "on", "by", "columns", "n", "slot"):
        v = getattr(node, attr, None)
        if v is not None:
            return f"{name}({attr}={v!r})"
    return name


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------


@dataclass
class _Check:
    """Everything a rule sees: the pre/post plans plus planning inputs."""

    logical: PL.Node
    optimized: PL.Node
    schemas: list
    p: int
    stats: list | None
    findings: list

    def add(self, rule: str, node, message: str) -> None:
        self.findings.append(Finding(rule, _head(node), message))


RULES: list[tuple[str, Callable]] = []


def rule(name: str):
    def deco(fn):
        RULES.append((name, fn))
        return fn
    return deco


# -- rule 1: schema preservation --------------------------------------------


@rule("schema")
def _check_schema(v: _Check) -> None:
    an = PL._Analysis(v.schemas)
    want = an.schema(v.logical)
    got = an.schema(v.optimized)
    if tuple(want) != tuple(got):
        missing = sorted(set(want) - set(got))
        extra = sorted(set(got) - set(want))
        v.add("schema", v.optimized,
              f"output columns changed: missing={missing} extra={extra} "
              f"order {tuple(want)} -> {tuple(got)}")
        return
    for k in want:
        a, b = want[k], got[k]
        if tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype:
            v.add("schema", v.optimized,
                  f"column {k!r} changed: {a.shape}/{a.dtype} -> "
                  f"{b.shape}/{b.dtype}")


# -- rule 2: partitioning soundness (elision justification) -----------------


def _derive_partitioning(v: _Check, an: PL._Analysis):
    """Re-derive placement tags bottom-up over the OPTIMIZED tree,
    independently of ``plan._elide``, and flag every skip flag / range
    alignment the derived tag does not justify. Output tags follow the
    STORED flags (what will execute), so an unjustified skip both fires a
    finding and poisons nothing downstream spuriously."""
    p = v.p

    def derive(node: PL.Node):
        if isinstance(node, PL.Scan):
            part = node.partitioning
            if part is not None and part.num_partitions != p:
                part = None
            return part
        if isinstance(node, (PL.Select, PL.Limit)):
            return derive(node.child)
        if isinstance(node, PL.Project):
            cp = derive(node.child)
            if cp is not None and set(cp.keys) <= set(node.columns):
                return cp
            return None
        if isinstance(node, PL.Repartition):
            cp = derive(node.child)
            target = Partitioning(node.keys, p, node.seed)
            if node.skip_shuffle and cp != target:
                v.add("partitioning", node,
                      f"skip_shuffle unjustified: input tag {cp} "
                      f"!= {target}")
            return target
        if isinstance(node, PL.Join):
            lp, rp = derive(node.left), derive(node.right)
            inner_ish = node.how in ("inner", "left")
            seed_used = node.seed if node.shuffle_seed is None \
                else node.shuffle_seed
            l_range = range_prefix_matches(lp, node.on)
            r_range = range_prefix_matches(rp, node.on)
            both_range = (l_range and r_range and lp == rp
                          and lp.fingerprint is not None)

            def hash_match(tag):
                return (isinstance(tag, Partitioning)
                        and tag.keys == node.on
                        and tag.num_partitions == p
                        and tag.seed == seed_used)

            if node.align is not None:
                anchor, anchor_skip, other_skip = (
                    (lp, node.skip_left_shuffle, node.skip_right_shuffle)
                    if node.align == "left"
                    else (rp, node.skip_right_shuffle,
                          node.skip_left_shuffle))
                ok = (node.align in ("left", "right") and anchor_skip
                      and not other_skip
                      and range_prefix_matches(anchor, node.on)
                      and node.align_keys == anchor.keys)
                if not ok:
                    v.add("partitioning", node,
                          f"range alignment unjustified: align={node.align} "
                          f"align_keys={node.align_keys}, anchor tag "
                          f"{anchor}")
            else:
                if node.skip_left_shuffle and not (both_range
                                                   or hash_match(lp)):
                    v.add("partitioning", node,
                          f"skip_left_shuffle unjustified by left tag {lp}")
                if node.skip_right_shuffle and not (both_range
                                                    or hash_match(rp)):
                    v.add("partitioning", node,
                          f"skip_right_shuffle unjustified by right tag "
                          f"{rp}")
            if node.align == "left":
                out = lp
            elif node.align == "right":
                out = rp
            elif (node.skip_left_shuffle and node.skip_right_shuffle
                  and isinstance(lp, RangePartitioning) and lp == rp):
                out = lp
            else:
                out = Partitioning(node.on, p, seed_used)
            return out if inner_ish else None
        if isinstance(node, PL.GroupBy):
            cp = derive(node.child)
            matches = ((isinstance(cp, Partitioning)
                        and cp.keys == node.keys
                        and cp.num_partitions == p)
                       or range_prefix_matches(cp, node.keys))
            if node.skip_shuffle and not matches:
                v.add("partitioning", node,
                      f"skip_shuffle unjustified by input tag {cp}")
            return cp if matches else Partitioning(node.keys, p, node.seed)
        if isinstance(node, (PL.Sort, PL.Window)):
            cp = derive(node.child)
            keys = node.by if isinstance(node, PL.Sort) \
                else node.by + node.order_by
            el = range_prefix_matches(cp, keys) or (
                isinstance(cp, RangePartitioning)
                and keys == cp.keys[:len(keys)])
            if node.skip_shuffle and not el:
                v.add("partitioning", node,
                      f"skip_shuffle unjustified by input tag {cp}")
            return cp if el else RangePartitioning(keys, p,
                                                   PL._range_fp(node))
        if isinstance(node, PL.SetOp):
            lp, rp = derive(node.left), derive(node.right)
            keys = tuple(sorted(an.schema(node.left)))
            target = Partitioning(keys, p, node.seed)
            if node.skip_left_shuffle and lp != target:
                v.add("partitioning", node,
                      f"skip_left_shuffle unjustified by left tag {lp}")
            if node.skip_right_shuffle and rp != target:
                v.add("partitioning", node,
                      f"skip_right_shuffle unjustified by right tag {rp}")
            return target
        if isinstance(node, PL.Distinct):
            cp = derive(node.child)
            keys = tuple(sorted(an.schema(node.child)))
            matches = (isinstance(cp, Partitioning) and cp.keys == keys) \
                or isinstance(cp, RangePartitioning)
            if node.skip_shuffle and not matches:
                v.add("partitioning", node,
                      f"skip_shuffle unjustified by input tag {cp}")
            return cp if matches else Partitioning(keys, p, node.seed)
        raise TypeError(node)

    derive(v.optimized)


def _scan_tags(root: PL.Node) -> dict[int, object]:
    """slot -> the partitioning tag its Scan nodes claim (every Scan of a
    slot must agree — one input table, one provenance)."""
    tags: dict[int, object] = {}
    conflicts: set[int] = set()

    def collect(n: PL.Node):
        if isinstance(n, PL.Scan):
            if n.slot in tags and tags[n.slot] != n.partitioning:
                conflicts.add(n.slot)
            tags[n.slot] = n.partitioning
        for c in PL.children(n):
            collect(c)

    collect(root)
    for s in conflicts:
        tags[s] = ("<conflicting>", s)
    return tags


@rule("partitioning")
def _check_partitioning(v: _Check) -> None:
    # Forged provenance: partitioning tags on Scans are INPUT facts (the
    # tag a materialized DistTable actually carries — fingerprints are
    # fresh unique tokens per table, so equal tags mean the same table).
    # The optimizer may consume them but must never invent or alter one:
    # a tag that appears in the optimized tree but not on the same slot
    # in the logical tree is forged, and would falsely authorize
    # zero-shuffle elisions (e.g. a skip-both range-range join).
    want, got = _scan_tags(v.logical), _scan_tags(v.optimized)
    for slot, tag in sorted(got.items()):
        if tag != want.get(slot):
            v.add("partitioning", v.optimized,
                  f"scan slot {slot} claims partitioning {tag} but the "
                  f"logical plan's input carries {want.get(slot)} — "
                  f"forged provenance")
    if v.p == 1:
        return  # every elision is the identity on a single shard
    _derive_partitioning(v, PL._Analysis(v.schemas))


# -- rule 3: pushdown legality (no orphaned column references) --------------


def _limit_contexts(root: PL.Node) -> list[tuple]:
    """Per-Limit (preorder) signature: (n, multiset of non-Project
    descendant node types). Only Project commutes with the global head-n
    (order- and count-preserving), so these signatures must survive
    optimization untouched."""
    out: list[tuple] = []

    def under(n: PL.Node, acc: dict) -> None:
        if not isinstance(n, PL.Project):
            name = type(n).__name__
            acc[name] = acc.get(name, 0) + 1
        for c in PL.children(n):
            under(c, acc)

    def walk(n: PL.Node) -> None:
        if isinstance(n, PL.Limit):
            acc: dict = {}
            under(n.child, acc)
            out.append((n.n, tuple(sorted(acc.items()))))
        for c in PL.children(n):
            walk(c)

    walk(root)
    return out


@rule("pushdown")
def _check_pushdown(v: _Check) -> None:
    an = PL._Analysis(v.schemas)

    def refs_ok(node, names, what: str, child) -> None:
        try:
            sch = set(an.schema(child))
        except KeyError as e:
            v.add("pushdown", node,
                  f"{what}: input schema unresolvable (missing column {e})")
            return
        missing = sorted(set(names) - sch)
        if missing:
            v.add("pushdown", node,
                  f"{what} references columns its input no longer has: "
                  f"{missing}")

    def walk(node: PL.Node) -> None:
        for c in PL.children(node):
            walk(c)
        if isinstance(node, PL.Select):
            if node.columns is not None:
                refs_ok(node, node.columns, "predicate footprint",
                        node.child)
        elif isinstance(node, PL.Project):
            refs_ok(node, node.columns, "projection", node.child)
        elif isinstance(node, PL.Join):
            refs_ok(node, node.on, "join key", node.left)
            refs_ok(node, node.on, "join key", node.right)
        elif isinstance(node, PL.GroupBy):
            cols = node.keys + tuple(c for c, _ in node.pairs)
            refs_ok(node, cols, "groupby", node.child)
        elif isinstance(node, PL.Sort):
            refs_ok(node, node.by, "sort key", node.child)
        elif isinstance(node, PL.Window):
            cols = node.by + node.order_by + tuple(
                c for _, c, _ in node.funcs if c is not None)
            refs_ok(node, cols, "window", node.child)
        elif isinstance(node, PL.SetOp):
            try:
                ls, rs = an.schema(node.left), an.schema(node.right)
            except KeyError:
                return  # already reported at the offending child
            if sorted(ls) != sorted(rs):
                v.add("pushdown", node,
                      f"set-op operand schemas diverge: {sorted(ls)} vs "
                      f"{sorted(rs)}")

    walk(v.optimized)
    before = _limit_contexts(v.logical)
    after = _limit_contexts(v.optimized)
    if before != after:
        v.add("pushdown", v.optimized,
              f"Limit crossed a non-Project node: descendant signatures "
              f"{before} -> {after}")


# -- rule 4: cost-sizing consistency ----------------------------------------

# Deliberately independent of plan._stats_arity: this table is the
# verifier's own record of how many ShuffleStats entries each node emits,
# so the two drifting apart is itself a finding.
_STATS_ARITY = {
    "Join": 2, "Union": 2, "Intersect": 2, "Difference": 2,
    "Limit": 1, "Repartition": 1, "GroupBy": 1, "Sort": 1, "Window": 1,
    "Distinct": 1,
    "Scan": 0, "Select": 0, "Project": 0,
}


def _expected_stats_arity(plan: PL.Node) -> int:
    total = _STATS_ARITY[type(plan).__name__]
    return total + sum(_expected_stats_arity(c) for c in PL.children(plan))


@rule("cost-sizing")
def _check_cost_sizing(v: _Check) -> None:
    have_stats = v.stats is not None and any(s is not None for s in v.stats)

    def walk(node: PL.Node) -> None:
        for c in PL.children(node):
            walk(c)
        if getattr(node, "sized", False):
            if not have_stats:
                v.add("cost-sizing", node,
                      "sized mark without any input statistics")
            if getattr(node, "bucket_capacity", None) is None:
                v.add("cost-sizing", node,
                      "sized mark but bucket_capacity is unset")
        if getattr(node, "out_sized", False):
            if not have_stats:
                v.add("cost-sizing", node,
                      "out_sized mark without any input statistics")
            if node.out_capacity is None:
                v.add("cost-sizing", node,
                      "out_sized mark but out_capacity is unset")
        if isinstance(node, PL.GroupBy) and node.strategy == "auto":
            v.add("cost-sizing", node,
                  "strategy 'auto' survived optimization unresolved")
        st = getattr(node, "stages", None)
        if st is not None:
            if not 1 <= st <= S.MAX_SHUFFLE_STAGES:
                v.add("cost-sizing", node,
                      f"stages={st} outside [1, {S.MAX_SHUFFLE_STAGES}]")
            bucket = getattr(node, "bucket_capacity", None)
            if bucket is not None and st > max(1, bucket):
                v.add("cost-sizing", node,
                      f"stages={st} exceeds bucket_capacity={bucket}")

    walk(v.optimized)
    mask = len(PL.cost_sized_stats_mask(v.optimized))
    want = _expected_stats_arity(v.optimized)
    if mask != want:
        v.add("cost-sizing", v.optimized,
              f"cost_sized_stats_mask arity {mask} != expected "
              f"ShuffleStats count {want}")


# -- rule 5: optimizer idempotence + cache-key stability --------------------


def _first_diff(a, b, path: str = "plan") -> str:
    if type(a) is not type(b):
        return f"{path}: {type(a).__name__} -> {type(b).__name__}"
    if isinstance(a, PL.Node):
        for f in dataclasses.fields(a):
            va, vb = getattr(a, f.name), getattr(b, f.name)
            if isinstance(va, PL.Node) or callable(va):
                continue
            if va != vb:
                return f"{path}.{f.name}: {va!r} -> {vb!r}"
        for i, (ca, cb) in enumerate(zip(PL.children(a), PL.children(b))):
            if ca != cb:
                return _first_diff(ca, cb, f"{path}[{i}]")
    return f"{path}: differs"


@rule("idempotence")
def _check_idempotence(v: _Check) -> None:
    reopt = PL.optimize(v.optimized, v.schemas, v.p, v.stats, verify=False)
    if reopt != v.optimized:
        v.add("idempotence", v.optimized,
              "optimize(optimize(p)) changed the plan: "
              + _first_diff(v.optimized, reopt))
    if PL.canonical_key(reopt) != PL.canonical_key(v.optimized):
        v.add("idempotence", v.optimized,
              "canonical_key not stable under re-optimization")


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def verify_plan(logical: PL.Node, optimized: PL.Node,
                input_schemas: Sequence[dict], num_shards: int,
                input_stats: Sequence | None = None) -> list[Finding]:
    """Run every registered rule; returns the findings (empty = clean).

    Total on arbitrary (even deliberately broken) plans: a rule that
    crashes contributes a finding instead of raising, so hand-mutated
    trees and fuzzer output are reported, never a stack trace.
    """
    v = _Check(logical, optimized, list(input_schemas), num_shards,
               None if input_stats is None else list(input_stats), [])
    for name, fn in RULES:
        try:
            fn(v)
        except Exception as e:  # noqa: BLE001 — a crashed rule IS a finding
            v.findings.append(Finding(name, type(e).__name__,
                                      f"rule crashed: {e!r}"))
    with _counters_lock:
        _counters["verify_runs"] += 1
        _counters["verify_findings"] += len(v.findings)
    return v.findings


def verify_or_raise(logical: PL.Node, optimized: PL.Node,
                    input_schemas: Sequence[dict], num_shards: int,
                    input_stats: Sequence | None = None) -> None:
    findings = verify_plan(logical, optimized, input_schemas, num_shards,
                           input_stats)
    if findings:
        raise PlanVerificationError(findings)


def format_findings(findings: Sequence[Finding]) -> str:
    """Human-readable block for ``explain(verify=True)``."""
    if not findings:
        return "verification: clean"
    lines = [f"verification: {len(findings)} finding(s)"]
    lines += [f"  - {f}" for f in findings]
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# collective accounting (shared with benchmarks) + the dynamic auditor
# ---------------------------------------------------------------------------

COLLECTIVES = ("all_to_all", "ppermute", "all_gather")


def count_collectives(jaxpr_text: str) -> dict[str, int]:
    """Collective-primitive counts in a printed jaxpr (``str(jax.make_jaxpr
    (...)(...))``). The one shared implementation behind the shuffle bench
    and :func:`audit_collectives`."""
    return {name: jaxpr_text.count(name + "[") for name in COLLECTIVES}


def _nchunks(width: int, stages: int) -> int:
    """Collectives ``staged_all_to_all`` issues for one ``(p, width)``
    buffer: one per chunk, and a single monolithic exchange when chunking
    degenerates (width 0/1 or stages <= 1)."""
    return max(1, len(_chunk_bounds(width, max(1, int(stages)))))


def _shuffle_collectives(rec: dict, p: int, exp: dict) -> None:
    """Fold one non-elided ``plan_report`` shuffle record into ``exp``,
    mirroring ``repartition``: per-column staged exchanges, the counts
    either riding a prepended slot of the 4-byte carrier column's first
    chunk or going out as one separate width-1 exchange."""
    if rec.get("elided"):
        return
    ncols, carrier = rec["columns"], rec["carrier"]
    bucket = rec["bucket"]
    if rec.get("mode", "alltoall") == "ring":
        # _ring_exchange: p-1 ppermute steps per buffer, stages ignored
        exp["ppermute"] += (ncols + (0 if carrier else 1)) * (p - 1)
        return
    stages = rec.get("stages") or 1
    if carrier:
        exp["all_to_all"] += ((ncols - 1) * _nchunks(bucket, stages)
                              + _nchunks(bucket + 1, stages))
    else:
        exp["all_to_all"] += ncols * _nchunks(bucket, stages) + 1


def _window_boundary_gathers(child_schema: dict, by, order_by, funcs) -> int:
    """How many all_gathers ``dist_window`` pays to stitch cross-shard
    groups: one per leaf of the window summary pytree (plus the lead
    summary when any func carries lead state). Counted by building the
    summaries abstractly (``jax.eval_shape``) over a tiny zero table of
    the child schema — exact, no device work."""
    import jax
    import jax.numpy as jnp

    from repro.core import ops_agg as A
    from repro.core.table import Table

    def build():
        cols = {k: jnp.zeros((4,) + tuple(s.shape), s.dtype)
                for k, s in child_schema.items()}
        t = Table(cols, jnp.asarray(4, jnp.int32))
        state = A.window_state(t, list(by), list(order_by))
        summ = A.window_summary(t, state, list(by), list(order_by), funcs)
        _, _, _, lead_req = A.carry_requirements(funcs)
        if lead_req:
            return summ, A.window_lead_summary(t, state, list(by), funcs)
        return (summ,)

    return len(jax.tree.leaves(jax.eval_shape(build)))


def expected_collectives(plan: PL.Node, input_schemas: Sequence[dict],
                         num_shards: int, report: Sequence[dict]) -> dict:
    """Static collective counts for an OPTIMIZED plan from its
    ``plan_report`` records: the exchange decomposition per shuffle, plus
    the gather sites the executor pays outside ``repartition`` (limit
    quotas, sort/window splitter samples, join range alignment, window
    boundary carries)."""
    p = num_shards
    an = PL._Analysis(input_schemas)
    exp = {name: 0 for name in COLLECTIVES}
    recs = list(report)
    pos = 0
    seen: set[int] = set()  # execute_plan memoizes shared subtrees by id

    def take(node: PL.Node) -> dict:
        nonlocal pos
        if pos >= len(recs):
            raise ValueError(
                f"plan_report exhausted at {type(node).__name__} — static "
                f"accounting and plan walk disagree")
        rec = recs[pos]
        pos += 1
        return rec

    def walk(node: PL.Node) -> None:
        if id(node) in seen:
            return
        seen.add(id(node))
        for c in PL.children(node):
            walk(c)
        if isinstance(node, PL.Limit):
            take(node)  # limit's record carries no exchange
            if p > 1:
                exp["all_gather"] += 1  # per-shard valid-count gather
        elif isinstance(node, PL.Join):
            _shuffle_collectives(take(node), p, exp)  # join.left
            _shuffle_collectives(take(node), p, exp)  # join.right
            if node.align is not None and p > 1:
                # _range_align_pid: one boundary gather per align key
                exp["all_gather"] += len(node.align_keys)
        elif isinstance(node, PL.SetOp):
            _shuffle_collectives(take(node), p, exp)
            _shuffle_collectives(take(node), p, exp)
        elif isinstance(node, PL.Sort):
            _shuffle_collectives(take(node), p, exp)
            if not node.skip_shuffle and p > 1:
                # _lex_splitter_pids: one sample gather per key column
                exp["all_gather"] += len(node.by)
        elif isinstance(node, PL.Window):
            _shuffle_collectives(take(node), p, exp)
            if not node.skip_shuffle and p > 1:
                exp["all_gather"] += len(node.by + node.order_by)
            if p > 1:
                exp["all_gather"] += _window_boundary_gathers(
                    an.schema(node.child), node.by, node.order_by,
                    node.funcs)
        elif isinstance(node, (PL.Repartition, PL.GroupBy, PL.Distinct)):
            _shuffle_collectives(take(node), p, exp)

    walk(plan)
    if pos != len(recs):
        raise ValueError(
            f"{len(recs) - pos} unconsumed plan_report record(s) — static "
            f"accounting and plan walk disagree")
    return exp


def audit_collectives(frame, *, strict: bool = False) -> dict:
    """Dynamic cross-check: trace the frame's fused program and compare the
    jaxpr's actual collective counts against :func:`expected_collectives`'
    static accounting of the same optimized plan.

    Returns ``{"expected", "actual", "matched", "report"}``; with
    ``strict=True`` a mismatch raises :class:`PlanVerificationError`.
    Trace-only (``jax.make_jaxpr``): no data moves, nothing executes.
    """
    import jax

    ctx = frame._ctx
    plan = frame.optimized()
    report: list[dict] = []

    def body(*tables):
        return PL.execute_plan(plan, tables, axis_name=ctx.axis_name,
                               num_shards=ctx.num_shards, report=report)

    args = tuple((t.columns, t.row_counts) for t in frame._inputs)
    jaxpr_text = str(jax.make_jaxpr(ctx._make_global(body))(*args))
    actual = count_collectives(jaxpr_text)
    expected = expected_collectives(
        plan, [t.schema for t in frame._inputs], ctx.num_shards, report)
    result = {"expected": expected, "actual": actual,
              "matched": expected == actual, "report": report}
    if strict and not result["matched"]:
        raise PlanVerificationError([Finding(
            "collective-audit", _head(plan),
            f"traced collectives {actual} != static accounting "
            f"{expected}")])
    return result
