"""LazyFrame — the user-facing lazy relational builder (paper §II composed).

``ctx.frame(t).select(...).join(...).groupby(...).collect()`` records a
logical plan (``repro.core.plan``) instead of executing operator by
operator. ``collect()`` optimizes the plan (predicate/projection pushdown,
shuffle elision from Partitioning tags) and compiles it into ONE
``shard_map`` body run through a single jitted dispatch — so an N-operator
ETL chain pays one launch and no full-capacity DistTable intermediates,
with the canonicalized plan as the jit-cache key (a pipeline re-collected
every step compiles exactly once).

The eager ``DistContext`` methods remain available and byte-compatible;
they run one-node plans through the same compiler. A frame and an eager
result interoperate freely: ``ctx.frame(eager_result)`` picks up the
result's Partitioning tag, so e.g. a groupby chained after a join on the
same key elides its shuffle (the co-partitioned fast path).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax

from repro.core import ops_agg as A
from repro.core import plan as PL
from repro.core.context import DistContext, DistTable
from repro.core.table import Table


class LazyFrame:
    """A deferred relational expression over one or more DistTables."""

    def __init__(self, ctx: DistContext, plan: PL.Node,
                 inputs: tuple[DistTable, ...]):
        self._ctx = ctx
        self._plan = plan
        self._inputs = tuple(inputs)

    # -- construction ---------------------------------------------------------
    @classmethod
    def scan(cls, ctx: DistContext, table: Table | DistTable) -> "LazyFrame":
        if isinstance(table, Table):
            table = ctx.scatter(table)
        return cls(ctx, PL.Scan(0, partitioning=table.partitioning), (table,))

    def _chain(self, plan: PL.Node) -> "LazyFrame":
        return LazyFrame(self._ctx, plan, self._inputs)

    def _lift(self, other) -> "LazyFrame":
        if isinstance(other, LazyFrame):
            assert other._ctx is self._ctx, "frames must share a DistContext"
            return other
        return LazyFrame.scan(self._ctx, other)

    def _merge(self, other: "LazyFrame"):
        """Union the two input lists (dedup by table identity) and remap the
        other plan's Scan slots into the merged numbering."""
        inputs = list(self._inputs)
        mapping = {}
        for i, t in enumerate(other._inputs):
            for j, s in enumerate(inputs):
                if s is t:
                    mapping[i] = j
                    break
            else:
                mapping[i] = len(inputs)
                inputs.append(t)
        return tuple(inputs), PL.remap_scans(other._plan, mapping)

    # -- operators (each returns a new frame) ---------------------------------
    def select(self, predicate: Callable[[dict], jax.Array], *, key=None
               ) -> "LazyFrame":
        """Filter rows. ``key``: hashable cache key for the predicate —
        required for the fused program to be jit-cached across calls, and
        it must cover any values the predicate captures (closure state is
        invisible to the cache; predicate CODE is fingerprinted)."""
        return self._chain(PL.Select(self._plan, predicate, key=key))

    def project(self, columns: Sequence[str]) -> "LazyFrame":
        return self._chain(PL.Project(self._plan, tuple(columns)))

    def limit(self, n: int) -> "LazyFrame":
        """True global head(n): exactly the first ``min(n, total)`` rows in
        shard order — the global top-n after :meth:`sort`. A counts
        prefix-scan inside the fused program assigns each shard its take
        quota (one int32 per shard on the wire, no AllToAll)."""
        return self._chain(PL.Limit(self._plan, int(n)))

    def partition_by(self, keys, *, seed: int = 7, bucket_capacity=None,
                     stages: int | None = None,
                     shuffle_mode: str = "alltoall") -> "LazyFrame":
        keys_t = (keys,) if isinstance(keys, str) else tuple(keys)
        return self._chain(PL.Repartition(self._plan, keys_t, seed=seed,
                                          bucket_capacity=bucket_capacity,
                                          stages=stages,
                                          shuffle_mode=shuffle_mode))

    def join(self, other, on, *, how: str = "inner", algorithm: str = "sort",
             bucket_capacity=None, out_capacity=None, seed: int = 7,
             stages: int | None = None, shuffle_mode: str = "alltoall"
             ) -> "LazyFrame":
        other = self._lift(other)
        inputs, rplan = self._merge(other)
        on_t = (on,) if isinstance(on, str) else tuple(on)
        node = PL.Join(self._plan, rplan, on_t, how=how, algorithm=algorithm,
                       bucket_capacity=bucket_capacity,
                       out_capacity=out_capacity, seed=seed,
                       stages=stages, shuffle_mode=shuffle_mode)
        return LazyFrame(self._ctx, node, inputs)

    def groupby(self, keys, aggs, *, strategy: str = "auto",
                bucket_capacity=None, partial_capacity=None,
                out_capacity=None, seed: int = 7, stages: int | None = None,
                shuffle_mode: str = "alltoall") -> "LazyFrame":
        """Keyed aggregation. ``strategy='auto'`` (default) defers the
        shuffle-vs-two-phase choice to the optimizer's cost model: with
        input stats (``ctx.analyze``) it compares estimated wire rows
        (``rows`` vs ``shards * key NDV``, the arXiv:2010.14596
        crossover) and right-sizes the AllToAll bucket; without stats it
        resolves to the documented ``two_phase`` fallback."""
        keys_t = (keys,) if isinstance(keys, str) else tuple(keys)
        pairs = A.normalize_aggs(aggs)
        node = PL.GroupBy(self._plan, keys_t, pairs, strategy=strategy,
                          bucket_capacity=bucket_capacity,
                          partial_capacity=partial_capacity,
                          out_capacity=out_capacity, seed=seed,
                          stages=stages, shuffle_mode=shuffle_mode)
        return self._chain(node)

    def sort(self, by, *, bucket_capacity=None, samples_per_shard: int = 64,
             stages: int | None = None, shuffle_mode: str = "alltoall"
             ) -> "LazyFrame":
        """Global sort (range partition + local sort). The optimizer tracks
        the output's :class:`~repro.core.repartition.RangePartitioning`, so
        a downstream sort/groupby on a key prefix elides its shuffle and a
        downstream join range-aligns its other side (one AllToAll, not
        two)."""
        by_t = (by,) if isinstance(by, str) else tuple(by)
        return self._chain(PL.Sort(self._plan, by_t,
                                   bucket_capacity=bucket_capacity,
                                   samples_per_shard=samples_per_shard,
                                   stages=stages,
                                   shuffle_mode=shuffle_mode))

    def window(self, by, funcs, *, order_by=(), bucket_capacity=None,
               samples_per_shard: int = 64, stages: int | None = None,
               shuffle_mode: str = "alltoall") -> "LazyFrame":
        """Window functions over (by, order_by)-sorted segments —
        row-preserving analytics: ``rank``, ``dense_rank``,
        ``row_number``, ``lag``/``lead`` (offsets via ``("lag", col,
        k)``), ``cumsum``, ``cummax``, ``running_mean``. Result columns
        are appended (``rank``, ``{col}_cumsum``, ...) and rows come back
        in (by, order_by) order.

        Lowering mirrors :meth:`sort`: an unsorted input pays ONE range-
        partition AllToAll; an input the optimizer can prove range-
        partitioned on a (by + order_by) prefix — e.g. a preceding
        ``.sort(...)`` — elides it entirely and pays only a p-sized
        boundary ``all_gather`` for the cross-shard group carries."""
        by_t = (by,) if isinstance(by, str) else tuple(by)
        order_t = (order_by,) if isinstance(order_by, str) \
            else tuple(order_by)
        pairs = A.normalize_funcs(funcs)
        return self._chain(PL.Window(self._plan, by_t, order_t, pairs,
                                     bucket_capacity=bucket_capacity,
                                     samples_per_shard=samples_per_shard,
                                     stages=stages,
                                     shuffle_mode=shuffle_mode))

    def union(self, other, *, bucket_capacity=None, seed: int = 7,
              stages: int | None = None, shuffle_mode: str = "alltoall"
              ) -> "LazyFrame":
        other = self._lift(other)
        inputs, rplan = self._merge(other)
        return LazyFrame(self._ctx, PL.Union(
            self._plan, rplan, bucket_capacity=bucket_capacity, seed=seed,
            stages=stages, shuffle_mode=shuffle_mode), inputs)

    def intersect(self, other, *, bucket_capacity=None, seed: int = 7,
                  stages: int | None = None, shuffle_mode: str = "alltoall"
                  ) -> "LazyFrame":
        other = self._lift(other)
        inputs, rplan = self._merge(other)
        return LazyFrame(self._ctx, PL.Intersect(
            self._plan, rplan, bucket_capacity=bucket_capacity, seed=seed,
            stages=stages, shuffle_mode=shuffle_mode), inputs)

    def difference(self, other, *, mode: str = "symmetric",
                   bucket_capacity=None, seed: int = 7,
                   stages: int | None = None,
                   shuffle_mode: str = "alltoall") -> "LazyFrame":
        other = self._lift(other)
        inputs, rplan = self._merge(other)
        return LazyFrame(self._ctx, PL.Difference(
            self._plan, rplan, bucket_capacity=bucket_capacity, seed=seed,
            mode=mode, stages=stages, shuffle_mode=shuffle_mode), inputs)

    def distinct(self, *, bucket_capacity=None, seed: int = 7,
                 stages: int | None = None, shuffle_mode: str = "alltoall"
                 ) -> "LazyFrame":
        return self._chain(PL.Distinct(self._plan,
                                       bucket_capacity=bucket_capacity,
                                       seed=seed, stages=stages,
                                       shuffle_mode=shuffle_mode))

    # -- introspection --------------------------------------------------------
    @property
    def schema(self) -> dict[str, jax.ShapeDtypeStruct]:
        an = PL._Analysis([t.schema for t in self._inputs])
        return an.schema(self._plan)

    def logical_plan(self) -> PL.Node:
        return self._plan

    def optimized(self) -> PL.Node:
        """The plan after all optimizer passes (what collect() executes),
        including the cost model's strategy/capacity choices when any
        input carries TableStats (``ctx.analyze``)."""
        return PL.optimize(self._plan, [t.schema for t in self._inputs],
                           self._ctx.num_shards,
                           [t.stats for t in self._inputs])

    def explain(self, *, optimize: bool = True, verify: bool = False,
                recovery: bool = False) -> str:
        """The plan tree, one node per line. On an optimized plan every
        potential shuffle is marked ``alltoall``/``elided``; when inputs
        carry stats each node is annotated with estimated rows and any
        cost-model-chosen capacities (``bucket=``, ``out=``,
        ``cost-sized``) — the audit trail for the physical plan.

        ``verify=True`` additionally runs the static plan verifier over
        the (logical, optimized) pair and appends its findings (or
        ``verification: clean``) — unlike the ``REPRO_VERIFY_PLANS``
        gate, this REPORTS instead of raising, so a broken rewrite can
        be inspected. ``recovery=True`` annotates each node with the
        degradation rungs the retry ladder would apply on failure
        (``repro.core.faults``)."""
        schemas = [t.schema for t in self._inputs]
        stats = [t.stats for t in self._inputs]
        if not optimize:
            return PL.explain(self._plan, schemas, stats,
                              recovery=recovery)
        # verify=False here: explain must render findings, not raise them
        plan = PL.optimize(self._plan, schemas, self._ctx.num_shards,
                           stats, verify=False)
        text = PL.explain(plan, schemas, stats, recovery=recovery)
        if verify:
            from repro.core import verify as V

            findings = V.verify_plan(self._plan, plan, schemas,
                                     self._ctx.num_shards, stats)
            text += "\n" + V.format_findings(findings)
        return text

    def plan_report(self) -> list[dict]:
        """Static shuffle accounting of the optimized plan — one record per
        potential AllToAll (elided flag, bucket, bytes/row, dense wire
        bytes). Dry-runs the compiled body under ``jax.eval_shape``; no
        data moves and nothing executes."""
        ctx = self._ctx
        plan = self.optimized()
        report: list[dict] = []

        def body(*tables):
            return PL.execute_plan(plan, tables, axis_name=ctx.axis_name,
                                   num_shards=ctx.num_shards, report=report)

        args = tuple((t.columns, t.row_counts) for t in self._inputs)
        jax.eval_shape(ctx._make_global(body), *args)
        return report

    # -- execution ------------------------------------------------------------
    def collect_with_stats(self):
        """Run the fused program; returns (DistTable, per-shuffle stats)."""
        return self._ctx._run_plan(self._plan, self._inputs, optimize=True)

    def collect(self) -> DistTable:
        """Optimize + compile + run the whole chain as one shard_map program."""
        out, _ = self.collect_with_stats()
        return out

    def collect_async(self):
        """Async dispatch: enqueue the fused program and return a
        :class:`~repro.core.context.PlanFuture` immediately — no host
        sync, not even the cost-sized overflow check (verified deferred,
        at ``future.result()`` or folded into a later dispatch). N
        clients submitting through one context overlap their host-side
        planning with each other's device execution and share the
        context's plan cache; results are bit-identical to sequential
        ``collect()`` calls."""
        return self._ctx.submit(self._plan, self._inputs, optimize=True)
