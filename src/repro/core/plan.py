"""Lazy logical-plan IR + fusing optimizer: one shard_map program per pipeline.

Cylon's core claim (paper §II) is that relational operators *compose* into a
single efficient distributed program; the follow-up operator-pattern algebra
(arXiv:2209.06146) makes that composition explicit. This module is that
composition layer for the JAX adaptation: a small IR of relational nodes, a
rule-based optimizer, and a compiler that evaluates the whole optimized plan
inside ONE ``shard_map`` body — so a four-operator ETL chain is one XLA
dispatch, not four, with no full-capacity ``DistTable`` materialization
between operators.

Optimizer passes (applied in order by :func:`optimize`):

1. **Predicate column probing** — run each ``Select`` predicate once over
   tiny zero-filled columns behind a recording mapping to learn which
   columns it reads (its pushdown footprint). Predicates that defeat the
   probe are conservatively pinned in place.
2. **Predicate pushdown** — move a ``Select`` below ``Project``/``Sort``/
   ``Repartition`` and into the side of a ``Join`` whose columns it reads
   (inner/left joins push left, inner/right push right), so rows are
   dropped *before* they cross the AllToAll.
3. **Projection pushdown** — insert ``Project`` nodes under every shuffle
   boundary (join/groupby/sort/repartition inputs) keeping only the columns
   the rest of the plan consumes, shrinking bytes/row on the wire.
4. **Shuffle elision** — thread :class:`~repro.core.repartition.Partitioning`
   and :class:`~repro.core.repartition.RangePartitioning` tags bottom-up; an
   input already hash-partitioned on an operator's keys (same seed, same
   modulus) has its AllToAll elided, and a range-partitioned input (sort
   output) satisfies a downstream Sort/GroupBy/Join on a key prefix the
   same way — a join additionally range-ALIGNS its other side to the
   sorted side's boundaries (one AllToAll instead of two). A single-shard
   mesh elides every shuffle (hash to one partition is the identity).
5. **Cost model** (``repro.core.stats``) — per-operator cardinality
   estimators propagate :class:`~repro.core.stats.TableStats` (row
   counts, per-key NDV sketches) from analyzed inputs through the plan;
   the cost pass then (a) resolves each GroupBy's ``strategy="auto"`` to
   ``shuffle`` vs ``two_phase`` by comparing estimated shuffle rows
   (``rows`` vs ``num_shards * key NDV`` — the arXiv:2010.14596
   crossover), (b) right-sizes every unset ``bucket_capacity`` /
   ``out_capacity`` from estimated occupancy instead of the fixed
   ``FALLBACK_SLACK`` multiple of table capacity, and (c) marks those
   nodes ``sized`` so the runtime knows an overflow means *estimate was
   wrong* and triggers one recompile-with-conservative-capacity retry
   (``DistContext._run_plan``) rather than wrong results. Without input
   statistics the pass only resolves ``auto`` strategies (to the
   documented ``two_phase`` fallback) and the executor's
   ``FALLBACK_SLACK`` sizing applies — byte-compatible with the
   pre-cost-model behavior.

``Limit`` is a true global head-n (a counts prefix-scan inside the fused
body assigns each shard its take quota), not a per-shard truncation; the
optimizer pushes it below order-preserving ``Project`` so truncation
happens before wide-row work.

The canonicalized plan (:func:`canonical_key`) is the jit-cache key, so a
pipeline re-collected every training step compiles exactly once.
"""
from __future__ import annotations

import dataclasses
import types
from dataclasses import dataclass, field, replace
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import ops_agg as A
from repro.core import ops_dist as D
from repro.core import ops_local as L
from repro.core import stats as S
from repro.core.repartition import (Partitioning, RangePartitioning,
                                    default_bucket_capacity,
                                    range_prefix_matches)
from repro.core.table import Table

# ---------------------------------------------------------------------------
# IR nodes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Node:
    """Base class of plan IR nodes (immutable, structurally comparable)."""


@dataclass(frozen=True)
class Scan(Node):
    """Leaf: the ``slot``-th input DistTable of the compiled program."""

    slot: int
    partitioning: Partitioning | RangePartitioning | None = None


@dataclass(frozen=True)
class Select(Node):
    """Row filter by a user predicate over the columns dict.

    ``key``: user-supplied hashable cache key for the predicate — without
    it the plan cannot be canonicalized and recompiles on every execution
    (the pre-existing eager ``ctx.select`` behaviour, now opt-out).
    ``columns``: the predicate's probed column footprint (filled by the
    optimizer; None = unknown, treat as reading everything).
    """

    child: Node
    predicate: Callable = field(compare=False)
    key: object = None
    columns: tuple[str, ...] | None = None


@dataclass(frozen=True)
class Project(Node):
    child: Node
    columns: tuple[str, ...]


@dataclass(frozen=True)
class Limit(Node):
    """True global head(n): a counts prefix-scan over the shuffle axis
    assigns each shard a take quota summing to min(n, total rows) — the
    first n rows in shard order, i.e. the global top-n after a Sort."""

    child: Node
    n: int


@dataclass(frozen=True)
class Repartition(Node):
    """Explicit hash repartition on ``keys`` — pre-partition once so later
    joins/groupbys on the same keys (and seed) elide their shuffles."""

    child: Node
    keys: tuple[str, ...]
    seed: int = 7
    bucket_capacity: int | None = None
    skip_shuffle: bool = False
    sized: bool = False  # bucket filled in by the cost model (estimate!)
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


@dataclass(frozen=True)
class Join(Node):
    left: Node
    right: Node
    on: tuple[str, ...]
    how: str = "inner"
    algorithm: str = "sort"
    bucket_capacity: int | None = None
    out_capacity: int | None = None
    seed: int = 7
    shuffle_seed: int | None = None  # resolved by the optimizer
    skip_left_shuffle: bool = False
    skip_right_shuffle: bool = False
    # range fast path (set by the optimizer): the named side is range-
    # partitioned on align_keys (a prefix of `on`); the other side is
    # range-ALIGNED to its boundaries instead of hash-shuffled.
    align: str | None = None          # None | "left" | "right"
    align_keys: tuple[str, ...] | None = None
    sized: bool = False      # bucket filled by the cost model (estimate!)
    out_sized: bool = False  # out_capacity filled by the cost model —
    # tracked separately so a USER-set out_capacity (deliberate
    # truncation, surfaced in stats) is never treated as a bad estimate
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


@dataclass(frozen=True)
class GroupBy(Node):
    child: Node
    keys: tuple[str, ...]
    pairs: tuple[tuple[str, str], ...]  # normalized (col, op) aggregations
    # "auto" defers the shuffle-vs-two-phase choice to the cost model
    # (arXiv:2010.14596: the winner flips with key cardinality); resolved
    # to a concrete strategy by the cost pass before execution —
    # "two_phase" when no statistics are available.
    strategy: str = "auto"
    bucket_capacity: int | None = None
    partial_capacity: int | None = None
    out_capacity: int | None = None
    seed: int = 7
    shuffle_seed: int | None = None
    skip_shuffle: bool = False
    sized: bool = False  # bucket filled in by the cost model (estimate!)
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


@dataclass(frozen=True)
class Sort(Node):
    child: Node
    by: tuple[str, ...]
    bucket_capacity: int | None = None
    samples_per_shard: int = 64
    skip_shuffle: bool = False
    sized: bool = False  # bucket filled in by the cost model (estimate!)
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


@dataclass(frozen=True)
class Window(Node):
    """Row-preserving window functions over (by, order_by)-sorted segments.

    Lowers to ``ops_dist.dist_window``: range partition on (by + order_by)
    — the dist_sort placement — then per-shard segment scans with a
    boundary-carry all_gather (never an AllToAll). An input already
    range-partitioned on a (by + order_by) prefix (a Sort output, or a
    previous Window) elides the shuffle entirely: the optimizer's prefix
    rules apply exactly as they do to Sort. ``funcs`` is the canonical
    ``ops_agg.normalize_funcs`` tuple.
    """

    child: Node
    by: tuple[str, ...]
    order_by: tuple[str, ...]
    funcs: tuple[tuple, ...]
    bucket_capacity: int | None = None
    samples_per_shard: int = 64
    skip_shuffle: bool = False
    sized: bool = False  # bucket filled in by the cost model (estimate!)
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


@dataclass(frozen=True)
class SetOp(Node):
    """Shared shape of the whole-row-hash binary operators."""

    left: Node
    right: Node
    bucket_capacity: int | None = None
    seed: int = 7
    mode: str = "symmetric"  # Difference only
    skip_left_shuffle: bool = False
    skip_right_shuffle: bool = False
    sized: bool = False  # bucket filled in by the cost model (estimate!)
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


@dataclass(frozen=True)
class Union(SetOp):
    pass


@dataclass(frozen=True)
class Intersect(SetOp):
    pass


@dataclass(frozen=True)
class Difference(SetOp):
    pass


@dataclass(frozen=True)
class Distinct(Node):
    child: Node
    bucket_capacity: int | None = None
    seed: int = 7
    skip_shuffle: bool = False
    sized: bool = False  # bucket filled in by the cost model (estimate!)
    stages: int | None = None  # shuffle pipeline depth (None = cost pick)
    shuffle_mode: str = "alltoall"


def children(node: Node) -> tuple[Node, ...]:
    if isinstance(node, Scan):
        return ()
    if isinstance(node, (Join, SetOp)):
        return (node.left, node.right)
    return (node.child,)


def _with_children(node: Node, kids: Sequence[Node]) -> Node:
    if isinstance(node, Scan):
        return node
    if isinstance(node, (Join, SetOp)):
        return replace(node, left=kids[0], right=kids[1])
    return replace(node, child=kids[0])


def remap_scans(node: Node, mapping: dict[int, int]) -> Node:
    """Renumber Scan slots (merging two frames' input lists into one)."""
    if isinstance(node, Scan):
        return replace(node, slot=mapping[node.slot])
    return _with_children(node, [remap_scans(c, mapping)
                                 for c in children(node)])


# ---------------------------------------------------------------------------
# schema inference
# ---------------------------------------------------------------------------

JOIN_SUFFIX = "_r"  # ops_local.join's clash suffix, mirrored here


class _Analysis:
    """Memoized per-node output schema (name -> ShapeDtypeStruct of one row's
    trailing shape). Memo keys are node identities; node refs are held so
    ids cannot be recycled mid-pass."""

    def __init__(self, input_schemas: Sequence[dict]):
        self.inputs = [dict(s) for s in input_schemas]
        self._memo: dict[int, tuple[Node, dict]] = {}

    def schema(self, node: Node) -> dict:
        hit = self._memo.get(id(node))
        if hit is not None and hit[0] is node:
            return hit[1]
        out = self._schema(node)
        self._memo[id(node)] = (node, out)
        return out

    def _schema(self, node: Node) -> dict:
        if isinstance(node, Scan):
            return dict(self.inputs[node.slot])
        if isinstance(node, Project):
            ch = self.schema(node.child)
            return {k: ch[k] for k in node.columns}
        if isinstance(node, Join):
            lsch = self.schema(node.left)
            rsch = self.schema(node.right)
            out = dict(lsch)
            for k, v in rsch.items():
                out[k + JOIN_SUFFIX if k in lsch else k] = v
            return out
        if isinstance(node, GroupBy):
            ch = self.schema(node.child)
            out = {k: ch[k] for k in node.keys}
            f32 = jnp.dtype(jnp.float32)
            for col, op in node.pairs:
                base = ch[col]
                if op in ("mean", "var"):
                    sds = jax.ShapeDtypeStruct(base.shape, f32)
                elif op == "count":
                    sds = jax.ShapeDtypeStruct((), jnp.dtype(jnp.int32))
                else:
                    sds = base
                out[f"{col}_{op}"] = sds
            return out
        if isinstance(node, Window):
            out = dict(self.schema(node.child))
            i32 = jnp.dtype(jnp.int32)
            f32 = jnp.dtype(jnp.float32)
            for fn, col, off in node.funcs:
                name = A.window_output_name(fn, col, off)
                if col is None:  # rank / dense_rank / row_number
                    sds = jax.ShapeDtypeStruct((), i32)
                elif fn == "running_mean":
                    sds = jax.ShapeDtypeStruct((), f32)
                else:  # lag / lead / cumsum / cummax keep the input dtype
                    sds = out[col]
                out[name] = sds
            return out
        # Select / Limit / Sort / Distinct / Repartition / set ops: unchanged
        return dict(self.schema(children(node)[0]))


# ---------------------------------------------------------------------------
# optimizer pass 1: predicate column probing
# ---------------------------------------------------------------------------


class _RecordingColumns(dict):
    """Columns dict that records which names a predicate reads."""

    def __init__(self, cols: dict):
        super().__init__(cols)
        self.accessed: set[str] = set()

    def __getitem__(self, k):
        self.accessed.add(k)
        return super().__getitem__(k)

    def get(self, k, default=None):
        self.accessed.add(k)
        return super().get(k, default)


def probe_predicate(predicate: Callable, schema: dict) -> tuple[str, ...] | None:
    """Learn a predicate's column footprint by running it over zeros.

    Returns the sorted accessed-column tuple, or None when the probe fails
    (exception, or no recorded access — e.g. the predicate iterates the
    dict), which pins the Select in place during pushdown.
    """
    cols = _RecordingColumns({
        k: jnp.zeros((2,) + tuple(s.shape), s.dtype) for k, s in schema.items()
    })
    try:
        out = predicate(cols)
        _ = jnp.shape(out)  # must be array-like
    except Exception:  # noqa: BLE001 — any failure disables pushdown only
        return None
    return tuple(sorted(cols.accessed)) or None


def _annotate_selects(node: Node, an: _Analysis) -> Node:
    kids = [_annotate_selects(c, an) for c in children(node)]
    node = _with_children(node, kids)
    if isinstance(node, Select) and node.columns is None:
        cols = probe_predicate(node.predicate, an.schema(node.child))
        if cols is not None:
            node = replace(node, columns=cols)
    return node


# ---------------------------------------------------------------------------
# optimizer pass 2: predicate pushdown (filter before shuffle)
# ---------------------------------------------------------------------------


def _pushdown_selects(node: Node, an: _Analysis) -> Node:
    kids = [_pushdown_selects(c, an) for c in children(node)]
    node = _with_children(node, kids)
    if not isinstance(node, Select) or node.columns is None:
        return node
    refs = set(node.columns)
    ch = node.child
    if isinstance(ch, Project) and refs <= set(ch.columns):
        return replace(ch, child=_pushdown_selects(
            replace(node, child=ch.child), an))
    if isinstance(ch, (Sort, Repartition)):
        return replace(ch, child=_pushdown_selects(
            replace(node, child=ch.child), an))
    if isinstance(ch, Join):
        lnames = set(an.schema(ch.left))
        rnames = set(an.schema(ch.right))
        # pushing a one-sided filter through an outer join changes which
        # rows of the OTHER side surface as unmatched — only inner/left
        # joins admit a left push, inner/right a right push.
        if refs <= lnames and ch.how in ("inner", "left"):
            return replace(ch, left=_pushdown_selects(
                replace(node, child=ch.left), an))
        if refs <= rnames and not (refs & lnames) and ch.how in ("inner",
                                                                 "right"):
            return replace(ch, right=_pushdown_selects(
                replace(node, child=ch.right), an))
    return node


# ---------------------------------------------------------------------------
# optimizer pass 2b: limit pushdown (truncate before wide-row work)
# ---------------------------------------------------------------------------


def _pushdown_limits(node: Node) -> Node:
    """``Limit(Project(x)) -> Project(Limit(x))``: Project preserves row
    order and count, so the global head-n commutes with it — the take
    quota is computed (and rows dropped) before any wide-row work above.
    Project is the ONLY order-preserving rewrite target: Select changes
    row membership, Sort/Repartition change placement/order."""
    kids = [_pushdown_limits(c) for c in children(node)]
    node = _with_children(node, kids)
    if isinstance(node, Limit) and isinstance(node.child, Project):
        proj = node.child
        return replace(proj, child=_pushdown_limits(
            replace(node, child=proj.child)))
    return node


# ---------------------------------------------------------------------------
# optimizer pass 3: projection pushdown (narrow rows before shuffle)
# ---------------------------------------------------------------------------


def _project_to(child: Node, cols: set[str], an: _Analysis) -> Node:
    """Project ``child`` down to ``cols`` (child-schema order) if narrower."""
    sch = an.schema(child)
    if set(sch) == cols:
        return child
    ordered = tuple(k for k in sch if k in cols)
    if isinstance(child, Project):
        return replace(child, columns=ordered)
    return Project(child, ordered)


def _pushdown_projections(node: Node, needed: set[str] | None,
                          an: _Analysis) -> Node:
    if isinstance(node, Scan):
        return node
    if isinstance(node, Project):
        return replace(node, child=_pushdown_projections(
            node.child, set(node.columns), an))
    if isinstance(node, Select):
        child_needed = (None if (needed is None or node.columns is None)
                        else needed | set(node.columns))
        return replace(node, child=_pushdown_projections(
            node.child, child_needed, an))
    if isinstance(node, Limit):
        return replace(node, child=_pushdown_projections(node.child, needed,
                                                         an))
    if isinstance(node, (Sort, Repartition, Window)):
        if isinstance(node, Sort):
            keys = set(node.by)
        elif isinstance(node, Repartition):
            keys = set(node.keys)
        else:  # Window: partition keys + order keys + function inputs
            keys = set(node.by) | set(node.order_by) \
                | {c for _, c, _ in node.funcs if c is not None}
        cn = None if needed is None else needed | keys
        child = _pushdown_projections(node.child, cn, an)
        if cn is not None:
            # window OUTPUT names in `cn` are not child columns: the
            # intersection with the child schema drops them
            child = _project_to(child, cn & set(an.schema(child)) | keys, an)
        return replace(node, child=child)
    if isinstance(node, Join):
        lsch = an.schema(node.left)
        rsch = an.schema(node.right)
        need_out = set(an.schema(node)) if needed is None else set(needed)
        ln = {k for k in lsch if k in need_out} | set(node.on)
        rn = set(node.on)
        for k in rsch:
            if (k + JOIN_SUFFIX if k in lsch else k) in need_out:
                rn.add(k)
                if k in lsch:
                    # a consumed '<k>_r' only gets its suffix while the
                    # name still CLASHES — keep the left copy alive even
                    # if nothing upstream reads it
                    ln.add(k)
        left = _project_to(_pushdown_projections(node.left, ln, an), ln, an)
        right = _project_to(_pushdown_projections(node.right, rn, an), rn, an)
        return replace(node, left=left, right=right)
    if isinstance(node, GroupBy):
        cn = set(node.keys) | {c for c, _ in node.pairs}
        child = _project_to(_pushdown_projections(node.child, cn, an), cn, an)
        return replace(node, child=child)
    # set ops & distinct compare whole rows: every child column is load-
    # bearing, nothing can be dropped below them.
    kids = [_pushdown_projections(c, None, an) for c in children(node)]
    return _with_children(node, kids)


# ---------------------------------------------------------------------------
# optimizer pass 4: shuffle elision via Partitioning/RangePartitioning tags
# ---------------------------------------------------------------------------


def _range_fp(node: Node):
    """Plan-internal splitter provenance: the canonical form of the subtree
    that computes the splitters. Two structurally identical subtrees in ONE
    plan see the same inputs and are deterministic, so equal fingerprints
    imply equal placement. None (uncanonicalizable subtree) never matches.
    """
    try:
        return ("plan", _canon(node))
    except _Uncacheable:
        return None


def _elide(node: Node, p: int, an: _Analysis
           ) -> tuple[Node, Partitioning | RangePartitioning | None]:
    if isinstance(node, Scan):
        part = node.partitioning
        if part is not None and part.num_partitions != p:
            part = None
        return node, part
    if isinstance(node, Select):
        c, cp = _elide(node.child, p, an)
        return replace(node, child=c), cp
    if isinstance(node, Project):
        c, cp = _elide(node.child, p, an)
        keep = cp if cp is not None and set(cp.keys) <= set(node.columns) \
            else None
        return replace(node, child=c), keep
    if isinstance(node, Limit):
        c, cp = _elide(node.child, p, an)
        return replace(node, child=c), cp
    if isinstance(node, Repartition):
        c, cp = _elide(node.child, p, an)
        target = Partitioning(node.keys, p, node.seed)
        skip = p == 1 or cp == target
        return replace(node, child=c, skip_shuffle=skip), target
    if isinstance(node, Join):
        l, lp = _elide(node.left, p, an)
        r, rp = _elide(node.right, p, an)
        # inner/left outputs keep true key values on their hash shard;
        # right/full emit unmatched-side rows whose (left-sourced) key
        # columns are zero-filled, so NO placement tag survives them.
        inner_ish = node.how in ("inner", "left")

        def out_part(seed):
            if inner_ish:
                return Partitioning(node.on, p, seed)
            return None
        if p == 1:
            out = replace(node, left=l, right=r, skip_left_shuffle=True,
                          skip_right_shuffle=True, shuffle_seed=node.seed)
            return out, out_part(node.seed)
        l_range = range_prefix_matches(lp, node.on)
        r_range = range_prefix_matches(rp, node.on)
        # both sides range-partitioned by the SAME splitter computation:
        # equal keys already colocated everywhere, skip both shuffles
        if l_range and r_range and lp == rp and lp.fingerprint is not None:
            out = replace(node, left=l, right=r, skip_left_shuffle=True,
                          skip_right_shuffle=True, shuffle_seed=node.seed)
            return out, (lp if inner_ish else None)
        target = None
        if isinstance(lp, Partitioning) and lp.keys == node.on:
            target = lp
        elif isinstance(rp, Partitioning) and rp.keys == node.on:
            target = rp
        if target is not None:
            out = replace(node, left=l, right=r,
                          skip_left_shuffle=lp == target,
                          skip_right_shuffle=rp == target,
                          shuffle_seed=target.seed)
            return out, out_part(target.seed)
        # one side range-partitioned (sort output): keep its placement and
        # range-ALIGN the other side to its boundaries — one AllToAll
        # instead of two, and the range placement survives the join
        if l_range:
            out = replace(node, left=l, right=r, skip_left_shuffle=True,
                          align="left", align_keys=lp.keys,
                          shuffle_seed=node.seed)
            return out, (lp if inner_ish else None)
        if r_range:
            out = replace(node, left=l, right=r, skip_right_shuffle=True,
                          align="right", align_keys=rp.keys,
                          shuffle_seed=node.seed)
            return out, (rp if inner_ish else None)
        out = replace(node, left=l, right=r, skip_left_shuffle=False,
                      skip_right_shuffle=False, shuffle_seed=node.seed)
        return out, out_part(node.seed)
    if isinstance(node, GroupBy):
        c, cp = _elide(node.child, p, an)
        # any hash partitioning on exactly the group keys colocates each
        # key on one shard — seed-independent, unlike the join fast path;
        # a range partitioning on a PREFIX of the keys colocates them too
        # (placement is a function of the prefix tuple)
        matches = (isinstance(cp, Partitioning) and cp.keys == node.keys) \
            or range_prefix_matches(cp, node.keys)
        if p == 1 or matches:
            out = replace(node, child=c, skip_shuffle=True,
                          shuffle_seed=node.seed)
            return out, cp if matches else Partitioning(node.keys, p,
                                                        node.seed)
        out = replace(node, child=c, shuffle_seed=node.seed)
        return out, Partitioning(node.keys, p, node.seed)
    if isinstance(node, Sort):
        c, cp = _elide(node.child, p, an)
        # an input range-partitioned on a by-prefix (equal prefixes
        # colocated, shard ranges ordered) — or on an EXTENSION of `by`
        # (placement refines the requested order) — already has the right
        # global placement: a local sort alone yields the global order,
        # and the input's placement tag survives untouched
        el = range_prefix_matches(cp, node.by) or (
            isinstance(cp, RangePartitioning)
            and node.by == cp.keys[:len(node.by)])
        if el:
            return replace(node, child=c, skip_shuffle=True), cp
        out = replace(node, child=c, skip_shuffle=p == 1)
        # the shuffle (or the single-shard identity) leaves the output
        # range-partitioned on `by`; fingerprint = the producing subtree
        return out, RangePartitioning(node.by, p, _range_fp(out))
    if isinstance(node, Window):
        c, cp = _elide(node.child, p, an)
        keys = node.by + node.order_by
        # same placement rules as Sort: a range partitioning on a prefix
        # of (by + order_by) — or an extension of it — already gives every
        # shard a contiguous slice of the target global order, so the
        # window pays only its boundary all_gather; the input's placement
        # tag survives (windows are row- and placement-preserving)
        el = range_prefix_matches(cp, keys) or (
            isinstance(cp, RangePartitioning)
            and keys == cp.keys[:len(keys)])
        if el:
            return replace(node, child=c, skip_shuffle=True), cp
        out = replace(node, child=c, skip_shuffle=p == 1)
        return out, RangePartitioning(keys, p, _range_fp(out))
    if isinstance(node, SetOp):
        l, lp = _elide(node.left, p, an)
        r, rp = _elide(node.right, p, an)
        keys = tuple(sorted(an.schema(node.left)))  # whole-row hash order
        if p == 1:
            out = replace(node, left=l, right=r, skip_left_shuffle=True,
                          skip_right_shuffle=True)
            return out, Partitioning(keys, p, node.seed)
        target = None
        if isinstance(lp, Partitioning) and lp.keys == keys:
            target = lp
        elif isinstance(rp, Partitioning) and rp.keys == keys:
            target = rp
        elided_seed = target.seed if target is not None else node.seed
        if target is None:
            target = Partitioning(keys, p, node.seed)
        out = replace(node, left=l, right=r, seed=elided_seed,
                      skip_left_shuffle=lp == target,
                      skip_right_shuffle=rp == target)
        return out, Partitioning(keys, p, elided_seed)
    if isinstance(node, Distinct):
        c, cp = _elide(node.child, p, an)
        keys = tuple(sorted(an.schema(node.child)))
        # hash on exactly the whole row (seed-independent) colocates
        # duplicates; so does ANY range partitioning — its keys are a
        # subset of the row, and equal rows have equal key tuples
        matches = (isinstance(cp, Partitioning) and cp.keys == keys) \
            or isinstance(cp, RangePartitioning)
        skip = p == 1 or matches
        part = cp if matches else Partitioning(keys, p, node.seed)
        return replace(node, child=c, skip_shuffle=skip), part
    raise TypeError(node)


# ---------------------------------------------------------------------------
# optimizer pass 5: the cost model — cardinality estimation + sizing
# ---------------------------------------------------------------------------


class _Estimator:
    """Memoized per-node :class:`~repro.core.stats.TableStats` estimate.

    None = unknown (an input without statistics poisons everything above
    it — the conservative fixed-slack path then applies). Estimates are
    classic System-R style: default selectivity for predicates, NDV-capped
    output rows for GroupBy/Distinct, containment for joins.
    """

    def __init__(self, an: _Analysis, input_stats: Sequence):
        self.an = an
        self.inputs = list(input_stats)
        self._memo: dict[int, tuple[Node, object]] = {}

    def stats(self, node: Node) -> S.TableStats | None:
        hit = self._memo.get(id(node))
        if hit is not None and hit[0] is node:
            return hit[1]
        out = self._stats(node)
        self._memo[id(node)] = (node, out)
        return out

    def _stats(self, node: Node) -> S.TableStats | None:
        if isinstance(node, Scan):
            if node.slot >= len(self.inputs):
                return None
            return self.inputs[node.slot]
        kids = [self.stats(c) for c in children(node)]
        if isinstance(node, Select):
            cs = kids[0]
            return None if cs is None else S.cap_rows(
                cs, cs.rows * S.DEFAULT_SELECTIVITY)
        if isinstance(node, Project):
            cs = kids[0]
            return None if cs is None else S.cap_rows(cs, cs.rows,
                                                      keep=node.columns)
        if isinstance(node, Limit):
            cs = kids[0]
            return None if cs is None else S.cap_rows(
                cs, min(float(node.n), cs.rows))
        if isinstance(node, (Sort, Repartition, Window)):
            # row- and key-preserving; only the shard placement changes
            # (a Window appends result columns, which simply carry no
            # column statistics — they never drive placement)
            cs = kids[0]
            return None if cs is None else S.cap_rows(cs, cs.rows)
        if isinstance(node, GroupBy):
            cs = kids[0]
            if cs is None:
                return None
            ndv = cs.ndv(node.keys)
            rows = cs.rows if ndv is None else min(ndv, cs.rows)
            return S.cap_rows(cs, rows, keep=node.keys)
        if isinstance(node, Join):
            sl, sr = kids
            if sl is None or sr is None:
                return None
            # containment: every value of the smaller key domain joins
            # into the larger -> |L><R| = |L|*|R| / max(ndv_l, ndv_r)
            dl = sl.ndv(node.on)
            dr = sr.ndv(node.on)
            dl = sl.rows if dl is None else dl
            dr = sr.rows if dr is None else dr
            m = sl.rows * sr.rows / max(dl, dr, 1.0)
            rows = {"inner": m, "left": m + sl.rows, "right": m + sr.rows,
                    "full": m + sl.rows + sr.rows}[node.how]
            lsch = self.an.schema(node.left)
            cols = dict(sl.columns)
            for k, c in sr.columns:
                cols[k + JOIN_SUFFIX if k in lsch else k] = c
            for k in node.on:  # equi-key: the smaller NDV survives
                a, b = sl.col(k), sr.col(k)
                if a is not None and b is not None:
                    cols[k] = S.ColumnStats(min(a.ndv, b.ndv), a.lo, a.hi)
            return S.cap_rows(
                S.TableStats(rows=rows, columns=tuple(sorted(cols.items()))),
                rows)
        if isinstance(node, (Union, Intersect, Difference)):
            sl, sr = kids
            if sl is None or sr is None:
                return None
            if isinstance(node, Intersect):
                rows = min(sl.rows, sr.rows)
            elif isinstance(node, Difference) and node.mode == "left":
                rows = sl.rows
            else:  # union / symmetric difference upper bound
                rows = sl.rows + sr.rows
            return S.cap_rows(sl, rows)
        if isinstance(node, Distinct):
            cs = kids[0]
            if cs is None:
                return None
            ndv = cs.ndv(tuple(self.an.schema(node.child)))
            rows = cs.rows if ndv is None else min(ndv, cs.rows)
            return S.cap_rows(cs, rows)
        raise TypeError(node)


def _schema_row_bytes(schema: dict) -> int:
    """Dense wire bytes per row of a schema (the _row_bytes formula on
    ShapeDtypeStructs of trailing row shapes)."""
    total = 0
    for sds in schema.values():
        n = 1
        for d in sds.shape:
            n *= d
        total += n * jnp.dtype(sds.dtype).itemsize
    return total


def _pick_node_stages(node: Node, est: _Estimator, p: int, bucket,
                      skipped: bool, *sources: Node):
    """The cost pass's shuffle-staging pick: wire bytes from the sized
    bucket and the shuffled input's schema -> :func:`S.pick_stages`.
    Keeps an explicit ``stages=`` untouched; leaves None (runtime
    auto-pick from the same formula) when the bucket isn't known yet."""
    if node.stages is not None or bucket is None or p <= 1 or skipped:
        return node.stages
    rb = max(_schema_row_bytes(est.an.schema(s)) for s in sources)
    return S.pick_stages(p * p * bucket * rb, bucket)


def _apply_costs(node: Node, est: _Estimator, p: int) -> Node:
    """Fill unset capacities / resolve ``auto`` strategies from estimates.

    Every capacity this pass writes is marked ``sized=True`` on its node:
    the runtime treats overflow on a sized plan as "the estimate was
    wrong" and retries once with conservative capacities
    (``execute_plan(..., safe_capacity=True)``). A single-shard mesh
    skips sizing entirely — there is no wire to save and the fallback
    capacities are already local-only. The same pass picks each shuffle's
    pipeline depth (``stages``) from its estimated wire bytes — S=1 below
    the threshold, so small shuffles pay zero extra collectives.
    """
    kids = [_apply_costs(c, est, p) for c in children(node)]
    if isinstance(node, GroupBy):
        cs = est.stats(node.child)  # memo holds the pre-costing child
        strategy, bucket, sized = node.strategy, node.bucket_capacity, \
            node.sized
        # None = key cardinality unknown (no stats, or the key column was
        # never sketched — e.g. a derived aggregate column)
        ndv = cs.ndv(node.keys) if cs is not None else None
        if strategy == "auto":
            # two-phase ships <= min(ndv, rows/p) partial rows per shard
            # (p * ndv total); raw shuffle ships every row — pick the
            # smaller wire volume. Missing information (no stats, or the
            # key column was never sketched) takes the documented
            # two_phase fallback, never worst-case shuffle.
            strategy = "two_phase" if ndv is None or p * ndv <= cs.rows \
                else "shuffle"
        if (bucket is None and cs is not None and p > 1
                and not node.skip_shuffle):
            src = cs.shard_rows(p)
            if strategy == "two_phase" and ndv is not None:
                src = min(src, ndv)
            bucket = S.size_bucket(src, p)
            sized = True
        stages = _pick_node_stages(node, est, p, bucket, node.skip_shuffle,
                                   node.child)
        return replace(node, child=kids[0], strategy=strategy,
                       bucket_capacity=bucket, sized=sized, stages=stages)
    if isinstance(node, Repartition):
        cs = est.stats(node.child)
        bucket, sized = node.bucket_capacity, node.sized
        if (bucket is None and cs is not None and p > 1
                and not node.skip_shuffle):
            bucket = S.size_bucket(cs.shard_rows(p), p)
            sized = True
        stages = _pick_node_stages(node, est, p, bucket, node.skip_shuffle,
                                   node.child)
        return replace(node, child=kids[0], bucket_capacity=bucket,
                       sized=sized, stages=stages)
    if isinstance(node, (Sort, Window)):
        cs = est.stats(node.child)
        bucket, sized = node.bucket_capacity, node.sized
        if (bucket is None and cs is not None and p > 1
                and not node.skip_shuffle):
            # sampled splitters miss true quantiles: widen the mean
            bucket = S.size_bucket(cs.shard_rows(p), p,
                                   factor=S.RANGE_SIZING_FACTOR)
            sized = True
        stages = _pick_node_stages(node, est, p, bucket, node.skip_shuffle,
                                   node.child)
        return replace(node, child=kids[0], bucket_capacity=bucket,
                       sized=sized, stages=stages)
    if isinstance(node, Join):
        sl, sr = est.stats(node.left), est.stats(node.right)
        js = est.stats(node)
        bucket, out = node.bucket_capacity, node.out_capacity
        sized, out_sized = node.sized, node.out_sized
        both_skipped = node.skip_left_shuffle and node.skip_right_shuffle
        if p > 1 and sl is not None and sr is not None:
            # a range-ALIGNED join keeps its runtime capacity-bump bucket
            # (a whole source shard may target one anchor range — the
            # unoverflowable bound beats any estimate there)
            if bucket is None and node.align is None and not both_skipped:
                src = max(
                    0.0 if node.skip_left_shuffle else sl.shard_rows(p),
                    0.0 if node.skip_right_shuffle else sr.shard_rows(p))
                bucket = S.size_bucket(src, p)
                sized = True
            if out is None and js is not None:
                # sized by estimated match count, not c_l + c_r — the
                # join truncation counter makes an underestimate loud
                out = S.size_output(js.rows, p,
                                    factor=S.JOIN_OUT_SIZING_FACTOR)
                out_sized = True
        stages = _pick_node_stages(node, est, p, bucket, both_skipped,
                                   node.left, node.right)
        return replace(node, left=kids[0], right=kids[1],
                       bucket_capacity=bucket, out_capacity=out,
                       sized=sized, out_sized=out_sized, stages=stages)
    if isinstance(node, SetOp):
        sl, sr = est.stats(node.left), est.stats(node.right)
        bucket, sized = node.bucket_capacity, node.sized
        both_skipped = node.skip_left_shuffle and node.skip_right_shuffle
        if (bucket is None and p > 1 and sl is not None and sr is not None
                and not both_skipped):
            src = max(0.0 if node.skip_left_shuffle else sl.shard_rows(p),
                      0.0 if node.skip_right_shuffle else sr.shard_rows(p))
            bucket = S.size_bucket(src, p)
            sized = True
        stages = _pick_node_stages(node, est, p, bucket, both_skipped,
                                   node.left, node.right)
        return replace(node, left=kids[0], right=kids[1],
                       bucket_capacity=bucket, sized=sized, stages=stages)
    if isinstance(node, Distinct):
        cs = est.stats(node.child)
        bucket, sized = node.bucket_capacity, node.sized
        if (bucket is None and cs is not None and p > 1
                and not node.skip_shuffle):
            bucket = S.size_bucket(cs.shard_rows(p), p)
            sized = True
        stages = _pick_node_stages(node, est, p, bucket, node.skip_shuffle,
                                   node.child)
        return replace(node, child=kids[0], bucket_capacity=bucket,
                       sized=sized, stages=stages)
    return _with_children(node, kids)


def apply_cost_model(plan: Node, input_schemas: Sequence[dict],
                     num_shards: int, input_stats: Sequence | None = None
                     ) -> Node:
    """The cost pass alone (strategy resolution + capacity sizing) — the
    eager one-node-plan path runs this without the logical rewrites so
    ``ctx.groupby(analyzed_table, ...)`` right-sizes like a fused plan."""
    an = _Analysis(input_schemas)
    est = _Estimator(an, input_stats if input_stats is not None
                     else [None] * len(input_schemas))
    return _apply_costs(plan, est, num_shards)


def estimate_output_stats(plan: Node, input_schemas: Sequence[dict],
                          input_stats: Sequence | None
                          ) -> S.TableStats | None:
    """The estimator's TableStats for the plan's result (None = unknown).
    Attached to materialized DistTables so chained pipelines keep cost-
    model coverage without re-analyzing intermediates."""
    if input_stats is None or not any(s is not None for s in input_stats):
        return None
    an = _Analysis(input_schemas)
    return _Estimator(an, input_stats).stats(plan)


def _node_cost_sized(node: Node) -> bool:
    return getattr(node, "sized", False) or getattr(node, "out_sized", False)


def degrade_shuffles(plan: Node) -> Node:
    """The ``mono-shuffle`` recovery rung: the same plan with every
    exchange pinned to one monolithic AllToAll (``stages=1``, no ring) —
    bit-identical results by the staging contract, but none of the
    pipelined-chunk machinery a ``shuffle.chunk`` fault lives in.
    ``stages=None`` (cost pick) is pinned too: the degraded run must not
    re-pick a staged depth."""
    node = _with_children(plan, [degrade_shuffles(c)
                                 for c in children(plan)])
    names = {f.name for f in dataclasses.fields(node)}
    upd = {}
    if "stages" in names and node.stages != 1:
        upd["stages"] = 1
    if "shuffle_mode" in names and node.shuffle_mode != "alltoall":
        upd["shuffle_mode"] = "alltoall"
    return replace(node, **upd) if upd else node


def plan_cost_sized(plan: Node) -> bool:
    """True when any capacity in the plan came from a cardinality
    ESTIMATE — the signal that runtime overflow warrants the safe retry."""
    if _node_cost_sized(plan):
        return True
    return any(plan_cost_sized(c) for c in children(plan))


def _stats_arity(node: Node) -> int:
    """How many ShuffleStats entries ``execute_plan`` emits for ``node``."""
    if isinstance(node, (Join, SetOp)):
        return 2
    if isinstance(node, (Limit, Repartition, GroupBy, Sort, Window,
                         Distinct)):
        return 1
    return 0


def cost_sized_stats_mask(plan: Node) -> list[bool]:
    """Per-ShuffleStats flag: did THIS entry's capacities come from cost-
    model estimates? Mirrors ``execute_plan``'s depth-first post-order
    stats emission exactly (children left-to-right, then the node's own
    entries), so the overflow-retry gate can ignore overflow on USER-set
    capacities — those keep the pre-cost-model surface-in-stats contract.
    """
    mask: list[bool] = []

    def walk(node: Node):
        for c in children(node):
            walk(c)
        mask.extend([_node_cost_sized(node)] * _stats_arity(node))

    walk(plan)
    return mask


def optimize_with_partitioning(
        plan: Node, input_schemas: Sequence[dict], num_shards: int,
        input_stats: Sequence | None = None, *,
        verify: bool | None = None,
) -> tuple[Node, Partitioning | RangePartitioning | None]:
    """All passes: probe -> predicate pushdown -> limit pushdown ->
    projection pushdown -> shuffle elision -> cost model. Pure
    plan-to-plan; safe to golden-test offline. Also returns the result's
    static placement (one elision walk serves both the rewrite and the
    output DistTable tag).

    ``verify`` runs ``repro.core.verify`` over the (logical, optimized)
    pair and raises ``PlanVerificationError`` on any invariant violation;
    ``None`` defers to the ``REPRO_VERIFY_PLANS`` env gate (default-on
    under pytest). The verifier re-optimizes with ``verify=False`` for
    its idempotence rule, so this never recurses."""
    logical = plan
    an = _Analysis(input_schemas)
    plan = _annotate_selects(plan, an)
    plan = _pushdown_selects(plan, an)
    plan = _pushdown_limits(plan)
    plan = _pushdown_projections(plan, None, an)
    plan, part = _elide(plan, num_shards, an)
    est = _Estimator(an, input_stats if input_stats is not None
                     else [None] * len(input_schemas))
    plan = _apply_costs(plan, est, num_shards)
    if verify is None or verify:
        from repro.core import verify as V  # deferred: verify imports us

        if verify or V.verification_enabled():
            V.verify_or_raise(logical, plan, input_schemas, num_shards,
                              input_stats)
    return plan, part


def optimize(plan: Node, input_schemas: Sequence[dict], num_shards: int,
             input_stats: Sequence | None = None, *,
             verify: bool | None = None) -> Node:
    return optimize_with_partitioning(plan, input_schemas, num_shards,
                                      input_stats, verify=verify)[0]


def output_partitioning(plan: Node, input_schemas: Sequence[dict],
                        num_shards: int
                        ) -> Partitioning | RangePartitioning | None:
    """Static placement of the plan's result (tags the output DistTable)."""
    _, part = _elide(plan, num_shards, _Analysis(input_schemas))
    return part


# ---------------------------------------------------------------------------
# canonical cache key
# ---------------------------------------------------------------------------


class _Uncacheable(Exception):
    pass


def canonical_key(plan: Node):
    """Hashable canonical form of the plan (the jit-cache key), or None when
    any Select lacks a user cache key (callables cannot be canonicalized)."""
    try:
        return _canon(plan)
    except _Uncacheable:
        return None


def identity_key(plan: Node):
    """Fallback cache key for plans :func:`canonical_key` rejects: keyless
    predicates are keyed by the CONTENT of everything that parameterizes
    their behavior, or the plan is not cached at all. Returns the hashable
    key, or ``None`` when any keyless callable cannot be safely
    content-keyed — such plans are never cached and re-trace on every
    dispatch (the pre-cache semantics: always correct, just slower).

    The key embeds the predicate's ``__code__`` object (CPython compares
    code objects by content, so a lambda re-created on every pass through
    its definition site — the common serving pattern — still hits) plus
    the *values* of its captured closure cells, ``__defaults__``,
    ``__kwdefaults__``, and every global its code references (recursively
    through nested code objects). Cache lookup compares these values by
    ``==``, and the cache's key tuple strongly pins them, so:

    * rebinding a module-level global the predicate reads changes the key
      (miss -> recompile with the new value);
    * a captured or referenced UNHASHABLE value (list, dict, ndarray —
      anything mutable-by-design) makes the plan uncacheable;
    * a dead value's id can never be recycled into a false hit (the key
      itself keeps it alive while the entry is resident).

    REMAINING ALIASING HAZARD (the documented contract): a captured
    object whose ``__hash__``/``__eq__`` are identity-based (the
    ``object`` defaults) but which carries mutable state compares equal
    to itself after in-place mutation — the cache cannot see such
    mutation and will reuse the executable traced with the old state.
    Plain values (numbers, strings, tuples, frozen dataclasses) are
    always safe; predicates closing over mutable identity-hashed objects
    must either mutate by REBINDING (which changes the key) or use an
    explicit user ``key=`` covering the state.
    """
    try:
        return _canon(plan, identity=True)
    except _Uncacheable:
        return None


def _value_token(v):
    """Content token for a value a keyless predicate's behavior depends on
    (closure cell, default, referenced global). The value itself rides in
    the key — equality is by content for hashable values; unhashable
    values (the mutable-in-place hazard class) reject caching."""
    try:
        hash(v)
    except TypeError:
        raise _Uncacheable from None
    return (type(v), v)


def _referenced_names(code) -> set:
    """Every name ``code`` (or a code object nested in its constants —
    inner lambdas, pre-3.12 comprehensions) can look up as a global.
    Over-approximate: ``co_names`` also holds attribute names, which at
    worst add spurious key components, never a false hit."""
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            names |= _referenced_names(const)
    return names


def _identity_of(predicate):
    """Hashable behavior-content of a keyless callable (see
    :func:`identity_key`); raises :class:`_Uncacheable` for opaque
    callables (no ``__code__``) and unhashable parameter values."""
    code = getattr(predicate, "__code__", None)
    if code is None:  # opaque callable: no visible behavior content
        raise _Uncacheable
    try:
        cells = tuple(_value_token(c.cell_contents)
                      for c in getattr(predicate, "__closure__", None) or ())
    except ValueError:  # unfilled cell (self-referential def)
        raise _Uncacheable from None
    defaults = tuple(_value_token(d)
                     for d in getattr(predicate, "__defaults__", None) or ())
    kwdefaults = tuple(
        (n, _value_token(v)) for n, v in
        sorted((getattr(predicate, "__kwdefaults__", None) or {}).items()))
    gl = getattr(predicate, "__globals__", None) or {}
    globals_used = tuple(
        (n, _value_token(gl[n])) if n in gl else (n, "@absent")
        for n in sorted(_referenced_names(code)))
    return ("@code", code, cells, defaults, kwdefaults, globals_used)


def _predicate_fingerprint(predicate):
    """Best-effort structural identity of a predicate's code: a fresh
    lambda with identical source shares it (cache hit), while two
    predicates accidentally given the same user key but different logic
    diverge. Captured closure VALUES are invisible here — the user key
    must cover those (the documented contract)."""
    code = getattr(predicate, "__code__", None)
    if code is None:
        return None
    return (code.co_code, tuple(map(str, code.co_consts)), code.co_names)


def _canon(node: Node, identity: bool = False):
    name = type(node).__name__
    if isinstance(node, Scan):
        return (name, node.slot)
    if isinstance(node, Select):
        if node.key is None:
            if not identity:
                raise _Uncacheable
            key = _identity_of(node.predicate)
        else:
            key = node.key
        return (name, key, _predicate_fingerprint(node.predicate),
                node.columns, _canon(node.child, identity))
    vals = []
    for f in dataclasses.fields(node):
        v = getattr(node, f.name)
        if isinstance(v, Node) or callable(v):
            continue
        # staging knobs at their identity values keep the pre-staging
        # canonical key: S=1 IS today's program (bit-identical, same HLO),
        # so default plans must hit the same cache entries they always did
        if f.name == "stages" and v in (None, 1):
            continue
        if f.name == "shuffle_mode" and v == "alltoall":
            continue
        vals.append((f.name, v))
    return (name, tuple(vals)) + tuple(_canon(c, identity)
                                       for c in children(node))


# ---------------------------------------------------------------------------
# compiler / executor — runs INSIDE shard_map (one body for the whole plan)
# ---------------------------------------------------------------------------


def execute_plan(plan: Node, tables: Sequence[Table], *, axis_name: str,
                 num_shards: int, report: list | None = None,
                 safe_capacity: bool = False) -> tuple[Table, tuple]:
    """Evaluate the plan over per-shard local Tables.

    Returns ``(output table, stats)`` where ``stats`` is one ShuffleStats
    per *potential* shuffle in depth-first plan order (zeros when elided),
    keeping the stats pytree stable whether or not the optimizer fired.

    ``safe_capacity`` is the overflow-retry mode: every capacity the plan
    left unset is taken at the UNOVERFLOWABLE bound (a send bucket the
    size of the whole source table — no hash spread can exceed it)
    instead of the ``FALLBACK_SLACK`` heuristic. ``DistContext._run_plan``
    re-runs a cost-sized plan this way (with its estimate-derived
    capacities stripped) after the overflow counter proves an estimate
    wrong; capacities the USER set explicitly are honored as-is in both
    modes (their overflow surfaces in stats, the pre-existing contract).
    """
    p = num_shards
    stats: list = []
    memo: dict[int, Table] = {}

    def cap(t: Table, bucket: int | None,
            slack: float = S.FALLBACK_SLACK) -> int:
        if bucket is not None:
            return bucket
        if safe_capacity:
            return t.capacity
        return default_bucket_capacity(t.capacity, p, slack)

    def run(node: Node) -> Table:
        hit = memo.get(id(node))
        if hit is not None:
            return hit
        out = _exec(node)
        memo[id(node)] = out
        return out

    def _exec(node: Node) -> Table:
        if isinstance(node, Scan):
            return tables[node.slot]
        if isinstance(node, Select):
            return L.select(run(node.child), node.predicate)
        if isinstance(node, Project):
            return L.project(run(node.child), list(node.columns))
        if isinstance(node, Limit):
            t = run(node.child)
            out, st = D.dist_limit(t, node.n, axis_name=axis_name,
                                   report=report)
            stats.extend(st)
            return out
        if isinstance(node, Repartition):
            t = run(node.child)
            out, st = D.dist_repartition_by(
                t, list(node.keys), axis_name=axis_name,
                bucket_capacity=cap(t, node.bucket_capacity), seed=node.seed,
                skip_shuffle=node.skip_shuffle, report=report,
                stages=node.stages, shuffle_mode=node.shuffle_mode)
            stats.extend(st)
            return out
        if isinstance(node, Join):
            lt, rt = run(node.left), run(node.right)
            cb = node.bucket_capacity or max(
                cap(lt, None), cap(rt, None))
            if node.bucket_capacity is None and node.align is not None:
                # range alignment is skew-prone in a way hash is not: ALL
                # of a source shard's rows may target one anchor range. A
                # bucket covering the shuffled side's whole capacity makes
                # a one-destination pileup unoverflowable (the same sizing
                # data/pipeline.py uses by hand); hash defaults would drop
                # rows silently under key skew.
                shuffled = rt if node.align == "left" else lt
                cb = max(cb, shuffled.capacity)
            # default output budget = what a fully-shuffled join would get
            # (each operand lands at p*cb rows after repartition), so an
            # elided shuffle never shrinks the truncation budget relative
            # to the eager chain
            out_capacity = node.out_capacity
            if out_capacity is None:
                out_capacity = int(S.JOIN_OUT_FACTOR * p * cb)
            out, st = D.dist_join(
                lt, rt, list(node.on), axis_name=axis_name,
                bucket_capacity=cb, how=node.how, algorithm=node.algorithm,
                out_capacity=out_capacity, seed=node.seed,
                shuffle_seed=node.shuffle_seed,
                skip_left_shuffle=node.skip_left_shuffle,
                skip_right_shuffle=node.skip_right_shuffle,
                align=node.align, align_keys=node.align_keys,
                count_truncation=node.out_sized,
                report=report, stages=node.stages,
                shuffle_mode=node.shuffle_mode)
            stats.extend(st)
            return out
        if isinstance(node, GroupBy):
            t = run(node.child)
            # "auto" is resolved by the cost pass; a plan executed without
            # it (direct execute_plan callers) gets the documented fallback
            strategy = "two_phase" if node.strategy == "auto" \
                else node.strategy
            out, st = D.dist_groupby(
                t, list(node.keys), node.pairs, axis_name=axis_name,
                bucket_capacity=cap(t, node.bucket_capacity),
                strategy=strategy,
                partial_capacity=node.partial_capacity,
                out_capacity=node.out_capacity, seed=node.seed,
                shuffle_seed=node.shuffle_seed,
                skip_shuffle=node.skip_shuffle, report=report,
                stages=node.stages, shuffle_mode=node.shuffle_mode)
            stats.extend(st)
            return out
        if isinstance(node, Sort):
            t = run(node.child)
            out, st = D.dist_sort(
                t, list(node.by), axis_name=axis_name,
                # range partition by sampled splitters misses true
                # quantiles: the no-stats bucket widens the one fallback
                # constant by the documented sort factor (== the old 4.0)
                bucket_capacity=cap(t, node.bucket_capacity,
                                    slack=S.FALLBACK_SLACK
                                    * S.SORT_SLACK_FACTOR),
                samples_per_shard=node.samples_per_shard,
                skip_shuffle=node.skip_shuffle, report=report,
                stages=node.stages, shuffle_mode=node.shuffle_mode)
            stats.extend(st)
            return out
        if isinstance(node, Window):
            t = run(node.child)
            out, st = D.dist_window(
                t, list(node.by), node.funcs, axis_name=axis_name,
                order_by=list(node.order_by),
                # range partition by sampled splitters, like Sort: the
                # no-stats bucket widens by the documented sort factor
                bucket_capacity=cap(t, node.bucket_capacity,
                                    slack=S.FALLBACK_SLACK
                                    * S.SORT_SLACK_FACTOR),
                samples_per_shard=node.samples_per_shard,
                skip_shuffle=node.skip_shuffle, report=report,
                stages=node.stages, shuffle_mode=node.shuffle_mode)
            stats.extend(st)
            return out
        if isinstance(node, SetOp):
            a, b = run(node.left), run(node.right)
            cb = node.bucket_capacity or max(cap(a, None), cap(b, None))
            kw = dict(axis_name=axis_name, bucket_capacity=cb, seed=node.seed,
                      skip_left_shuffle=node.skip_left_shuffle,
                      skip_right_shuffle=node.skip_right_shuffle,
                      report=report, stages=node.stages,
                      shuffle_mode=node.shuffle_mode)
            if isinstance(node, Union):
                out, st = D.dist_union(a, b, **kw)
            elif isinstance(node, Intersect):
                out, st = D.dist_intersect(a, b, **kw)
            else:
                out, st = D.dist_difference(a, b, mode=node.mode, **kw)
            stats.extend(st)
            return out
        if isinstance(node, Distinct):
            t = run(node.child)
            out, st = D.dist_distinct(
                t, axis_name=axis_name,
                bucket_capacity=cap(t, node.bucket_capacity), seed=node.seed,
                skip_shuffle=node.skip_shuffle, report=report,
                stages=node.stages, shuffle_mode=node.shuffle_mode)
            stats.extend(st)
            return out
        raise TypeError(node)

    out = run(plan)
    return out, tuple(stats)


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------


def _shuffle_word(skip: bool) -> str:
    return "elided" if skip else "alltoall"


def _recovery_rungs(node: Node) -> list[str]:
    """The degradation rungs that apply to ``node`` should its execution
    fail — the ``recovery=`` annotation in :func:`explain`."""
    rungs = []
    if isinstance(node, (Join, SetOp)):
        live = not (node.skip_left_shuffle and node.skip_right_shuffle)
    else:
        live = not getattr(node, "skip_shuffle", True)
    if live and any(f.name == "stages" for f in dataclasses.fields(node)):
        rungs.append("mono-alltoall")
    if isinstance(node, (GroupBy, Window)):
        rungs.append("oracle-kernel")
    if _node_cost_sized(node):
        rungs.append("safe-capacity")
    return rungs


def explain(plan: Node, input_schemas: Sequence[dict] | None = None,
            input_stats: Sequence | None = None, *,
            recovery: bool = False) -> str:
    """Human-readable plan tree (golden-testable): one node per line, with
    every potential shuffle marked ``alltoall`` or ``elided``.

    With ``input_schemas`` + ``input_stats`` every node is additionally
    annotated with its estimated output rows (``~rows=``), and nodes
    whose capacities the cost model filled in show them (``bucket=``,
    ``out=``, ``cost-sized``) — the audit trail for every physical-
    planning decision. Without statistics the output is unchanged.

    ``recovery=True`` appends each node's applicable degradation rungs
    (``recovery=mono-alltoall+oracle-kernel+safe-capacity``) — how the
    retry ladder would re-execute the node after a failure (see
    ``repro.core.faults``). Off by default so golden plans are stable.
    """
    est = None
    if input_schemas is not None and input_stats is not None \
            and any(s is not None for s in input_stats):
        est = _Estimator(_Analysis(input_schemas), input_stats)
    lines: list[str] = []

    def notes(node: Node) -> str:
        parts = []
        bucket = getattr(node, "bucket_capacity", None)
        if bucket is not None and not isinstance(node, (Select, Project,
                                                        Limit, Scan)):
            parts.append(f"bucket={bucket}")
        if isinstance(node, Join) and node.out_capacity is not None:
            parts.append(f"out={node.out_capacity}")
        stages = getattr(node, "stages", None)
        if stages is not None:
            parts.append(f"stages={stages}")
        if getattr(node, "shuffle_mode", "alltoall") != "alltoall":
            parts.append(f"mode={node.shuffle_mode}")
        if _node_cost_sized(node):
            parts.append("cost-sized")
        if est is not None:
            s = est.stats(node)
            if s is not None:
                parts.append(f"~rows={int(round(s.rows))}")
        if recovery:
            rungs = _recovery_rungs(node)
            if rungs:
                parts.append("recovery=" + "+".join(rungs))
        return (", " + ", ".join(parts)) if parts else ""

    def walk(node: Node, depth: int):
        pad = "  " * depth
        if isinstance(node, Scan):
            part = ""
            pt = node.partitioning
            if isinstance(pt, RangePartitioning):
                part = f", partitioned=range{pt.keys}/{pt.num_partitions}"
            elif pt is not None:
                part = (f", partitioned=hash{pt.keys}%"
                        f"{pt.num_partitions}@seed{pt.seed}")
            txt = f"Scan(slot={node.slot}{part}"
        elif isinstance(node, Select):
            txt = f"Select(key={node.key!r}, columns={node.columns}"
        elif isinstance(node, Project):
            txt = f"Project(columns={node.columns}"
        elif isinstance(node, Limit):
            txt = f"Limit(n={node.n}"
        elif isinstance(node, Repartition):
            txt = (f"Repartition(keys={node.keys}, seed={node.seed}, "
                   f"shuffle={_shuffle_word(node.skip_shuffle)}")
        elif isinstance(node, Join):
            extra = ""
            if node.align is not None:
                extra = f", align={node.align}{node.align_keys}"
            txt = (f"Join(on={node.on}, how={node.how}, "
                   f"algorithm={node.algorithm}, "
                   f"left={_shuffle_word(node.skip_left_shuffle)}, "
                   f"right={_shuffle_word(node.skip_right_shuffle)}{extra}")
        elif isinstance(node, GroupBy):
            txt = (f"GroupBy(keys={node.keys}, aggs={node.pairs}, "
                   f"strategy={node.strategy}, "
                   f"shuffle={_shuffle_word(node.skip_shuffle)}")
        elif isinstance(node, Sort):
            txt = (f"Sort(by={node.by}, "
                   f"shuffle={_shuffle_word(node.skip_shuffle)}")
        elif isinstance(node, Window):
            fn_names = tuple(A.window_output_name(fn, col, off)
                             for fn, col, off in node.funcs)
            txt = (f"Window(by={node.by}, order_by={node.order_by}, "
                   f"funcs={fn_names}, "
                   f"shuffle={_shuffle_word(node.skip_shuffle)}")
        elif isinstance(node, SetOp):
            extra = f", mode={node.mode}" if isinstance(node, Difference) \
                else ""
            txt = (f"{type(node).__name__}("
                   f"left={_shuffle_word(node.skip_left_shuffle)}, "
                   f"right={_shuffle_word(node.skip_right_shuffle)}{extra}")
        elif isinstance(node, Distinct):
            txt = f"Distinct(shuffle={_shuffle_word(node.skip_shuffle)}"
        else:
            txt = f"{type(node).__name__}("
        lines.append(f"{pad}{txt}{notes(node)})")
        for c in children(node):
            walk(c, depth + 1)

    walk(plan, 0)
    return "\n".join(lines)
