"""Small shared utilities: padding, pow2 math, platform detection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.cache
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Pallas kernels run in interpret mode off-TPU (this container is CPU)."""
    return not on_tpu()


def next_pow2(n: int) -> int:
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(n: int, m: int) -> int:
    return ceil_div(n, m) * m


def pad_to(x: jax.Array, n: int, fill) -> jax.Array:
    """Pad 1-D array x up to length n with `fill` (no-op if already n)."""
    if x.shape[0] == n:
        return x
    assert x.shape[0] < n, (x.shape, n)
    return jnp.concatenate(
        [x, jnp.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)]
    )


def safe_constrain(x, mesh, spec):
    """with_sharding_constraint that no-ops inside manual (shard_map)
    regions, where the full-mesh NamedSharding is rejected — e.g. the
    pod-compressed gradient path wraps the whole model in a pod-manual
    shard_map; the inner TP constraints become hints we can drop there."""
    from jax.sharding import NamedSharding
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and any(
                "Manual" in str(t) for t in getattr(am, "axis_types", ())):
            return x
    except Exception:  # noqa: BLE001 — older jax: check the axis env instead
        # jax<0.5 rejects the constraint only at lowering (uncatchable
        # here), so pre-check: inside a shard_map, axes are bound in the
        # axis env — drop the hint if the spec mentions any of them.
        try:
            from jax._src.core import get_axis_env
            bound = set(get_axis_env().axis_sizes)
        except Exception:  # noqa: BLE001
            bound = set()
        named = set()
        for part in spec:
            if part is None:
                continue
            named |= set(part) if isinstance(part, tuple) else {part}
        if named & bound:
            return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def axis_size(axis_name) -> int:
    """Version-compat static mesh-axis size inside shard_map/pmap bodies
    (jax<0.5 has no jax.lax.axis_size; psum of the unit constant folds to
    the static size there)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False,
              axis_names=None):
    """Version-compat shard_map (jax>=0.8 moved it to jax.shard_map).

    axis_names: axes to run manually (the rest stay auto); None = all.
    The old experimental API spells that as auto=<complement>.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if axis_names is None else {
        "auto": frozenset(mesh.axis_names) - set(axis_names)}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep, **kw)


def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype")
    )
