"""Production meshes (assignment contract).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (16, 16) = 256 chips ("data", "model");
multi-pod (2, 16, 16) = 512 chips ("pod", "data", "model"). The pod axis
rides DCN; data/model ride ICI — transport selection by axis choice
(core/context.py docstring).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, pod: int = 1):
    """Development mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    data = n // (model * pod)
    assert data * model * pod == n, (n, model, pod)
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
