"""Serving launcher: batched prefill + greedy decode with a KV cache.

``python -m repro.launch.serve --arch llama3-8b --tiny --batch 4
--prompt-len 32 --gen 16`` runs a batch of synthetic prompts through
prefill then decode steps (the decode_32k/long_500k cells lower exactly
this ``decode_fn``).
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_tiny
    from repro.launch.mesh import make_local_mesh
    from repro.models.factory import build_model
    from repro.train.steps import make_decode_step, make_prefill_step

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    mesh = make_local_mesh(model=args.model_axis) \
        if jax.device_count() > 1 else None
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(args.seed))

    max_len = args.prompt_len + args.gen
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_frontend_tokens,
                                 cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, args.prompt_len, cfg.d_model)),
            jnp.float32)

    prefill = jax.jit(make_prefill_step(model, max_len,
                                        enc_len=args.prompt_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t1 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t2 = time.perf_counter()
    gen = np.concatenate(out, axis=1)
    print(f"prefill: {t1-t0:.3f}s  decode: {(t2-t1)/max(args.gen-1,1)*1e3:.1f}"
          f" ms/tok  throughput: {args.batch*(args.gen-1)/max(t2-t1,1e-9):.1f}"
          " tok/s")
    print("generated token ids (first row):", gen[0][:16])


if __name__ == "__main__":
    main()
