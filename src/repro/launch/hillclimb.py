import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: lower a (arch, shape) cell under config variants
and report the three roofline terms per variant (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell llama3_train \
        --out results/perf_llama3.json
"""
import argparse
import json

import jax

from repro.configs import get_config
from repro.launch.dryrun import compile_cell, roofline_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA


def _measure(cfg, shape, mesh, *, microbatches=None):
    rec = roofline_cell(cfg, shape, mesh)
    try:
        lowered, compiled = compile_cell(cfg, shape, mesh,
                                         microbatches=microbatches)
        mem = RA.memory_stats(compiled)
        up = RA.cpu_upcast_temp_bytes(compiled.as_text())
        mem["peak_adjusted"] = max(mem["peak_bytes"] - up["total"]
                                   + up["largest"], mem["argument_bytes"])
        rec["memory"] = mem
    except Exception as e:  # noqa: BLE001
        rec["memory"] = {"error": str(e)[:300]}
    return rec


# --- variant sets per chosen cell -------------------------------------------

def cell_llama3_train(mesh):
    base = get_config("llama3-8b")
    return "llama3-8b", "train_4k", [
        ("baseline_tp16", base),
        ("fsdp_layout", base.replace(layout="fsdp")),
        ("fsdp_layout_remat_dots", base.replace(layout="fsdp", remat="dots")),
        ("tp16_remat_dots", base.replace(remat="dots")),
    ]


def cell_minicpm3_decode(mesh):
    base = get_config("minicpm3-4b")
    return "minicpm3-4b", "decode_32k", [
        ("baseline_latent_cache", base),
        ("latent_seqshard", base.replace(mla_seq_shard=True)),
    ]


def cell_qwen2_train(mesh):
    base = get_config("qwen2-moe-a2.7b")
    return "qwen2-moe-a2.7b", "train_4k", [
        ("baseline_ep_shuffle", base),
        ("gspmd_gathered_experts", base.replace(ep_shuffle=False)),
        ("ep_shuffle_cf1.0", base.replace(moe_capacity_factor=1.0)),
        ("ep_shuffle_cf2.0", base.replace(moe_capacity_factor=2.0)),
    ]


CELLS = {
    "llama3_train": cell_llama3_train,
    "minicpm3_decode": cell_minicpm3_decode,
    "qwen2_train": cell_qwen2_train,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--out", required=True)
    ap.add_argument("--variants", default=None,
                    help="comma-separated subset of variant names")
    args = ap.parse_args()
    jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

    mesh = make_production_mesh()
    arch, shape, variants = CELLS[args.cell](mesh)
    want = set(args.variants.split(",")) if args.variants else None
    out = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            out = json.load(f)
    for name, cfg in variants:
        if want and name not in want:
            continue
        print(f"[variant] {name}")
        try:
            rec = _measure(cfg, shape, mesh)
            t, tf = rec["terms"], rec["terms_flash"]
            print(f"  compute {t['compute_s']*1e3:.1f}ms | mem(fl) "
                  f"{tf['memory_s']*1e3:.1f}ms | coll "
                  f"{t['collective_s']*1e3:.1f}ms -> {tf['dominant']}"
                  f" | peak {rec['memory'].get('peak_adjusted', 0)/2**30:.1f}"
                  " GiB")
        except Exception as e:  # noqa: BLE001
            rec = {"error": f"{type(e).__name__}: {e}"}
            print(f"  FAIL: {e}")
        out.setdefault(arch, {}).setdefault(shape, {})[name] = rec
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, default=float)
    print("[done]", args.out)


if __name__ == "__main__":
    main()
