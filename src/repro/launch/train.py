"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the end-to-end loop (relational ETL pipeline -> jitted train step)
on whatever devices exist. On a real pod this process runs per-host under
``jax.distributed.initialize()`` (the loop/checkpoint/data layers are
already written against global meshes and step-keyed determinism); on this
container it runs single-process — use ``--devices N`` to run SPMD over N
host devices (set before jax initializes).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --tiny \
        --steps 100 --batch 16 --seq 256
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
        --tiny --devices 8 --model-axis 2 --steps 50
"""
from __future__ import annotations

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (sets XLA_FLAGS)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--pod-axis", type=int, default=1)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.configs import get_config, get_tiny
    from repro.data.pipeline import PipelineConfig, RelationalTokenPipeline
    from repro.launch.mesh import make_local_mesh
    from repro.models.factory import build_model
    from repro.train.loop import LoopConfig, run
    from repro.train.optimizer import OptConfig

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    mesh = None
    if jax.device_count() > 1:
        mesh = make_local_mesh(model=args.model_axis, pod=args.pod_axis)
        print(f"mesh: {dict(mesh.shape)}")
    model = build_model(cfg, mesh)

    if cfg.family in ("vlm", "audio"):
        print(f"note: {cfg.family} frontend is a stub; launcher trains the "
              "text path (tokens only) — use examples/ for full-batch runs",
              file=sys.stderr)

    pipe = RelationalTokenPipeline(PipelineConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size, seed=args.seed))
    ocfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                     total_steps=args.steps)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, log_every=10,
                      microbatches=args.microbatches,
                      compress_pod=args.compress_pod, seed=args.seed)
    state, history = run(model, pipe, ocfg, lcfg)
    if history:
        print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
