import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (assignment contract).

For every (architecture × input shape × mesh) cell:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...) \\
            .lower(**input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Meshes: single-pod (16,16) and multi-pod (2,16,16) — the multi-pod pass
proves the "pod" axis shards. Additionally (single-pod only) the roofline
extractor lowers depth pairs unrolled (see roofline/analysis.py) and
derives the three roofline terms. Results land in a JSON file consumed by
EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--mesh single|multi|both] [--roofline] \
        [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, train_microbatches
from repro.configs.shapes import SHAPES, cache_shape, input_specs, runnable
from repro.launch.mesh import make_production_mesh
from repro.models.factory import build_model
from repro.roofline import analysis as RA
from repro.train.optimizer import OptConfig
from repro.train import steps as ST


def _named(mesh, spec_tree, shape_tree):
    """ShapeDtypeStructs carrying NamedShardings (zero-allocation args)."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shape_tree, spec_tree)


def _batch_sds(model, cfg, shape_name, mesh):
    specs = input_specs(cfg, shape_name)
    part = ST.batch_specs(model, specs)
    return _named(mesh, part, specs)


def build_cell(cfg, shape_name: str, mesh, *, microbatches: int | None = None):
    """Returns (step_fn, args tuple of sharded ShapeDtypeStructs)."""
    cell = SHAPES[shape_name]
    model = build_model(cfg, mesh)
    if cell.kind == "train":
        mb = microbatches if microbatches is not None \
            else train_microbatches(cfg.arch)
        dp = model.rules.pod * model.rules.data
        if model.rules.layout == "fsdp":
            dp *= model.rules.model  # model axis is a batch axis here
        mb = max(1, min(mb, cell.global_batch // max(dp, 1)))
        step = ST.make_train_step(model, OptConfig(), microbatches=mb)
        state_shapes = jax.eval_shape(
            lambda k: ST.init_train_state(model, k), jax.random.PRNGKey(0))
        state_sds = _named(mesh, ST.train_state_specs(model), state_shapes)
        return step, (state_sds, _batch_sds(model, cfg, shape_name, mesh))
    if cell.kind == "prefill":
        step = ST.make_prefill_step(model, cell.seq_len, enc_len=cell.seq_len)
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params_sds = _named(mesh, model.param_specs, params_shapes)
        return step, (params_sds, _batch_sds(model, cfg, shape_name, mesh))
    # decode: unroll the layer loop — scan xs->ys caches cannot buffer-alias,
    # doubling KV memory; unrolled DUS aliases in place (serving practice)
    cfg = cfg.replace(scan_layers=False)
    model = build_model(cfg, mesh)
    step = ST.make_decode_step(model)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sds = _named(mesh, model.param_specs, params_shapes)
    b, s = cache_shape(cfg, shape_name)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(b, s, s if cfg.family == "audio" else 0))
    cache_sds = _named(mesh, model.cache_specs(b), cache_shapes)
    dp, _ = model.rules.decode_layout(b)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                               sharding=NamedSharding(mesh, P(dp, None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return step, (params_sds, cache_sds, tok, pos)


def compile_cell(cfg, shape_name, mesh, *, microbatches=None, donate=True):
    step, args = build_cell(cfg, shape_name, mesh, microbatches=microbatches)
    kw = {}
    if donate and SHAPES[shape_name].kind == "train":
        kw["donate_argnums"] = (0,)
        kw["out_shardings"] = (
            jax.tree.map(lambda x: x.sharding, args[0]), None)
    elif donate and SHAPES[shape_name].kind == "decode":
        # pin the output cache to the input cache's sharding so donation
        # aliases (otherwise in+out caches both stay live — 2x KV memory)
        kw["donate_argnums"] = (1,)
        kw["out_shardings"] = (
            None, jax.tree.map(lambda x: x.sharding, args[1]))
    with mesh:
        lowered = jax.jit(step, **kw).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# roofline extraction (single-pod)
# ---------------------------------------------------------------------------


def _depth_pairs(cfg):
    """[(label, depth-config-fn, depths (l1, l2), weight-at-full-depth)]."""
    if cfg.family == "hybrid":
        per = cfg.attn_every
        periods = cfg.num_layers // per
        rem = cfg.num_layers - periods * per
        return [("period", (per, 2 * per), periods),
                ("rem", (1, 2), rem)]
    if cfg.family == "ssm":
        per = cfg.slstm_every
        periods = cfg.num_layers // per
        rem = cfg.num_layers - periods * per
        pairs = [("period", (per, 2 * per), periods)]
        if rem:
            pairs.append(("rem", (1, 2), rem))
        return pairs
    return [("layer", (1, 2), cfg.num_layers)]


def _cost_of(cfg, shape_name, mesh, depth, *, microbatches):
    c = cfg.replace(num_layers=depth, scan_layers=False, time_unroll=True,
                    remat="none")
    if cfg.family == "audio":
        c = c.replace(encoder_layers=depth)
    lowered, compiled = compile_cell(c, shape_name, mesh,
                                     microbatches=microbatches, donate=False)
    cost = RA.cost_stats(compiled)
    txt = compiled.as_text()
    coll = RA.collective_stats(txt)
    hb = RA.hbm_bytes(txt)
    cost["bytes_xla"] = cost["bytes"]          # raw CPU-backend number
    cost["bytes"] = float(hb["bytes"])         # TPU-traffic model
    cost["bytes_flash"] = float(hb["flash_adjusted"])  # w/ Pallas flash attn
    cost["coll_bytes"] = float(coll["bytes"])
    cost["coll_wire_bytes"] = float(coll["wire_bytes"])
    return cost, coll


def roofline_cell(cfg, shape_name, mesh) -> dict:
    """Three-term roofline via depth-pair extrapolation (DESIGN.md §5)."""
    cell = SHAPES[shape_name]
    # roofline lowers one microbatch (mb=1): same math, small graphs
    total = {}
    detail = {}
    for label, (l1, l2), weight in _depth_pairs(cfg):
        if weight == 0:
            continue
        c1, coll1 = _cost_of(cfg, shape_name, mesh, l1, microbatches=1)
        c2, coll2 = _cost_of(cfg, shape_name, mesh, l2, microbatches=1)
        pair = RA.DepthPair(l1, l2, c1, c2)
        per = pair.per_layer()
        if not total:  # depth-independent part (embed/head/opt) counted once
            base = pair.at(0)
            for k, v in base.items():
                total[k] = total.get(k, 0.0) + v
        for k, v in per.items():
            total[k] = total.get(k, 0.0) + v * weight
        detail[label] = {"per_unit": per, "count": weight,
                         "coll_counts": coll2["counts"]}
    chips = int(np.prod(list(mesh.shape.values())))
    terms = RA.roofline_terms(total["flops"], total["bytes"],
                              total["coll_wire_bytes"])
    terms_flash = RA.roofline_terms(total["flops"], total["bytes_flash"],
                                    total["coll_wire_bytes"])
    model = build_model(cfg, mesh)
    pc = RA.count_params(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    mf = RA.model_flops(cfg, pc, cell.kind, cell.global_batch, cell.seq_len)
    hlo_global_flops = total["flops"] * chips
    return {
        "per_device": total,
        "terms": terms,
        "terms_flash": terms_flash,
        "chips": chips,
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_global_flops, 1.0),
        "params": pc,
        "detail": detail,
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, meshes: list[str], *,
             do_roofline: bool, out: dict):
    cfg = get_config(arch)
    ok, reason = runnable(cfg, shape_name)
    rec = out.setdefault(arch, {}).setdefault(shape_name, {})
    if not ok:
        rec["skipped"] = reason
        print(f"[skip] {arch} x {shape_name}: {reason}")
        return
    for mesh_kind in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        t0 = time.perf_counter()
        try:
            lowered, compiled = compile_cell(cfg, shape_name, mesh)
            mem = RA.memory_stats(compiled)
            txt = compiled.as_text()
            coll = RA.collective_stats(txt)
            cost = RA.cost_stats(compiled)
            up = RA.cpu_upcast_temp_bytes(txt)
            mem["peak_adjusted"] = max(
                mem["peak_bytes"] - up["total"] + up["largest"],
                mem["argument_bytes"])
            dt = time.perf_counter() - t0
            rec[mesh_kind] = {
                "ok": True, "compile_s": dt, "memory": mem,
                "collectives_once": coll, "cost_once": cost,
                "hbm_frac": mem["peak_adjusted"] / RA.HBM_PER_CHIP,
            }
            print(f"[ok] {arch} x {shape_name} x {mesh_kind}: "
                  f"peak {mem['peak_bytes']/2**30:.2f} GiB/dev raw, "
                  f"{mem['peak_adjusted']/2**30:.2f} GiB TPU-adj "
                  f"({100*rec[mesh_kind]['hbm_frac']:.0f}% HBM), "
                  f"compile {dt:.0f}s")
        except Exception as e:  # noqa: BLE001 — record and continue
            rec[mesh_kind] = {"ok": False, "error": f"{type(e).__name__}: {e}",
                              "traceback": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
        if do_roofline and mesh_kind == "single" and rec[mesh_kind].get("ok"):
            try:
                t0 = time.perf_counter()
                rec["roofline"] = roofline_cell(cfg, shape_name, mesh)
                rec["roofline"]["extract_s"] = time.perf_counter() - t0
                t = rec["roofline"]["terms"]
                print(f"     roofline: compute {t['compute_s']*1e3:.2f}ms "
                      f"memory {t['memory_s']*1e3:.2f}ms "
                      f"collective {t['collective_s']*1e3:.2f}ms "
                      f"-> {t['dominant']}-bound; "
                      f"useful {100*rec['roofline']['useful_ratio']:.0f}%")
            except Exception as e:  # noqa: BLE001
                rec["roofline"] = {"error": f"{type(e).__name__}: {e}",
                                   "traceback": traceback.format_exc()[-2000:]}
                print(f"[FAIL roofline] {arch} x {shape_name}: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--merge", action="store_true",
                    help="merge into existing --out instead of overwriting")
    args = ap.parse_args()

    cache_dir = os.environ.get("JAX_CACHE_DIR", "/tmp/jax_cache")
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out: dict = {}
    if args.merge and os.path.exists(args.out):
        with open(args.out) as f:
            out = json.load(f)

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        run_cell(arch, shape_name, meshes, do_roofline=args.roofline, out=out)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, default=float)
    print(f"[done] wrote {args.out}")


if __name__ == "__main__":
    main()
