"""Pallas TPU kernel: causal GQA flash attention (the training hot spot).

The (B, H, S, S) score matrix never touches HBM: the grid walks
(batch, q-head, q-block, k-block) with the k-block dimension innermost;
running (max, sumexp, weighted-V accumulator) live in VMEM scratch across
the k sweep (online softmax). Block shapes are MXU-aligned ((bq, hd) x
(hd, bk) matmuls with hd, bq, bk multiples of 128 on TPU).

GQA rides the index_map: q head h reads kv head ``h // group``, so no
k/v replication in HBM. Causality skips fully-masked k-blocks via
``pl.when`` (upper-triangular blocks cost zero compute) and masks the
diagonal block elementwise.

VMEM budget per grid step (bq=bk=512, hd=128, bf16 in / fp32 scratch):
q 128K + k 128K + v 128K + acc 256K + (m,l) 4K + out 128K < 1 MiB — far
under the ~16 MiB/core limit, leaving room for double-buffered pipelines.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import interpret_mode

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, nk: int, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: k-block strictly above the diagonal contributes nothing
    run = jnp.bool_(True) if not causal else (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool | None = None
                    ) -> jax.Array:
    """q (B, S, H, hd); k, v (B, S, KV, hd); H % KV == 0. Returns (B,S,H,hd).

    S must be a multiple of max(bq, bk) (wrapper-level padding is the
    caller's job; model seq lens here are powers of two).
    """
    if interpret is None:
        interpret = interpret_mode()
    b, s, h, hd = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    g = h // kv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(hd)

    # layout: (B, H, S, hd) blocks of (1, 1, bq, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, causal=causal)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        scratch_shapes=[
            _vmem((bq, hd), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
