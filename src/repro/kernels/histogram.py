"""Pallas TPU kernel: bucket histogram for hash-partition (one-hot reduction).

Cylon's hash-partition needs per-destination row counts before building send
buffers. Scatter-add (the CPU/GPU idiom) is serialized on TPU; the native
formulation is a one-hot compare + reduction, which the compiler maps onto
dense vector ops (and onto the MXU via one_hot @ ones when P is large).

Grid walks row-blocks; each step accumulates its block's counts into the
single (1, P) output block (revisited across the grid — Pallas keeps it
resident in VMEM, so HBM sees one read of ids and one write of P counts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import interpret_mode, round_up

LANES = 128
BLOCK_ROWS = 32  # (32, 128) ids per grid step


def _hist_kernel(ids_ref, o_ref, *, num_buckets: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...].reshape(-1)  # (BLOCK_ROWS*LANES,)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (1, num_buckets), 1)
    # one-hot (rows, P) summed over rows -> (1, P); invalid ids (< 0, e.g.
    # padding) match no bucket.
    onehot = (ids[:, None] == buckets).astype(jnp.int32)
    o_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def bucket_histogram(
    ids: jax.Array, num_buckets: int, *, interpret: bool | None = None
) -> jax.Array:
    """Count occurrences of each bucket id in [0, num_buckets).

    ids: (N,) int32; entries outside the range (padding uses -1) are ignored.
    Returns (num_buckets,) int32. Matches ref.histogram_ref exactly.
    """
    if interpret is None:
        interpret = interpret_mode()
    (n,) = ids.shape
    tile = BLOCK_ROWS * LANES
    n_pad = max(round_up(n, tile), tile)
    idp = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(ids.astype(jnp.int32))
    idp = idp.reshape(n_pad // LANES, LANES)
    grid = (n_pad // tile,)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, num_buckets=num_buckets),
        out_shape=jax.ShapeDtypeStruct((1, num_buckets), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_buckets), lambda i: (0, 0)),
        interpret=interpret,
    )(idp)
    return out.reshape(num_buckets)
