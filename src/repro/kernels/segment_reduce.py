"""Pallas TPU kernel: segmented reduction — the groupby hot path.

After sort-by-key + boundary detection (core/ops_agg.py), aggregation is a
segmented reduction: ``out[g] = op(values[i] for i where seg_ids[i] == g)``.
Scatter-accumulate (the CPU/GPU idiom) serializes on TPU; the native
formulation — same design as kernels/histogram.py — is a one-hot compare
against the segment iota, reduced over the row axis. For f32 sums the
one-hot contraction is a matmul, so the accumulation rides the MXU; min/max
use a masked VPU reduction.

The grid is 2-D: ``(segment tiles, row blocks)``. Each step folds one row
block's partials into the current (1, SEG_TILE)-wide slice of the output;
the row axis is the *inner* grid dimension, so a given output tile stays
VMEM-resident across all of its row steps (HBM sees the rows once per
segment tile and one write per output tile). ``MAX_SEGMENTS`` is the
per-tile width budget — the (rows_block, SEG_TILE) one-hot that must fit
in VMEM — not a limit on the total segment count: larger ``num_segments``
simply adds segment tiles, each comparing against its own offset window of
the segment id space. The XLA scatter path (``kernels/ops.py``,
``use_kernel=False``) remains the oracle/fallback for N-D payloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.utils import interpret_mode, round_up

LANES = 128
BLOCK_ROWS = 8  # (8, 128) = 1024 rows per grid step; (1024, G) one-hot fits VMEM
# per-tile segment width (VMEM budget for the one-hot), NOT a global cap:
# num_segments beyond it tiles the segment axis in the second grid dim
MAX_SEGMENTS = 1024

OPS = ("sum", "min", "max")


def _seg_kernel(seg_ref, val_ref, o_ref, *, op: str, seg_tile: int):
    row_step = pl.program_id(1)  # inner dim: output tile stays resident
    seg_base = pl.program_id(0) * seg_tile
    init = ref.seg_init(op, o_ref.dtype)

    @pl.when(row_step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    seg = seg_ref[...].reshape(-1)  # (BLOCK_ROWS*LANES,)
    val = val_ref[...].reshape(-1)
    # this tile covers segment ids [seg_base, seg_base + seg_tile)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (1, seg_tile), 1) + seg_base
    onehot = seg[:, None] == buckets  # (rows, tile); padding (-1) matches none
    if op == "sum" and val.dtype == jnp.float32:
        # MXU path: (1, rows) @ (rows, tile)
        o_ref[...] += jnp.dot(val[None, :], onehot.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
    elif op == "sum":
        o_ref[...] += jnp.sum(jnp.where(onehot, val[:, None], init),
                              axis=0, keepdims=True)
    elif op == "min":
        o_ref[...] = jnp.minimum(
            o_ref[...],
            jnp.min(jnp.where(onehot, val[:, None], init), axis=0,
                    keepdims=True))
    elif op == "max":
        o_ref[...] = jnp.maximum(
            o_ref[...],
            jnp.max(jnp.where(onehot, val[:, None], init), axis=0,
                    keepdims=True))
    else:
        raise ValueError(op)


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "op", "interpret"))
def segment_reduce_tiles(
    values: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Segmented sum/min/max of 1-D `values` into `num_segments` slots.

    seg_ids: (n,) int32; entries outside [0, num_segments) are ignored.
    Empty segments hold the op identity (0 / +inf-like / -inf-like).
    Any segment count is supported: up to MAX_SEGMENTS runs as a single
    output tile (one VMEM-resident block revisited across row steps);
    beyond that the segment axis tiles into a second grid dimension.
    Matches ref.segment_reduce_ref exactly either way.
    """
    assert op in OPS, op
    assert values.ndim == 1 and values.shape == seg_ids.shape, (
        values.shape, seg_ids.shape)
    if interpret is None:
        interpret = interpret_mode()
    (n,) = values.shape
    tile = BLOCK_ROWS * LANES
    n_pad = max(round_up(n, tile), tile)
    if num_segments <= MAX_SEGMENTS:
        seg_tile = max(round_up(num_segments, LANES), LANES)
    else:
        seg_tile = MAX_SEGMENTS
    g_pad = max(round_up(num_segments, seg_tile), seg_tile)
    segp = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(
        seg_ids.astype(jnp.int32)).reshape(n_pad // LANES, LANES)
    valp = jnp.zeros((n_pad,), values.dtype).at[:n].set(values) \
        .reshape(n_pad // LANES, LANES)
    grid = (g_pad // seg_tile, n_pad // tile)  # (segment tiles, row blocks)
    out = pl.pallas_call(
        functools.partial(_seg_kernel, op=op, seg_tile=seg_tile),
        out_shape=jax.ShapeDtypeStruct((1, g_pad), values.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda s, i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, LANES), lambda s, i: (i, 0))],
        out_specs=pl.BlockSpec((1, seg_tile), lambda s, i: (0, s)),
        interpret=interpret,
    )(segp, valp)
    return out.reshape(g_pad)[:num_segments]
