"""Pallas TPU kernel: segmented reduction — the groupby hot path.

After sort-by-key + boundary detection (core/ops_agg.py), aggregation is a
segmented reduction: ``out[g] = op(values[i] for i where seg_ids[i] == g)``.
Scatter-accumulate (the CPU/GPU idiom) serializes on TPU; the native
formulation — same design as kernels/histogram.py — is a one-hot compare
against the segment iota, reduced over the row axis. For f32 sums the
one-hot contraction is a matmul, so the accumulation rides the MXU; min/max
use a masked VPU reduction.

Grid walks row-blocks; each step folds its block's per-segment partials into
the single (1, G) output block (revisited across the grid — Pallas keeps it
VMEM-resident, so HBM sees one read of the rows and one write of G results).
Segment count is capped by MAX_SEGMENTS (the (rows_block, G) one-hot must
fit in VMEM); larger G falls back to the XLA scatter path in kernels/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.utils import interpret_mode, round_up

LANES = 128
BLOCK_ROWS = 8  # (8, 128) = 1024 rows per grid step; (1024, G) one-hot fits VMEM
MAX_SEGMENTS = 1024

OPS = ("sum", "min", "max")


def _seg_kernel(seg_ref, val_ref, o_ref, *, op: str, num_segments: int):
    step = pl.program_id(0)
    init = ref.seg_init(op, o_ref.dtype)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    seg = seg_ref[...].reshape(-1)  # (BLOCK_ROWS*LANES,)
    val = val_ref[...].reshape(-1)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (1, num_segments), 1)
    onehot = seg[:, None] == buckets  # (rows, G); padding (-1) matches nothing
    if op == "sum" and val.dtype == jnp.float32:
        # MXU path: (1, rows) @ (rows, G)
        o_ref[...] += jnp.dot(val[None, :], onehot.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
    elif op == "sum":
        o_ref[...] += jnp.sum(jnp.where(onehot, val[:, None], init),
                              axis=0, keepdims=True)
    elif op == "min":
        o_ref[...] = jnp.minimum(
            o_ref[...],
            jnp.min(jnp.where(onehot, val[:, None], init), axis=0,
                    keepdims=True))
    elif op == "max":
        o_ref[...] = jnp.maximum(
            o_ref[...],
            jnp.max(jnp.where(onehot, val[:, None], init), axis=0,
                    keepdims=True))
    else:
        raise ValueError(op)


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "op", "interpret"))
def segment_reduce_tiles(
    values: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Segmented sum/min/max of 1-D `values` into `num_segments` slots.

    seg_ids: (n,) int32; entries outside [0, num_segments) are ignored.
    Empty segments hold the op identity (0 / +inf-like / -inf-like).
    Matches ref.segment_reduce_ref exactly.
    """
    assert op in OPS, op
    assert values.ndim == 1 and values.shape == seg_ids.shape, (
        values.shape, seg_ids.shape)
    if num_segments > MAX_SEGMENTS:
        # hard error (not an assert stripped by -O): the (rows, G) one-hot
        # would exceed the kernel's VMEM tile budget — silently wrong or
        # OOM. kernels/ops.py::segment_reduce routes oversize calls to the
        # XLA scatter fallback before reaching here.
        raise ValueError(
            f"segment_reduce_tiles: num_segments={num_segments} exceeds "
            f"MAX_SEGMENTS={MAX_SEGMENTS}; call kernels.ops.segment_reduce "
            f"for the XLA fallback routing")
    if interpret is None:
        interpret = interpret_mode()
    (n,) = values.shape
    tile = BLOCK_ROWS * LANES
    n_pad = max(round_up(n, tile), tile)
    g_pad = max(round_up(num_segments, LANES), LANES)
    segp = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(
        seg_ids.astype(jnp.int32)).reshape(n_pad // LANES, LANES)
    valp = jnp.zeros((n_pad,), values.dtype).at[:n].set(values) \
        .reshape(n_pad // LANES, LANES)
    grid = (n_pad // tile,)
    out = pl.pallas_call(
        functools.partial(_seg_kernel, op=op, num_segments=g_pad),
        out_shape=jax.ShapeDtypeStruct((1, g_pad), values.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, g_pad), lambda i: (0, 0)),
        interpret=interpret,
    )(segp, valp)
    return out.reshape(g_pad)[:num_segments]
