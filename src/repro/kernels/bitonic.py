"""Pallas TPU kernel: in-VMEM bitonic (key, payload) sort tile.

Cylon's sort-join local operator is bound by the leaf sort. A pointer-based
quicksort/mergesort does not vectorize on the TPU VPU; the TPU-idiomatic
equivalent is a bitonic comparator network: every compare-exchange pass is a
dense reshape + min/max/where over the whole tile, which maps onto 8x128
vector registers with no data-dependent control flow.

The kernel sorts one tile of TILE (power-of-two) elements entirely in VMEM:
log2(T)*(log2(T)+1)/2 passes, each reading/writing VREGs only — HBM traffic
is one tile read + one tile write total. Larger arrays use the kernel as the
leaf sort (see ops.sort_pairs): XLA's global sort handles the cross-tile
merge; the VMEM-resident leaf is the paper's "cache-efficient local operator"
re-expressed for the HBM->VMEM->VREG hierarchy.

Direction math: at stage k = 2^m, distance j = 2^p (p < m), element index
i = b*2j + s*j + t (s in {0,1}, t < j). Bit m of i equals bit (m-p-1) of b,
so the ascending flag per pair-block is ((b >> (m-p-1)) & 1) == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import interpret_mode, next_pow2

# 2**11 keys + payload = 2 * 8 KiB * 2 arrays (in+out) ... comfortably < VMEM.
# Kept modest because interpret-mode (CPU CI) executes every pass in Python.
DEFAULT_TILE = 1 << 11


def _compare_exchange(keys, vals, m: int, p: int):
    """One bitonic pass at stage 2^m, distance 2^p over flat pow2 arrays."""
    n = keys.shape[0]
    j = 1 << p
    kb = keys.reshape(n // (2 * j), 2, j)
    vb = vals.reshape(n // (2 * j), 2, j)
    b = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1), 0)
    asc = ((b >> (m - p - 1)) & 1) == 0
    lo_k, hi_k = kb[:, 0, :], kb[:, 1, :]
    lo_v, hi_v = vb[:, 0, :], vb[:, 1, :]
    # lexicographic (key, payload) comparator: payload tie-break makes the
    # network a stable sort whenever payloads are distinct (callers pass iota).
    le = (lo_k < hi_k) | ((lo_k == hi_k) & (lo_v <= hi_v))
    keep = le == asc  # True -> keep (lo, hi) order
    nlo_k = jnp.where(keep, lo_k, hi_k)
    nhi_k = jnp.where(keep, hi_k, lo_k)
    nlo_v = jnp.where(keep, lo_v, hi_v)
    nhi_v = jnp.where(keep, hi_v, lo_v)
    keys = jnp.stack([nlo_k, nhi_k], axis=1).reshape(n)
    vals = jnp.stack([nlo_v, nhi_v], axis=1).reshape(n)
    return keys, vals


def _bitonic_kernel(k_ref, v_ref, ko_ref, vo_ref, *, tile: int):
    keys = k_ref[...].reshape(tile)
    vals = v_ref[...].reshape(tile)
    log_t = tile.bit_length() - 1
    # Full static unroll: log_t*(log_t+1)/2 compare-exchange passes.
    for m in range(1, log_t + 1):
        for p in reversed(range(m)):
            keys, vals = _compare_exchange(keys, vals, m, p)
    ko_ref[...] = keys.reshape(k_ref.shape)
    vo_ref[...] = vals.reshape(v_ref.shape)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def bitonic_sort_tiles(
    keys: jax.Array,
    payload: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
):
    """Sort each contiguous tile of (keys, payload) ascending by key.

    keys: (N,) uint32/int32/float32, N a multiple of `tile` (pow2, >=256).
    Returns per-tile-sorted (keys, payload). Full-array sorts pad with the
    dtype max so the tail tile sorts its sentinels to the end (ops.py).
    """
    if interpret is None:
        interpret = interpret_mode()
    (n,) = keys.shape
    assert n % tile == 0 and tile == next_pow2(tile) and tile >= 256, (n, tile)
    lanes = 128
    rows = tile // lanes
    kp = keys.reshape(n // lanes, lanes)
    vp = payload.reshape(n // lanes, lanes)
    grid = (n // tile,)
    ko, vo = pl.pallas_call(
        functools.partial(_bitonic_kernel, tile=tile),
        out_shape=(
            jax.ShapeDtypeStruct(kp.shape, keys.dtype),
            jax.ShapeDtypeStruct(vp.shape, payload.dtype),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(kp, vp)
    return ko.reshape(n), vo.reshape(n)
