"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: tests sweep shapes/dtypes and assert
``assert_allclose(kernel(x), ref(x))`` (exact for the integer kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# murmur3 fmix32 column hash
# ---------------------------------------------------------------------------

_M1 = jnp.uint32(0x85EBCA6B)
_M2 = jnp.uint32(0xC2B2AE35)
_GOLDEN = jnp.uint32(0x9E3779B9)


def fmix32(h: jax.Array) -> jax.Array:
    """murmur3 32-bit finalizer (the avalanche step Cylon's hash kernel uses)."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * _M1
    h = h ^ (h >> 13)
    h = h * _M2
    h = h ^ (h >> 16)
    return h


def hash32_ref(x: jax.Array, seed: int = 0) -> jax.Array:
    """Hash a column of int32/uint32/float32 to uint32.

    Floats are hashed by bit pattern (so -0.0 != 0.0; callers canonicalize).
    """
    if jnp.issubdtype(x.dtype, jnp.floating):
        x = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    x = x.astype(jnp.uint32)
    return fmix32(x ^ jnp.uint32(seed))


def hash_combine_ref(h1: jax.Array, h2: jax.Array) -> jax.Array:
    """boost::hash_combine — order-sensitive multi-column hash accumulator."""
    h1 = h1.astype(jnp.uint32)
    h2 = h2.astype(jnp.uint32)
    return h1 ^ (h2 + _GOLDEN + (h1 << 6) + (h1 >> 2))


# ---------------------------------------------------------------------------
# bitonic key+payload sort
# ---------------------------------------------------------------------------


def sort_pairs_ref(keys: jax.Array, payload: jax.Array):
    """Ascending sort of (keys, payload) by keys. Oracle: jax.lax.sort."""
    return jax.lax.sort((keys, payload), num_keys=1)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Materialized-softmax GQA attention oracle. q (B,S,H,hd); k/v (B,T,KV,hd)."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# segmented reduction
# ---------------------------------------------------------------------------


def seg_init(op: str, dtype) -> jax.Array:
    """Identity element of `op` for `dtype` (the empty-segment fill value)."""
    dtype = jnp.dtype(dtype)
    if op == "sum":
        return jnp.zeros((), dtype)
    lo, hi = (
        (jnp.array(-jnp.inf, dtype), jnp.array(jnp.inf, dtype))
        if jnp.issubdtype(dtype, jnp.floating)
        else (jnp.array(jnp.iinfo(dtype).min, dtype),
              jnp.array(jnp.iinfo(dtype).max, dtype))
    )
    return hi if op == "min" else lo


def segment_reduce_ref(values: jax.Array, seg_ids: jax.Array,
                       num_segments: int, op: str = "sum") -> jax.Array:
    """Dense one-hot segmented reduction (sum/min/max) — the semantics oracle.

    values: (n, ...) ; seg_ids: (n,) int32, entries outside [0, num_segments)
    (padding uses -1) contribute nothing. Empty segments hold the identity.
    """
    onehot = seg_ids[:, None] == jnp.arange(num_segments)[None, :]  # (n, G)
    onehot = onehot.reshape(onehot.shape + (1,) * (values.ndim - 1))
    v = values[:, None]
    init = seg_init(op, values.dtype)
    if op == "sum":
        return jnp.sum(jnp.where(onehot, v, init), axis=0)
    if op == "min":
        return jnp.min(jnp.where(onehot, v, init), axis=0)
    if op == "max":
        return jnp.max(jnp.where(onehot, v, init), axis=0)
    raise ValueError(op)


# ---------------------------------------------------------------------------
# segmented prefix scan
# ---------------------------------------------------------------------------

_SCAN_OPS = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}


def segment_scan_ref(values: jax.Array, seg_ids: jax.Array,
                     op: str = "sum", inclusive: bool = True) -> jax.Array:
    """Segmented running sum/min/max over contiguous segment runs.

    ``out[i] = op(values[j] for j <= i with seg_ids[j] == seg_ids[i])``
    (strict ``j < i`` when ``inclusive=False``, identity when a row has no
    in-segment predecessor). seg_ids must form contiguous runs (sorted;
    trailing -1 padding allowed) — the (segment, value) pair combinator is
    associative only under that contract. Oracle: jax.lax.associative_scan.
    """
    f = _SCAN_OPS[op]

    def combine(a, b):
        sa, va = a
        sb, vb = b
        return sb, jnp.where(sa == sb, f(va, vb), vb)

    _, incl = jax.lax.associative_scan(combine, (seg_ids, values))
    if inclusive:
        return incl
    init = seg_init(op, values.dtype)
    same_prev = (seg_ids == jnp.roll(seg_ids, 1)).at[0].set(False)
    return jnp.where(same_prev, jnp.roll(incl, 1), init)


# ---------------------------------------------------------------------------
# bucket histogram
# ---------------------------------------------------------------------------


def histogram_ref(ids: jax.Array, num_buckets: int) -> jax.Array:
    """Count of ids per bucket; ids outside [0, num_buckets) are ignored."""
    valid = (ids >= 0) & (ids < num_buckets)
    return jnp.sum(
        jnp.where(valid[:, None], ids[:, None] == jnp.arange(num_buckets)[None, :], False),
        axis=0,
        dtype=jnp.int32,
    )
