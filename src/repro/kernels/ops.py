"""Public jit'd wrappers around the Pallas kernels (with composition helpers).

The core library calls these — never the kernels directly — so the
kernel/fallback choice, padding and multi-column combination live in one
place. Off-TPU everything runs with interpret=True (bit-exact semantics).
"""
from __future__ import annotations

import functools
import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp

from repro.core import faults as FLT
from repro.kernels import ref
from repro.kernels.bitonic import DEFAULT_TILE, bitonic_sort_tiles
from repro.kernels.hash64 import hash32
from repro.kernels.histogram import bucket_histogram
from repro.kernels.segment_reduce import MAX_SEGMENTS, segment_reduce_tiles
from repro.kernels.segment_scan import segment_scan_tiles
from repro.utils import interpret_mode, next_pow2

__all__ = [
    "hash32",
    "hash_columns",
    "bucket_histogram",
    "sort_pairs",
    "segment_reduce",
    "segment_scan",
    "key_max",
    "oracle_scope",
    "oracle_only",
]


# -- the kernel -> XLA-oracle degradation rung -------------------------------
# DistContext's recovery ladder re-executes a failed plan with every Pallas
# segment kernel swapped for its bit-identical XLA oracle. The flag is
# thread-local and consulted at TRACE time (resolution below happens
# outside the inner jits, so it always takes effect — a cached trace of
# the kernel path cannot shadow it).

_oracle = threading.local()


def oracle_only() -> bool:
    """True while the calling thread is inside :func:`oracle_scope`."""
    return getattr(_oracle, "depth", 0) > 0


@contextmanager
def oracle_scope():
    """Force every segment kernel to its XLA oracle on this thread — the
    ``oracle-kernel`` recovery rung (bit-identical on the integer-valued
    inputs the engine produces)."""
    _oracle.depth = getattr(_oracle, "depth", 0) + 1
    try:
        yield
    finally:
        _oracle.depth -= 1


def _kernel_fault(out: jax.Array) -> jax.Array:
    """Apply an armed ``kernel.dispatch`` fault: raise, or return ``out``
    NaN-poisoned (floats only — result validation detects the NaNs and
    quarantines the run). No-op when no fault fires."""
    fp = FLT.check("kernel.dispatch")
    if fp is None:
        return out
    mode = fp.effective_mode
    if mode == "nan" and jnp.issubdtype(out.dtype, jnp.floating):
        return jnp.full_like(out, jnp.nan)
    raise FLT.FaultError("kernel.dispatch", f"mode={mode}")


def hash_columns(columns: list[jax.Array], seed: int = 0) -> jax.Array:
    """Row-wise uint32 hash over one or more columns (order-sensitive).

    This is the paper's multi-column record hash used by hash-partition,
    hash-join, union/intersect/difference (which hash the whole row).
    """
    assert columns, "hash_columns needs at least one column"
    h = hash32(columns[0], seed=seed)
    for c in columns[1:]:
        h = ref.hash_combine_ref(h, hash32(c, seed=seed))
    return h


def key_max(dtype) -> jax.Array:
    """Sentinel that sorts after every real key of `dtype`."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def segment_reduce(
    values: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    *,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Segmented sum/min/max: out[g] = op(values[i] where seg_ids[i] == g).

    values: (n, ...) — reductions run along the leading axis; seg_ids: (n,)
    int32, entries outside [0, num_segments) (padding uses -1) are ignored.
    Empty segments hold the op identity (ref.seg_init).

    The Pallas one-hot kernel handles 1-D f32/i32 values at ANY segment
    count — counts past MAX_SEGMENTS tile the segment axis in a second
    grid dimension (kernels/segment_reduce.py). N-D payloads fall back to
    XLA scatter-reduce; ``use_kernel=False`` forces that path (the
    bit-identical oracle the tests sweep against).

    Auto (``use_kernel=None``) prefers the kernel wherever it actually
    runs AS a kernel; under interpret mode (no TPU — tests, CPU CI) the
    emulated multi-tile one-hot is far slower than XLA scatter, so auto
    only takes the kernel path for single-tile segment counts there.

    Resolution happens HERE, outside the jit: :func:`oracle_scope` (the
    recovery ladder) overrides any choice to the XLA path, and an armed
    ``kernel.dispatch`` fault acts only when the kernel path is taken —
    so a degraded re-execution provably avoids the faulted site.
    """
    assert op in ("sum", "min", "max"), op
    assert seg_ids.ndim == 1 and values.shape[0] == seg_ids.shape[0], (
        values.shape, seg_ids.shape)
    shape_ok = values.ndim == 1 and values.dtype in (jnp.float32, jnp.int32)
    if use_kernel is None:
        use_kernel = shape_ok and (num_segments <= MAX_SEGMENTS
                                   or not interpret_mode())
    elif use_kernel and not shape_ok:
        raise ValueError(
            f"segment_reduce kernel needs 1-D f32/i32 values; got "
            f"shape={values.shape} dtype={values.dtype}. Use "
            f"use_kernel=None for the XLA fallback.")
    if use_kernel and oracle_only():
        use_kernel = False
    out = _segment_reduce_jit(values, seg_ids, num_segments, op, use_kernel)
    return _kernel_fault(out) if use_kernel else out


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "use_kernel"))
def _segment_reduce_jit(values, seg_ids, num_segments, op, use_kernel):
    if use_kernel:
        return segment_reduce_tiles(values, seg_ids, num_segments, op)
    init = ref.seg_init(op, values.dtype)
    out = jnp.full((num_segments,) + values.shape[1:], init, values.dtype)
    # out-of-range ids -> num_segments, dropped by the scatter
    idx = jnp.where((seg_ids >= 0) & (seg_ids < num_segments),
                    seg_ids, num_segments)
    at = out.at[idx]
    scatter = {"sum": at.add, "min": at.min, "max": at.max}[op]
    return scatter(values, mode="drop")


def segment_scan(
    values: jax.Array,
    seg_ids: jax.Array,
    op: str = "sum",
    *,
    inclusive: bool = True,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Segmented running sum/min/max along the row axis (window hot path).

    ``out[i] = op(values[j] for j <= i with seg_ids[j] == seg_ids[i])``
    (strict ``j < i`` when ``inclusive=False``; rows without an in-segment
    predecessor hold the op identity). seg_ids: (n,) int32 contiguous runs
    — the sorted-segment layout ``core/ops_agg`` produces — with trailing
    -1 padding allowed.

    The Pallas kernel (kernels/segment_scan.py) handles 1-D f32/i32
    values; ``use_kernel=False`` forces the XLA ``associative_scan``
    oracle (bit-identical on integer-valued inputs). Auto prefers the
    kernel only where it actually runs AS a kernel: under interpret mode
    (no TPU — tests, CPU CI) the emulated per-block triangular mask is
    far slower than XLA's native scan.
    """
    assert op in ("sum", "min", "max"), op
    assert seg_ids.ndim == 1 and values.shape == seg_ids.shape, (
        values.shape, seg_ids.shape)
    shape_ok = values.ndim == 1 and values.dtype in (jnp.float32, jnp.int32)
    if use_kernel is None:
        use_kernel = shape_ok and not interpret_mode()
    elif use_kernel and not shape_ok:
        raise ValueError(
            f"segment_scan kernel needs 1-D f32/i32 values; got "
            f"shape={values.shape} dtype={values.dtype}. Use "
            f"use_kernel=None for the XLA fallback.")
    if use_kernel and oracle_only():
        use_kernel = False
    out = _segment_scan_jit(values, seg_ids, op, inclusive, use_kernel)
    return _kernel_fault(out) if use_kernel else out


@functools.partial(jax.jit,
                   static_argnames=("op", "inclusive", "use_kernel"))
def _segment_scan_jit(values, seg_ids, op, inclusive, use_kernel):
    if use_kernel:
        return segment_scan_tiles(values, seg_ids, op, inclusive=inclusive)
    return ref.segment_scan_ref(values, seg_ids, op, inclusive)


@functools.partial(jax.jit, static_argnames=("tile", "use_kernel"))
def sort_pairs(
    keys: jax.Array,
    payload: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    use_kernel: bool | None = None,
):
    """Full ascending (keys, payload) sort.

    Strategy (see kernels/bitonic.py): the Pallas bitonic tile is the
    VMEM-resident leaf sort; arrays larger than one tile fall back to XLA's
    global sort (whose TPU lowering is itself a vectorized merge network).
    `use_kernel=False` forces the XLA path — benchmarks compare the two.
    """
    if use_kernel is None:
        use_kernel = True
    (n,) = keys.shape
    if not use_kernel or n > tile:
        return jax.lax.sort((keys, payload), num_keys=1)
    n_pad = max(next_pow2(n), 256)
    kp = jnp.full((n_pad,), key_max(keys.dtype), keys.dtype).at[:n].set(keys)
    vp = jnp.zeros((n_pad,), payload.dtype).at[:n].set(payload)
    ko, vo = bitonic_sort_tiles(kp, vp, tile=n_pad)
    return ko[:n], vo[:n]
