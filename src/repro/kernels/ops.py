"""Public jit'd wrappers around the Pallas kernels (with composition helpers).

The core library calls these — never the kernels directly — so the
kernel/fallback choice, padding and multi-column combination live in one
place. Off-TPU everything runs with interpret=True (bit-exact semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitonic import DEFAULT_TILE, bitonic_sort_tiles
from repro.kernels.hash64 import hash32
from repro.kernels.histogram import bucket_histogram
from repro.utils import next_pow2

__all__ = [
    "hash32",
    "hash_columns",
    "bucket_histogram",
    "sort_pairs",
    "key_max",
]


def hash_columns(columns: list[jax.Array], seed: int = 0) -> jax.Array:
    """Row-wise uint32 hash over one or more columns (order-sensitive).

    This is the paper's multi-column record hash used by hash-partition,
    hash-join, union/intersect/difference (which hash the whole row).
    """
    assert columns, "hash_columns needs at least one column"
    h = hash32(columns[0], seed=seed)
    for c in columns[1:]:
        h = ref.hash_combine_ref(h, hash32(c, seed=seed))
    return h


def key_max(dtype) -> jax.Array:
    """Sentinel that sorts after every real key of `dtype`."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


@functools.partial(jax.jit, static_argnames=("tile", "use_kernel"))
def sort_pairs(
    keys: jax.Array,
    payload: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    use_kernel: bool | None = None,
):
    """Full ascending (keys, payload) sort.

    Strategy (see kernels/bitonic.py): the Pallas bitonic tile is the
    VMEM-resident leaf sort; arrays larger than one tile fall back to XLA's
    global sort (whose TPU lowering is itself a vectorized merge network).
    `use_kernel=False` forces the XLA path — benchmarks compare the two.
    """
    if use_kernel is None:
        use_kernel = True
    (n,) = keys.shape
    if not use_kernel or n > tile:
        return jax.lax.sort((keys, payload), num_keys=1)
    n_pad = max(next_pow2(n), 256)
    kp = jnp.full((n_pad,), key_max(keys.dtype), keys.dtype).at[:n].set(keys)
    vp = jnp.zeros((n_pad,), payload.dtype).at[:n].set(payload)
    ko, vo = bitonic_sort_tiles(kp, vp, tile=n_pad)
    return ko[:n], vo[:n]
