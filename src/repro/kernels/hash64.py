"""Pallas TPU kernel: murmur3-fmix32 column hash (Cylon's hash-partition hot spot).

The paper's hash-partition / hash-join local operators are bound by per-row
hashing + bucketing throughput on the CPU. On TPU the same hot spot is a
pure-VPU elementwise pipeline; the kernel tiles the column through VMEM in
(8, 128)-aligned blocks so HBM traffic is exactly one read + one write per
element (arithmetic intensity is tiny — this op is memory-bound by design,
see benchmarks/bench_kernels.py).

Layout: a column of N rows is padded to a multiple of ``BLOCK_ROWS * 128``
and viewed as (N/128, 128); the grid walks row-blocks of BLOCK_ROWS.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import interpret_mode, round_up

LANES = 128
BLOCK_ROWS = 64  # (64, 128) uint32 tile = 32 KiB in / 32 KiB out of VMEM

def _hash_kernel(x_ref, o_ref, *, seed: int):
    x = x_ref[...]
    if jnp.issubdtype(x.dtype, jnp.floating):
        x = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    h = x.astype(jnp.uint32) ^ jnp.uint32(seed)
    # murmur3 fmix32 avalanche — wraps naturally in uint32.
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    o_ref[...] = h


@functools.partial(jax.jit, static_argnames=("seed", "interpret"))
def hash32(x: jax.Array, seed: int = 0, *, interpret: bool | None = None) -> jax.Array:
    """Hash a 1-D column to uint32 with the Pallas kernel.

    Accepts int32/uint32/float32 (floats hashed by bit pattern). Output
    matches :func:`repro.kernels.ref.hash32_ref` exactly.
    """
    if interpret is None:
        interpret = interpret_mode()
    (n,) = x.shape
    tile = BLOCK_ROWS * LANES
    n_pad = max(round_up(n, tile), tile)
    xp = jnp.zeros((n_pad,), x.dtype).at[:n].set(x).reshape(n_pad // LANES, LANES)
    grid = (n_pad // tile,)
    out = pl.pallas_call(
        functools.partial(_hash_kernel, seed=seed),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.uint32),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        interpret=interpret,
    )(xp)
    return out.reshape(n_pad)[:n]
