"""Pallas TPU kernel: segmented prefix scan — the window-function hot path.

Window functions (core/ops_agg.window) reduce to *segment scans* over the
sorted frame: after sort-by-(keys, order) + boundary detection, ``rank`` is
a segmented running max, ``dense_rank``/``cumsum``/``running_mean`` are
segmented running sums, ``cummax`` a running max — all over contiguous
per-group runs of rows.

The kernel formulation mirrors kernels/segment_reduce.py's one-hot idiom,
tiled along the segment-sorted row axis: the grid walks row blocks in
order, and each block materializes the (BLOCK, BLOCK) *triangular same-
segment* mask — ``mask[i, j] = (j < i) & (seg[j] == seg[i])`` — so the
exclusive scan of a block is one masked reduction over the j axis (an MXU
matmul for f32 sums, a VPU min/max otherwise). TPU grid steps execute
sequentially and output blocks with a constant index map stay VMEM-
resident, so the cross-block carry (the running value and segment id at
the previous block's last row) lives in two (1, 1) output refs revisited
by every step — the same persistence contract segment_reduce relies on
for its output tiles.

Requirements: segment ids form contiguous runs (non-decreasing, as
produced by sort + cumsum-of-boundaries), with -1 allowed as trailing
padding. ``ref.segment_scan_ref`` (jax.lax.associative_scan over
(segment, value) pairs) is the bit-exact oracle under integer or
integer-valued-float inputs; kernels/ops.py routes ``use_kernel=False``
(and CPU interpret mode, where the emulated triangular mask is far slower
than XLA's scan) to it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.utils import interpret_mode, round_up

LANES = 128
BLOCK_ROWS = 8
#: rows per grid step — the (BLOCK, BLOCK) triangular mask is the VMEM
#: budget (1 MiB of bool + a 4 MiB f32 one-hot on the matmul path), the
#: same block budget segment_reduce spends on its one-hot.
BLOCK = BLOCK_ROWS * LANES  # 1024

OPS = ("sum", "min", "max")


def _scan_kernel(seg_ref, val_ref, o_ref, cval_ref, cseg_ref, *,
                 op: str, inclusive: bool):
    step = pl.program_id(0)
    init = ref.seg_init(op, o_ref.dtype)

    @pl.when(step == 0)
    def _init():
        cval_ref[...] = jnp.full_like(cval_ref, init)
        # -2 matches no real segment id (>= 0) and no -1 padding
        cseg_ref[...] = jnp.full_like(cseg_ref, -2)

    seg = seg_ref[...].reshape(-1)  # (BLOCK,)
    val = val_ref[...].reshape(-1)
    n = seg.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    # strict triangle: row i's EXCLUSIVE prefix within its segment run
    mask = (jj < ii) & (seg[None, :] == seg[:, None])
    if op == "sum" and val.dtype == jnp.float32:
        # MXU path: (n, n) @ (n, 1)
        excl = jnp.dot(mask.astype(jnp.float32), val[:, None],
                       preferred_element_type=jnp.float32).reshape(-1)
    elif op == "sum":
        excl = jnp.sum(jnp.where(mask, val[None, :], jnp.zeros_like(init)),
                       axis=1)
    elif op == "min":
        excl = jnp.min(jnp.where(mask, val[None, :], init), axis=1)
    else:  # max
        excl = jnp.max(jnp.where(mask, val[None, :], init), axis=1)

    # fold the previous blocks' carry into rows continuing its segment
    cont = seg == cseg_ref[0, 0]
    carry = jnp.where(cont, cval_ref[0, 0], init)
    if op == "sum":
        excl = excl + carry
        incl = excl + val
    elif op == "min":
        excl = jnp.minimum(excl, carry)
        incl = jnp.minimum(excl, val)
    else:
        excl = jnp.maximum(excl, carry)
        incl = jnp.maximum(excl, val)

    out = incl if inclusive else excl
    o_ref[...] = out.reshape(o_ref.shape)
    cval_ref[0, 0] = incl[n - 1]
    cseg_ref[0, 0] = seg[n - 1]


@functools.partial(jax.jit,
                   static_argnames=("op", "inclusive", "interpret"))
def segment_scan_tiles(
    values: jax.Array,
    seg_ids: jax.Array,
    op: str = "sum",
    *,
    inclusive: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Segmented running sum/min/max of 1-D ``values`` along the row axis.

    ``out[i] = op(values[j] for j <= i with seg_ids[j] == seg_ids[i])``
    (``j < i`` when ``inclusive=False``; rows with no in-segment
    predecessor hold the op identity). seg_ids: (n,) int32 contiguous
    runs — non-decreasing, -1 trailing padding allowed. Matches
    ``ref.segment_scan_ref`` exactly on integer-valued inputs.
    """
    assert op in OPS, op
    assert values.ndim == 1 and values.shape == seg_ids.shape, (
        values.shape, seg_ids.shape)
    if interpret is None:
        interpret = interpret_mode()
    (n,) = values.shape
    n_pad = max(round_up(n, BLOCK), BLOCK)
    segp = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(
        seg_ids.astype(jnp.int32)).reshape(n_pad // LANES, LANES)
    valp = jnp.zeros((n_pad,), values.dtype).at[:n].set(values) \
        .reshape(n_pad // LANES, LANES)
    grid = (n_pad // BLOCK,)
    out, _, _ = pl.pallas_call(
        functools.partial(_scan_kernel, op=op, inclusive=inclusive),
        out_shape=[jax.ShapeDtypeStruct((n_pad // LANES, LANES),
                                        values.dtype),
                   jax.ShapeDtypeStruct((1, 1), values.dtype),  # carry val
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],    # carry seg
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda s: (s, 0)),
                  pl.BlockSpec((BLOCK_ROWS, LANES), lambda s: (s, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda s: (s, 0)),
                   pl.BlockSpec((1, 1), lambda s: (0, 0)),
                   pl.BlockSpec((1, 1), lambda s: (0, 0))],
        interpret=interpret,
    )(segp, valp)
    return out.reshape(n_pad)[:n]
