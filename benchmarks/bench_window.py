"""Window functions: pre-sorted (boundary-carry) vs naive shuffle lowering.

Distributed window functions over an already-sorted frame need NO data
movement beyond a p-sized boundary ``all_gather`` of per-shard carry
state — the fused sort -> window chain runs the window at 0 AllToAlls and
0 wire bytes. The naive lowering (what Dask/Spark pay: repartition before
every windowed stage) range-shuffles the whole table again. The table
reports AllToAll counts, dense wire bytes, wall clock, and bit-identity
against the single-host local operator (integer-valued float payloads: no
reduction-order bit drift).

Asserts — also enforced when CI uploads the JSON — that the window step
on the pre-sorted path moves ZERO wire bytes, that the chain as a whole
ships strictly fewer bytes than the naive lowering, and that both paths
are bit-identical to the local oracle for all 8 window functions.

Each measurement runs in a fresh subprocess: the 8-device host platform
must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8
FUNCS = ["rank", "dense_rank", "row_number", ("lag", "d0"), ("lead", "d0"),
         ("cumsum", "d0"), ("cummax", "d0"), ("running_mean", "d0")]


def run_worker(rows_per_worker: int, num_groups: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_window", "--worker",
         "--rows-per-worker", str(rows_per_worker),
         "--num-groups", str(num_groups)],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--num-groups", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from benchmarks.common import timeit
    from repro.core import ops_agg as A
    from repro.core.context import DistContext
    from repro.core.table import Table as T

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    cap = args.rows_per_worker
    n = cap * WORKERS
    rng = np.random.default_rng(77)
    # few groups over many shards: nearly every group spans shard
    # boundaries, so the carry fold is doing real stitching; unique order
    # values keep every function deterministic -> bit-comparable
    k = rng.integers(0, args.num_groups, n).astype(np.int32)
    o = rng.permutation(n).astype(np.int32)
    d0 = rng.integers(-50, 50, n).astype(np.float32)
    parts = [T.from_arrays({"k": k[i * cap:(i + 1) * cap],
                            "o": o[i * cap:(i + 1) * cap],
                            "d0": d0[i * cap:(i + 1) * cap]})
             for i in range(WORKERS)]
    dt = ctx.from_local_parts(parts)
    bucket = 2 * cap  # skew-proof: a range bucket can absorb a whole shard

    def ov(stats):
        return sum(int(np.asarray(s.overflow).sum()) for s in stats)

    # single-host oracle: the local operator (oracle-verified in tests)
    local = A.window(T.from_arrays({"k": k, "o": o, "d0": d0}), "k", FUNCS,
                     order_by="o").to_numpy()

    # the frame both paths start from: a dist_sort output. The pre-sorted
    # lowering uses its RangePartitioning provenance (window elides to a
    # boundary all_gather); the naive lowering sees the SAME bytes with
    # the provenance stripped — what every engine without placement
    # tracking pays — and range-shuffles the whole table again.
    import dataclasses

    s, _ = ctx.sort(dt, ["k", "o"], bucket_capacity=bucket)
    s_naive = dataclasses.replace(s, partitioning=None)
    pres = ctx.frame(s).window("k", FUNCS, order_by="o")
    naive = ctx.frame(s_naive).window("k", FUNCS, order_by="o",
                                      bucket_capacity=bucket)

    nrep, prep = naive.plan_report(), pres.plan_report()
    n_out, n_stats = naive.collect_with_stats()
    p_out, p_stats = pres.collect_with_stats()
    assert ov(n_stats) == 0, f"naive overflow {ov(n_stats)}"
    assert ov(p_stats) == 0, f"pre-sorted overflow {ov(p_stats)}"

    def identical(out):
        d = out.to_table().to_numpy()
        return all(np.array_equal(d[name], local[name]) for name in local)

    win = [r for r in prep if r["op"] == "window"]
    assert len(win) == 1 and win[0]["elided"], win
    naive_ok, pres_ok = identical(n_out), identical(p_out)

    secs_naive = timeit(lambda: naive.collect().row_counts, warmup=1,
                        iters=3)
    secs_pres = timeit(lambda: pres.collect().row_counts, warmup=1,
                       iters=3)

    print("RESULT:" + json.dumps({
        "rows": n, "groups": args.num_groups,
        "naive_identical": bool(naive_ok),
        "presorted_identical": bool(pres_ok),
        "naive_alltoall": sum(not r["elided"] for r in nrep),
        "presorted_alltoall": sum(not r["elided"] for r in prep),
        "presorted_wire_mb": sum(r["wire_bytes"] for r in prep) / 1e6,
        "naive_wire_mb": sum(r["wire_bytes"] for r in nrep) / 1e6,
        "naive_seconds": secs_naive, "presorted_seconds": secs_pres,
    }))


def main(quick: bool = False):
    rpw = 2_000 if quick else 20_000
    r = run_worker(rpw, num_groups=12)
    assert r["naive_identical"] and r["presorted_identical"], r
    assert r["presorted_alltoall"] == 0, r  # boundary all_gather only
    assert r["presorted_wire_mb"] == 0.0, r
    assert r["presorted_wire_mb"] < r["naive_wire_mb"], r
    t = Table(
        f"window functions over a dist_sort output (P={WORKERS}, "
        f"{rpw} rows/worker, 8 funcs): boundary-carry elision vs the "
        "naive re-shuffle lowering",
        ["mode", "alltoall", "wire_mb", "seconds", "identical"])
    t.add("naive", r["naive_alltoall"], round(r["naive_wire_mb"], 3),
          r["naive_seconds"], r["naive_identical"])
    t.add("pre-sorted", r["presorted_alltoall"],
          round(r["presorted_wire_mb"], 3), r["presorted_seconds"],
          r["presorted_identical"])
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main([a for a in sys.argv[1:] if a != "--json"])
    else:
        import argparse

        ap = argparse.ArgumentParser(description=__doc__)
        ap.add_argument("--quick", action="store_true")
        ap.add_argument("--json", metavar="PATH", default=None)
        args = ap.parse_args()
        table = main(args.quick)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quick": args.quick,
                           "sections": {"window": [table.to_dict()]}},
                          f, indent=2, default=str)
            print(f"[json] wrote {args.json}")
