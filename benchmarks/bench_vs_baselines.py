"""Paper Fig. 9 / Table II: Cylon vs Spark vs Dask — adapted as the jitted
XLA relational ops vs (a) a NumPy per-partition engine ("dask-like": python
orchestration over numpy partitions) and (b) a pure-Python row-at-a-time
engine ("RDD-like": the per-row overhead regime of JVM/Python big-data
stacks). Same workload as the paper: int key + payload, inner join and
union-distinct.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Table, timeit, timeit_host
from repro.core import ops_local as L
from repro.core.table import Table as RTable
from repro.data.synthetic import random_table

import jax


def _numpy_join(ka, kb):
    """Partitioned sort-merge join in NumPy (per-partition python loop)."""
    parts = 8
    out = 0
    ha = ka % parts
    hb = kb % parts
    for p in range(parts):
        a = np.sort(ka[ha == p])
        b = np.sort(kb[hb == p])
        ia = np.searchsorted(b, a, side="left")
        ib = np.searchsorted(b, a, side="right")
        out += int((ib - ia).sum())
    return out


def _python_join(ka, kb):
    """Row-at-a-time hash join (the RDD-ish regime)."""
    ht = {}
    for k in kb:
        ht[k] = ht.get(k, 0) + 1
    n = 0
    for k in ka:
        n += ht.get(k, 0)
    return n


def _numpy_union(ka, kb):
    return np.union1d(ka, kb).shape[0]


def _python_union(ka, kb):
    return len(set(ka) | set(kb))


def main(quick: bool = False):
    n = 50_000 if quick else 400_000
    a = random_table(n, key_range=n, seed=1)
    b = random_table(n, key_range=n, seed=2)
    ka = np.asarray(a.columns["k"])
    kb = np.asarray(b.columns["k"])
    ka_l = ka.tolist()
    kb_l = kb.tolist()

    t = Table(f"Fig9/TableII: engine comparison (inner join + union, "
              f"n={n} rows/side)",
              ["op", "engine", "seconds", "speedup_vs_python"])

    # ours: jitted relational ops on Tables
    ta = RTable.from_arrays({"k": a.columns["k"]})
    tb = RTable.from_arrays({"k": b.columns["k"]})
    join_fn = jax.jit(lambda x, y: L.join(
        x, y, "k", algorithm="hash", out_capacity=4 * n).row_count)
    union_fn = jax.jit(lambda x, y: L.union(x, y).row_count)

    t_j_ours = timeit(join_fn, ta, tb)
    t_j_np = timeit_host(_numpy_join, ka, kb)
    t_j_py = timeit_host(_python_join, ka_l, kb_l, iters=1)
    t.add("inner_join", "cylon-jax (jit)", t_j_ours, t_j_py / t_j_ours)
    t.add("inner_join", "numpy-partitioned", t_j_np, t_j_py / t_j_np)
    t.add("inner_join", "python-rows", t_j_py, 1.0)

    t_u_ours = timeit(union_fn, ta, tb)
    t_u_np = timeit_host(_numpy_union, ka, kb)
    t_u_py = timeit_host(_python_union, ka_l, kb_l, iters=1)
    t.add("union", "cylon-jax (jit)", t_u_ours, t_u_py / t_u_ours)
    t.add("union", "numpy-partitioned", t_u_np, t_u_py / t_u_np)
    t.add("union", "python-rows", t_u_py, 1.0)

    # correctness cross-check
    ours = int(jax.block_until_ready(join_fn(ta, tb)))
    assert ours == _numpy_join(ka, kb) == _python_join(ka_l, kb_l)
    assert int(union_fn(ta, tb)) == _numpy_union(ka, kb)

    t.emit()
    return t


if __name__ == "__main__":
    import sys
    main("--quick" in sys.argv)
