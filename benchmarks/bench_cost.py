"""Cost-model-driven physical planning vs the fixed-slack baseline.

Sweeps key cardinality over the same groupby pipeline twice per point:
once over a raw (no-stats) table — the optimizer falls back to the
documented ``two_phase`` strategy and the ``FALLBACK_SLACK`` capacity
heuristic — and once over the SAME table after ``ctx.analyze`` (one
vectorized stats pass: row counts + per-key NDV sketch). With stats the
optimizer picks the strategy per node from the arXiv:2010.14596
crossover (``two_phase`` while ``shards * NDV < rows``, raw ``shuffle``
above it) and right-sizes the AllToAll bucket from estimated occupancy
instead of table capacity.

Asserted at BOTH sweep ends (also under CI's --quick smoke):
  * the model picks the cheaper strategy (two_phase low, shuffle high);
  * the cost-sized plan ships strictly fewer dense wire bytes
    (workers^2 x bucket x row_bytes) than the fixed-slack baseline;
  * results are bit-identical to the eager oracle (integer-valued float
    payloads: aggregation order cannot perturb bits);
  * no overflow and no safe-capacity retry (the estimates held).

Tables are deliberately HALF-FULL (capacity = 2x rows): the fixed-slack
path can only see capacity, the stats path knows the true row count —
the structural advantage this benchmark quantifies.

Each measurement runs in a fresh subprocess: the 8-device host platform
must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8
AGGS = (("d0", "sum"), ("d0", "count"), ("d0", "min"), ("d0", "max"))


def run_worker(rows_per_worker: int, key_range: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_cost", "--worker",
         "--rows-per-worker", str(rows_per_worker),
         "--key-range", str(key_range)],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--key-range", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from benchmarks.common import timeit
    from repro.core.context import DistContext
    from repro.core.table import Table as T

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    rows, kr = args.rows_per_worker, args.key_range

    def int_table(seed):
        """Integer-valued float payloads (bit-exact sums), half-full."""
        rng = np.random.default_rng(seed)
        return T.from_arrays({
            "k": rng.integers(0, kr, rows).astype(np.int32),
            "d0": rng.integers(-40, 40, rows).astype(np.float32)},
            capacity=2 * rows)

    raw = ctx.from_local_parts([int_table(100 + i) for i in range(WORKERS)])
    analyzed = ctx.analyze(raw)

    base = ctx.frame(raw).groupby("k", AGGS)        # fixed-slack fallback
    cost = ctx.frame(analyzed).groupby("k", AGGS)   # stats-driven

    strategy = cost.optimized().strategy
    base_rep, cost_rep = base.plan_report(), cost.plan_report()
    base_wire = sum(r["wire_bytes"] for r in base_rep)
    cost_wire = sum(r["wire_bytes"] for r in cost_rep)

    eager, _ = ctx.groupby(raw, "k", AGGS)  # the oracle both must match
    b_out = base.collect()
    c_out, c_stats = cost.collect_with_stats()
    overflow = sum(int(np.asarray(s.overflow).sum()) for s in c_stats)

    from repro.testing.compare import tables_bitwise_equal
    secs_base = timeit(lambda: base.collect().row_counts, warmup=1, iters=3)
    secs_cost = timeit(lambda: cost.collect().row_counts, warmup=1, iters=3)

    print("RESULT:" + json.dumps({
        "rows": rows * WORKERS, "key_range": kr,
        "groups": int(np.asarray(c_out.global_rows())),
        "strategy": strategy,
        "base_wire_mb": base_wire / 1e6, "cost_wire_mb": cost_wire / 1e6,
        "base_seconds": secs_base, "cost_seconds": secs_cost,
        "identical": bool(tables_bitwise_equal(eager, c_out)
                          and tables_bitwise_equal(eager, b_out)),
        "overflow": overflow, "retries": ctx.overflow_retries,
    }))


def main(quick: bool = False):
    rpw = 1_000 if quick else 10_000
    # sweep ends: NDV 32 (p*ndv << rows -> two_phase) up to a key range
    # several times the global row count (ndv ~ rows -> raw shuffle)
    sweep = [(32, "two_phase"), (rpw * WORKERS * 4, "shuffle")]
    t = Table(
        f"cost-model planning (P={WORKERS}, {rpw} rows/worker, half-full "
        "capacity): stats-driven strategy choice + right-sized buckets vs "
        "the fixed-slack no-stats baseline",
        ["key_range", "strategy", "groups", "base_wire_mb", "cost_wire_mb",
         "wire_reduction", "base_seconds", "cost_seconds", "identical"])
    for kr, expect in sweep:
        r = run_worker(rpw, kr)
        assert r["strategy"] == expect, (kr, expect, r)
        assert r["identical"], r
        assert r["overflow"] == 0 and r["retries"] == 0, r
        assert r["cost_wire_mb"] < r["base_wire_mb"], r
        t.add(kr, r["strategy"], r["groups"], round(r["base_wire_mb"], 4),
              round(r["cost_wire_mb"], 4),
              round(r["base_wire_mb"] / max(r["cost_wire_mb"], 1e-9), 1),
              r["base_seconds"], r["cost_seconds"], r["identical"])
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main([a for a in sys.argv[1:] if a != "--json"])
    else:
        import argparse

        ap = argparse.ArgumentParser(description=__doc__)
        ap.add_argument("--quick", action="store_true")
        ap.add_argument("--json", metavar="PATH", default=None)
        args = ap.parse_args()
        table = main(args.quick)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quick": args.quick,
                           "sections": {"cost": [table.to_dict()]}},
                          f, indent=2, default=str)
            print(f"[json] wrote {args.json}")
