"""Fused LazyFrame plans vs eager op-by-op execution (the plan-layer win).

The ETL chain measured (paper Fig. 3 composition + the arXiv:2209.06146
operator algebra):

    join(orders, users, on=k) -> select(d0 > 0) -> groupby(k, aggs)
        -> join(dims, on=k)                       # dims pre-partitioned on k

Eager: 4 dispatches, 6 potential AllToAlls (join 2 + groupby 1 + join 2,
the pre-partitioning itself excluded), full-width rows on the wire.
Fused: ONE shard_map program; the optimizer pushes the filter and the
column projections below the first join's shuffles, elides the groupby
shuffle (join output is already hash-partitioned on k) and both shuffles
of the second join (co-partitioned fast path). The table reports AllToAll
counts, dense wire bytes (workers^2 x bucket x row_bytes — what the
collective actually ships), received rows, wall clock, and a bit-identical
equality check of fused vs eager results (payloads are integer-valued
floats, so aggregation order cannot perturb bits).

Each measurement runs in a fresh subprocess: the 8-device host platform
must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8
AGGS = (("d0", "sum"), ("d0", "mean"), ("d0", "var"), ("d0", "count"),
        ("d0_r", "min"), ("d0_r", "max"))


def run_worker(rows_per_worker: int, key_range: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_plan", "--worker",
         "--rows-per-worker", str(rows_per_worker),
         "--key-range", str(key_range)],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _int_table(rows: int, key_range: int, payloads: int, seed: int,
               key_name: str = "k"):
    """Integer-valued float payloads: sums are exact in f32, so fused and
    eager results can be compared bit-for-bit."""
    import numpy as np

    from repro.core.table import Table as T

    rng = np.random.default_rng(seed)
    cols = {key_name: rng.integers(0, key_range, rows).astype(np.int32)}
    for i in range(payloads):
        cols[f"d{i}"] = rng.integers(-50, 50, rows).astype(np.float32)
    return T.from_arrays(cols)


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--key-range", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from benchmarks.common import timeit
    from repro.core.context import DistContext

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    cap, kr = args.rows_per_worker, args.key_range
    pred_key = "d0_positive"

    orders = ctx.from_local_parts(
        [_int_table(cap, kr, 3, seed=100 + i) for i in range(WORKERS)])
    users = ctx.from_local_parts(
        [_int_table(cap, kr, 3, seed=200 + i) for i in range(WORKERS)])
    # dims: unique keys, pre-partitioned on k once (outside the timed chain)
    from repro.core.table import Table as T
    dims_host = T.from_arrays({
        "k": np.arange(kr, dtype=np.int32),
        "dval": (np.arange(kr) % 97).astype(np.float32)})
    dims, _ = ctx.partition_by(ctx.scatter(dims_host), "k", seed=7)

    # The eager groupby/join-2 inputs arrive pre-concentrated (the first
    # join already placed each key on its hash shard, so the re-shuffle is
    # all self-sends into ONE bucket): their buckets must absorb a whole
    # shard's rows, not rows/P. The fused plan elides those shuffles, so
    # its buckets are irrelevant — but the node params stay identical to
    # keep the programs comparable op-for-op.
    gb_bucket = 2 * cap

    def ov(stats):
        return sum(int(np.asarray(s.overflow).sum()) for s in stats)

    def eager_chain(report=None, overflow=None):
        j, st1 = ctx.join(orders, users, "k", report=report)
        s = ctx.select(j, lambda c: c["d0"] > 0.0, key=pred_key,
                       report=report)
        g, st2 = ctx.groupby(s, "k", AGGS, strategy="shuffle",
                             bucket_capacity=gb_bucket, report=report)
        out, st3 = ctx.join(g, dims, "k", bucket_capacity=gb_bucket,
                            report=report)
        if overflow is not None:
            overflow.append(ov(st1) + ov(st2) + ov(st3))
        return out

    fused = (ctx.frame(orders)
             .join(ctx.frame(users), "k")
             .select(lambda c: c["d0"] > 0.0, key=pred_key)
             .groupby("k", AGGS, strategy="shuffle",
                      bucket_capacity=gb_bucket)
             .join(ctx.frame(dims), "k", bucket_capacity=gb_bucket))

    # static shuffle accounting: fused from the optimizer's dry run, eager
    # from the per-op trace reports (fresh context -> every op traces once)
    eager_report: list = []
    eager_overflow: list = []
    e_out = eager_chain(report=eager_report, overflow=eager_overflow)
    f_report = fused.plan_report()
    f_out, f_stats = fused.collect_with_stats()
    assert eager_overflow[0] == 0, f"eager overflow {eager_overflow[0]}"
    assert ov(f_stats) == 0, f"fused overflow {ov(f_stats)}"

    def acct(report):
        return (sum(not r["elided"] for r in report),
                sum(r["wire_bytes"] for r in report))

    eager_a2a, eager_wire = acct(eager_report)
    fused_a2a, fused_wire = acct(f_report)

    from repro.testing.compare import tables_bitwise_equal
    identical = tables_bitwise_equal(e_out, f_out)
    received = sum(int(np.asarray(s.received).sum()) for s in f_stats)

    secs_eager = timeit(lambda: eager_chain().row_counts, warmup=1, iters=3)
    secs_fused = timeit(lambda: fused.collect().row_counts, warmup=1, iters=3)

    print("RESULT:" + json.dumps({
        "rows": cap * WORKERS, "key_range": kr,
        "groups": int(np.asarray(f_out.global_rows())),
        "identical": bool(identical),
        "eager_alltoall": eager_a2a, "fused_alltoall": fused_a2a,
        "eager_wire_mb": eager_wire / 1e6, "fused_wire_mb": fused_wire / 1e6,
        "fused_received_rows": received,
        "eager_seconds": secs_eager, "fused_seconds": secs_fused,
    }))


def main(quick: bool = False):
    rpw = 2_000 if quick else 20_000
    # sparse join: expected matches (= rows^2/key_range) stay well inside
    # the default join out_capacity, so neither path hits the truncation
    # failure mode and results must agree bit-for-bit
    key_range = rpw * 4
    t = Table(
        f"lazy plan fusion (P={WORKERS}, {rpw} rows/worker): one shard_map "
        "program per pipeline — pushdown + shuffle elision vs eager op-by-op",
        ["mode", "alltoall", "wire_mb", "seconds", "groups", "identical",
         "wire_reduction"])
    r = run_worker(rpw, key_range)
    assert r["identical"], "fused result != eager result"
    assert r["fused_alltoall"] < r["eager_alltoall"], r
    assert r["fused_wire_mb"] < r["eager_wire_mb"], r
    t.add("eager", r["eager_alltoall"], round(r["eager_wire_mb"], 3),
          r["eager_seconds"], r["groups"], r["identical"], 1.0)
    t.add("fused", r["fused_alltoall"], round(r["fused_wire_mb"], 3),
          r["fused_seconds"], r["groups"], r["identical"],
          round(r["eager_wire_mb"] / max(r["fused_wire_mb"], 1e-9), 1))
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main([a for a in sys.argv[1:] if a != "--json"])
    else:
        import argparse

        ap = argparse.ArgumentParser(description=__doc__)
        ap.add_argument("--quick", action="store_true")
        ap.add_argument("--json", metavar="PATH", default=None)
        args = ap.parse_args()
        table = main(args.quick)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quick": args.quick,
                           "sections": {"plan": [table.to_dict()]}},
                          f, indent=2, default=str)
            print(f"[json] wrote {args.json}")
