"""Sort->join chains: range-partition provenance vs eager re-shuffling.

``dist_sort`` pays an AllToAll to range-partition its input; eager
execution then throws that placement away and the following sort-merge
join hash-shuffles BOTH sides again (3 AllToAlls for the chain). The plan
optimizer instead tracks the sort's ``RangePartitioning`` tag, keeps the
sorted side in place, and range-ALIGNS the other side to its boundaries
(re-derived from per-shard key maxima — an all_gather of p scalars, not a
shuffle): 2 AllToAlls, bit-identical output. The chained groupby on the
same key then elides its shuffle entirely off the surviving tag.

The table reports AllToAll counts, dense wire bytes, wall clock, and the
row-multiset equality check (integer-valued float payloads: no reduction-
order bit drift). Asserts — also enforced when CI uploads the JSON — that
the fused chain runs STRICTLY fewer AllToAlls and is bit-identical.

Each measurement runs in a fresh subprocess: the 8-device host platform
must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8
AGGS = (("d0", "sum"), ("d0", "count"), ("d0_r", "max"))


def run_worker(rows_per_worker: int, key_range: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sort_chain", "--worker",
         "--rows-per-worker", str(rows_per_worker),
         "--key-range", str(key_range)],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--key-range", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from benchmarks.common import timeit
    from repro.core.context import DistContext
    from repro.core.table import Table as T

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    cap, kr = args.rows_per_worker, args.key_range

    def int_table(rows, seed):
        """Integer-valued float payloads: exact in f32, bit-comparable."""
        rng = np.random.default_rng(seed)
        return T.from_arrays({
            "k": rng.integers(0, kr, rows).astype(np.int32),
            "d0": rng.integers(-50, 50, rows).astype(np.float32)})

    orders = ctx.from_local_parts(
        [int_table(cap, seed=100 + i) for i in range(WORKERS)])
    users = ctx.from_local_parts(
        [int_table(cap, seed=200 + i) for i in range(WORKERS)])
    # skew-proof buckets: a range bucket can absorb a whole shard's rows
    bucket = 2 * cap

    def ov(stats):
        return sum(int(np.asarray(s.overflow).sum()) for s in stats)

    def eager_chain(report=None, overflow=None):
        s, st1 = ctx.sort(orders, "k", bucket_capacity=bucket, report=report)
        j, st2 = ctx.join(s, users, "k", algorithm="sort",
                          bucket_capacity=bucket, report=report)
        g, st3 = ctx.groupby(j, "k", AGGS, strategy="shuffle",
                             bucket_capacity=2 * bucket, report=report)
        if overflow is not None:
            overflow.append(ov(st1) + ov(st2) + ov(st3))
        return g

    fused = (ctx.frame(orders).sort("k", bucket_capacity=bucket)
             .join(ctx.frame(users), "k", algorithm="sort",
                   bucket_capacity=bucket)
             .groupby("k", AGGS, strategy="shuffle",
                      bucket_capacity=2 * bucket))

    eager_report: list = []
    eager_overflow: list = []
    e_out = eager_chain(report=eager_report, overflow=eager_overflow)
    f_report = fused.plan_report()
    f_out, f_stats = fused.collect_with_stats()
    assert eager_overflow[0] == 0, f"eager overflow {eager_overflow[0]}"
    assert ov(f_stats) == 0, f"fused overflow {ov(f_stats)}"

    def acct(report):
        return (sum(not r["elided"] for r in report),
                sum(r["wire_bytes"] for r in report))

    eager_a2a, eager_wire = acct(eager_report)
    fused_a2a, fused_wire = acct(f_report)

    from repro.testing.compare import tables_bitwise_equal
    identical = tables_bitwise_equal(e_out, f_out)

    secs_eager = timeit(lambda: eager_chain().row_counts, warmup=1, iters=3)
    secs_fused = timeit(lambda: fused.collect().row_counts, warmup=1, iters=3)

    print("RESULT:" + json.dumps({
        "rows": cap * WORKERS, "key_range": kr,
        "groups": int(np.asarray(f_out.global_rows())),
        "identical": bool(identical),
        "eager_alltoall": eager_a2a, "fused_alltoall": fused_a2a,
        "eager_wire_mb": eager_wire / 1e6, "fused_wire_mb": fused_wire / 1e6,
        "eager_seconds": secs_eager, "fused_seconds": secs_fused,
    }))


def main(quick: bool = False):
    rpw = 2_000 if quick else 20_000
    # sparse join (matches ~= rows^2/key_range stay inside out_capacity):
    # neither path truncates, so bit-identity is a hard assert
    key_range = rpw * 4
    t = Table(
        f"sort->join->groupby chain (P={WORKERS}, {rpw} rows/worker): "
        "range-partition provenance keeps the sorted side in place and "
        "elides downstream shuffles vs eager re-shuffling",
        ["mode", "alltoall", "wire_mb", "seconds", "groups", "identical",
         "wire_reduction"])
    r = run_worker(rpw, key_range)
    assert r["identical"], "fused result != eager result"
    assert r["fused_alltoall"] < r["eager_alltoall"], r
    assert r["fused_wire_mb"] < r["eager_wire_mb"], r
    t.add("eager", r["eager_alltoall"], round(r["eager_wire_mb"], 3),
          r["eager_seconds"], r["groups"], r["identical"], 1.0)
    t.add("fused", r["fused_alltoall"], round(r["fused_wire_mb"], 3),
          r["fused_seconds"], r["groups"], r["identical"],
          round(r["eager_wire_mb"] / max(r["fused_wire_mb"], 1e-9), 1))
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main([a for a in sys.argv[1:] if a != "--json"])
    else:
        import argparse

        ap = argparse.ArgumentParser(description=__doc__)
        ap.add_argument("--quick", action="store_true")
        ap.add_argument("--json", metavar="PATH", default=None)
        args = ap.parse_args()
        table = main(args.quick)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quick": args.quick,
                           "sections": {"sort_chain": [table.to_dict()]}},
                          f, indent=2, default=str)
            print(f"[json] wrote {args.json}")
