"""Benchmark harness: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]
                                           [--sections A,B] [--skip A,B]

Sections:
  Fig9/TableII engine comparison (bench_vs_baselines)
  Fig10 binding/dispatch overhead (bench_binding_overhead)
  kernels roofline (bench_kernels)
  groupby strategies: shuffle vs two-phase (bench_groupby)
  lazy plan fusion: fused vs eager ETL chain (bench_plan)
  sort->join chains: range provenance vs re-shuffling (bench_sort_chain)
  staged shuffles: pipelined AllToAll vs monolithic (bench_shuffle)
  cost-model planning: stats-driven strategy + sizing (bench_cost)
  window functions: boundary-carry elision vs re-shuffle (bench_window)
  concurrent-query serving: cache warmth x dispatch mode (bench_serving)
  Fig7 weak scaling + Fig8 strong scaling (bench_scaling)

--sections/--skip select a comma-separated subset by name (CI runs the
serving section in its own leg). --json writes every section's tables as
machine-readable records (the BENCH_*.json perf-trajectory feed).
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sizes; CI smoke mode")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write results as JSON to PATH")
    ap.add_argument("--sections", metavar="NAMES", default=None,
                    help="comma-separated section names to run (only)")
    ap.add_argument("--skip", metavar="NAMES", default=None,
                    help="comma-separated section names to skip")
    args = ap.parse_args()
    quick = args.quick

    t0 = time.perf_counter()
    from benchmarks import (bench_binding_overhead, bench_cost,
                            bench_groupby, bench_kernels, bench_plan,
                            bench_scaling, bench_serving, bench_shuffle,
                            bench_sort_chain, bench_vs_baselines,
                            bench_window)

    print(f"# benchmark run (quick={quick})")
    sections = [
        ("vs_baselines", bench_vs_baselines.main),
        ("binding_overhead", bench_binding_overhead.main),
        ("kernels", bench_kernels.main),
        ("groupby", bench_groupby.main),
        ("plan", bench_plan.main),
        ("sort_chain", bench_sort_chain.main),
        ("shuffle", bench_shuffle.main),
        ("cost", bench_cost.main),
        ("window", bench_window.main),
        ("serving", bench_serving.main),
        ("scaling", bench_scaling.main),
    ]
    known = {name for name, _ in sections}
    only = set(args.sections.split(",")) if args.sections else None
    skip = set(args.skip.split(",")) if args.skip else set()
    for requested in (only or set()) | skip:
        assert requested in known, (requested, sorted(known))
    sections = [(n, f) for n, f in sections
                if (only is None or n in only) and n not in skip]
    results: dict[str, list[dict]] = {}
    for name, fn in sections:
        tables = fn(quick)
        if tables is None:
            tables = []
        elif not isinstance(tables, (list, tuple)):
            tables = [tables]
        results[name] = [t.to_dict() for t in tables]
    elapsed = time.perf_counter() - t0

    if args.json:
        payload = {"quick": quick, "elapsed_seconds": elapsed,
                   "sections": results}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"\n[json] wrote {args.json}")
    print(f"\n[done] total {elapsed:.0f}s")


if __name__ == "__main__":
    main()
