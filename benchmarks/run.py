"""Benchmark harness: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
  Fig9/TableII engine comparison (bench_vs_baselines)
  Fig10 binding/dispatch overhead (bench_binding_overhead)
  kernels roofline (bench_kernels)
  Fig7 weak scaling + Fig8 strong scaling (bench_scaling)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    t0 = time.perf_counter()
    from benchmarks import (bench_binding_overhead, bench_kernels,
                            bench_scaling, bench_vs_baselines)

    print(f"# benchmark run (quick={quick})")
    bench_vs_baselines.main(quick)
    bench_binding_overhead.main(quick)
    bench_kernels.main(quick)
    bench_scaling.main(quick)
    print(f"\n[done] total {time.perf_counter() - t0:.0f}s")


if __name__ == "__main__":
    main()
