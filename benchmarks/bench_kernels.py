"""Kernel-level benches: Pallas hot spots vs their XLA/ref formulations.

On this CPU container the Pallas kernels execute in interpret mode (Python)
— their wall-clock is meaningless, so we time the XLA reference path (what
the TPU kernel replaces) and report each kernel's analytic roofline terms
on v5e (bytes moved / HBM bw vs FLOPs / peak) — the number the kernel is
designed to hit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, timeit
from repro.kernels import ref
from repro.kernels.hash64 import hash32
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def main(quick: bool = False):
    n = 1 << (18 if quick else 22)
    t = Table("kernel roofline (v5e model) + CPU XLA-path timings",
              ["kernel", "shape", "cpu_xla_ms", "v5e_mem_us", "v5e_compute_us",
               "bound"])

    # hash32: 1 read + 1 write of uint32; ~8 int-ops/element
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2**31, n), jnp.int32)
    f = jax.jit(lambda x: ref.hash32_ref(x, seed=7))
    ms = timeit(f, x) * 1e3
    mem = 8 * n / HBM_BW * 1e6
    comp = 8 * n / PEAK_FLOPS * 1e6
    t.add("hash32(murmur3)", f"({n},)", ms, mem, comp,
          "memory" if mem > comp else "compute")

    # histogram: read ids + tiny output; one-hot matmul formulation
    p = 64
    ids = jnp.asarray(np.random.default_rng(1).integers(-1, p, n), jnp.int32)
    f = jax.jit(lambda i: ref.histogram_ref(i, p))
    ms = timeit(f, ids) * 1e3
    mem = 4 * n / HBM_BW * 1e6
    comp = n * p / PEAK_FLOPS * 1e6  # one-hot compare+add
    t.add("bucket_histogram", f"({n},)x{p}", ms, mem, comp,
          "memory" if mem > comp else "compute")

    # bitonic tile sort: log^2 passes in VMEM; HBM = 1 read + 1 write
    m = 1 << 11
    keys = jnp.asarray(np.random.default_rng(2).integers(0, 2**31, m),
                       jnp.uint32)
    payload = jnp.arange(m, dtype=jnp.int32)
    f = jax.jit(lambda k, v: jax.lax.sort((k, v), num_keys=1))
    ms = timeit(f, keys, payload) * 1e3
    passes = 11 * 12 // 2
    mem = 8 * m * 2 / HBM_BW * 1e6
    comp = passes * m * 4 / PEAK_FLOPS * 1e6
    t.add("bitonic_sort_tile", f"({m},)", ms, mem, comp,
          "memory" if mem > comp else "compute")

    # flash attention: S=2048 block; bytes = qkv+o once vs 4*S^2*hd matmul
    b, s, h, hd = 1, 2048, 8, 128
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    ms = timeit(f, q, k, v) * 1e3
    flops = 4 * b * h * s * s * hd / 2  # causal halves
    mem = (4 * b * s * h * hd * 2) / HBM_BW * 1e6
    comp = flops / PEAK_FLOPS * 1e6
    t.add("flash_attention", f"B{b} S{s} H{h} hd{hd}", ms, mem, comp,
          "memory" if mem > comp else "compute")

    t.emit()
    return t


if __name__ == "__main__":
    import sys
    main("--quick" in sys.argv)
