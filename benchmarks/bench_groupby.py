"""GroupBy strategies (arXiv:2010.14596): shuffle-then-aggregate vs
two-phase partial-aggregate -> AllToAll -> combine.

On low-cardinality keys two-phase shuffles one partial row per locally
distinct key instead of every raw row, so both the received-row count and
the dense AllToAll wire bytes (workers^2 x bucket x row_bytes) shrink by
~rows/cardinality. The table reports both, plus the measured reduction —
the hardware-independent scaling signal (the CPU container time-shares one
core, so wall-clock parity is expected; see bench_scaling's caveat).

Each (strategy, cardinality) runs in a fresh subprocess: the 8-device host
platform must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8
AGGS = (("d0", "sum"), ("d0", "mean"), ("d0", "var"), ("d1", "min"),
        ("d1", "max"), ("d0", "count"))


def run_worker(strategy: str, rows_per_worker: int, key_range: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_groupby", "--worker",
         "--strategy", strategy, "--rows-per-worker", str(rows_per_worker),
         "--key-range", str(key_range)],
        capture_output=True, text=True, env=env, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--strategy", choices=["shuffle", "two_phase"],
                    required=True)
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--key-range", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from benchmarks.common import timeit
    from repro.core.context import DistContext
    from repro.core.repartition import default_bucket_capacity
    from repro.data.synthetic import random_table
    from repro.utils import ceil_div

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    cap, kr = args.rows_per_worker, args.key_range
    dt = ctx.from_local_parts([
        random_table(cap, key_range=kr, seed=1, shard=i)
        for i in range(WORKERS)])
    if args.strategy == "shuffle":
        # every raw row crosses the wire: bucket must absorb rows/p x skew
        bucket = default_bucket_capacity(cap, WORKERS)
    else:
        # only partial rows (<= key cardinality per shard) cross the wire
        bucket = max(8, ceil_div(kr * 2, WORKERS))

    fn = lambda: ctx.groupby(dt, "k", AGGS, strategy=args.strategy,
                             bucket_capacity=bucket)
    out, (st,) = fn()
    groups = int(out.global_rows())
    received = int(np.asarray(st.received).sum())
    overflow = int(np.asarray(st.overflow).sum())
    # bytes/row of what actually crosses the wire: raw rows for shuffle,
    # phase-1 partial rows (keys + algebraic slots) for two_phase
    if args.strategy == "shuffle":
        shipped_schema = random_table(4, key_range=4, seed=0).schema
    else:
        from repro.core import ops_agg as A
        shipped_schema = A.partial_groupby(
            random_table(4, key_range=4, seed=0), "k", AGGS).schema
    row_bytes = sum(np.dtype(v).itemsize for v in shipped_schema.values())
    # dense AllToAll: every shard ships p buckets regardless of validity
    wire_bytes = WORKERS * WORKERS * bucket * row_bytes
    secs = timeit(lambda: fn()[0].row_counts, warmup=1, iters=3)
    print("RESULT:" + json.dumps({
        "strategy": args.strategy, "rows": cap * WORKERS, "key_range": kr,
        "groups": groups, "seconds": secs, "received_rows": received,
        "overflow": overflow, "bucket": bucket, "wire_mb": wire_bytes / 1e6,
    }))


def main(quick: bool = False):
    rpw = 4_000 if quick else 40_000
    cardinalities = [64, 1024] if quick else [64, 1024, 16_384]
    t = Table(
        f"groupby strategies (P={WORKERS}, {rpw} rows/worker): "
        "two-phase shuffle-volume reduction on low-cardinality keys",
        ["key_range", "strategy", "groups", "seconds", "received_rows",
         "wire_mb", "shuffle_rows_reduction"])
    for kr in cardinalities:
        base = run_worker("shuffle", rpw, kr)
        two = run_worker("two_phase", rpw, kr)
        assert base["groups"] == two["groups"], (base, two)
        assert base["overflow"] == 0 and two["overflow"] == 0, (base, two)
        t.add(kr, "shuffle", base["groups"], base["seconds"],
              base["received_rows"], base["wire_mb"], 1.0)
        t.add(kr, "two_phase", two["groups"], two["seconds"],
              two["received_rows"], two["wire_mb"],
              base["received_rows"] / max(two["received_rows"], 1))
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main(sys.argv[1:])
    else:
        main("--quick" in sys.argv)
