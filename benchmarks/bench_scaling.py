"""Paper Figs. 7 & 8: weak + strong scaling of distributed Join (hash &
sort) and Union over SPMD worker counts.

Caveat (recorded in EXPERIMENTS.md): this container exposes ONE physical
core, so the P "devices" time-share it — wall-clock cannot show speedup.
The curves validate the BSP structure (flat per-worker cost would appear
on real chips), and the per-worker collective bytes from the compiled HLO
(bench output column) are the hardware-independent scaling signal.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKER_COUNTS = [1, 2, 4, 8]
OPS = ["join_hash", "join_sort", "union"]


def run_worker(op: str, workers: int, rows_per_worker: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={workers}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.scaling_worker", "--op", op,
         "--workers", str(workers), "--rows-per-worker",
         str(rows_per_worker)],
        capture_output=True, text=True, env=env, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def bench_weak(rows_per_worker: int = 50_000) -> Table:
    t = Table("Fig7: weak scaling (rows/worker fixed = %d)" % rows_per_worker,
              ["op", "workers", "total_rows", "seconds", "rows_per_sec"])
    for op in OPS:
        for p in WORKER_COUNTS:
            r = run_worker(op, p, rows_per_worker)
            t.add(op, p, r["total_rows"], r["seconds"], r["rows_per_second"])
    return t


def bench_strong(total_rows: int = 200_000) -> Table:
    t = Table("Fig8: strong scaling (total rows fixed = %d)" % total_rows,
              ["op", "workers", "rows_per_worker", "seconds", "speedup"])
    for op in OPS:
        base = None
        for p in WORKER_COUNTS:
            r = run_worker(op, p, total_rows // p)
            if base is None:
                base = r["seconds"]
            t.add(op, p, total_rows // p, r["seconds"], base / r["seconds"])
    return t


def main(quick: bool = False):
    rpw = 20_000 if quick else 50_000
    tot = 80_000 if quick else 200_000
    weak, strong = bench_weak(rpw), bench_strong(tot)
    weak.emit()
    strong.emit()
    return [weak, strong]


if __name__ == "__main__":
    main("--quick" in sys.argv)
