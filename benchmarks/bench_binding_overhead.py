"""Paper Fig. 10: binding overhead (C++ vs Python vs Java bindings).

The analogue here: the relational ops are XLA programs; the "binding" is
the Python dispatch into the JAX runtime. We measure per-call dispatch
overhead (tiny input, overhead-dominated) vs amortized compute (large
input), plus the AOT-compiled call path — the paper's claim "binding
overhead is negligible" maps to overhead/compute -> 0 as rows grow.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Table, timeit
from repro.core import ops_local as L
from repro.core.table import Table as RTable
from repro.data.synthetic import random_table


def main(quick: bool = False):
    sizes = [256, 4096, 65536] + ([] if quick else [524288])
    t = Table("Fig10: dispatch/binding overhead",
              ["rows", "jit_call_us", "aot_call_us", "us_per_1k_rows"])
    for n in sizes:
        a = random_table(n, key_range=n, seed=1)
        ta = RTable.from_arrays({"k": a.columns["k"], "v": a.columns["d0"]})
        fn = jax.jit(lambda x: L.sort_by(x, "k").row_count)
        aot = fn.lower(ta).compile()
        t_jit = timeit(fn, ta, warmup=2, iters=20)
        t_aot = timeit(aot, ta, warmup=2, iters=20)
        t.add(n, t_jit * 1e6, t_aot * 1e6, t_aot * 1e6 / (n / 1000))
    t.emit()
    return t


if __name__ == "__main__":
    import sys
    main("--quick" in sys.argv)
