"""Merge bench JSON artifacts into one markdown summary.

    python -m benchmarks.summarize out.md file1.json [file2.json ...]
    python -m benchmarks.summarize - bench-*.json   # write to stdout

Each input is a ``benchmarks.run --json`` payload (or a single-bench
export with the same ``{"sections": {name: [tables]}}`` shape). CI feeds
the merged output to ``$GITHUB_STEP_SUMMARY`` so the per-run perf
trajectory — AllToAll counts, wire bytes, wall clock, bit-identity gates —
is readable on the run page without downloading artifacts. Duplicate
sections across inputs (e.g. the full run plus a standalone re-export)
are emitted once, first occurrence wins.
"""
from __future__ import annotations

import json
import sys


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def table_markdown(table: dict) -> str:
    """One benchmarks.common.Table dict -> a markdown table with title."""
    cols = table["columns"]
    lines = [f"**{table['title']}**", "",
             "| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for row in table["rows"]:
        lines.append("| " + " | ".join(_fmt(v) for v in row) + " |")
    return "\n".join(lines)


def summarize(paths: list[str]) -> str:
    seen: set[str] = set()
    out = ["# Benchmark summary"]
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            out.append(f"\n> could not read `{path}`: {e}")
            continue
        sections = payload.get("sections", {})
        meta = []
        if payload.get("quick"):
            meta.append("quick mode")
        if "elapsed_seconds" in payload:
            meta.append(f"{payload['elapsed_seconds']:.0f}s")
        for name, tables in sections.items():
            if name in seen:
                continue
            seen.add(name)
            out.append(f"\n## {name}" + (f" ({', '.join(meta)})"
                                         if meta else ""))
            for t in tables:
                out.append("\n" + table_markdown(t))
    if len(out) == 1:
        out.append("\n_no benchmark sections found_")
    return "\n".join(out) + "\n"


def main() -> None:
    if len(sys.argv) < 3:
        sys.exit(__doc__)
    dest, paths = sys.argv[1], sys.argv[2:]
    text = summarize(paths)
    if dest == "-":
        sys.stdout.write(text)
    else:
        with open(dest, "a") as f:
            f.write(text)
        print(f"[summary] wrote {dest} from {len(paths)} file(s)")


if __name__ == "__main__":
    main()
