"""Concurrent-query serving: cold vs warm cache, sequential vs async overlap.

The paper's setting is a data-engineering layer embedded in live AI
workloads — many clients issuing small relational queries over shared
tables, where the metrics are per-query p50/p99 latency and sustained
queries/sec, not single-query wall time. This benchmark drives
``ServingSession.run_open_loop`` over an 8-shard mesh through a
mixed-shape workload (groupby / sort+limit / keyless-select+groupby /
join) in three phases:

* **cold sequential** — fresh plan cache: every shape pays its compile
  inline, and every cost-sized query pays its overflow host-sync before
  the next submission;
* **warm sequential** — same loop on the now-warm cache: 0 compiles, but
  submissions still serialize on deferred verification;
* **warm async** — bounded in-flight futures: dispatch overlaps device
  execution, and overflow verification folds into later dispatches.

Asserts — also enforced by the CI ``bench-serving`` leg — that the warm
phases run at 0 compiles and 0 recompiles, that warm-async achieves
strictly higher queries/sec than cold-sequential, and that the async
results are bit-identical per query to the sequential results.

Each measurement runs in a fresh subprocess: the 8-device host platform
must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8


def run_worker(rows_per_worker: int, num_clients: int,
               queries_per_client: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving", "--worker",
         "--rows-per-worker", str(rows_per_worker),
         "--num-clients", str(num_clients),
         "--queries-per-client", str(queries_per_client)],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--num-clients", type=int, required=True)
    ap.add_argument("--queries-per-client", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.core.context import DistContext
    from repro.core.serving import ServingSession
    from repro.core.table import Table as T
    from repro.testing.compare import tables_bitwise_equal

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    cap = args.rows_per_worker
    n = cap * WORKERS
    rng = np.random.default_rng(42)
    orders = T.from_arrays({
        "k": rng.integers(0, 64, n).astype(np.int32),
        "d0": rng.integers(-50, 50, n).astype(np.float32),
        "d1": rng.integers(0, 1000, n).astype(np.int32)})
    dims = T.from_arrays({
        "k": np.arange(64, dtype=np.int32),
        "w": rng.integers(0, 9, 64).astype(np.float32)})

    sess = ServingSession(ctx, max_in_flight=8)
    sess.register("orders", orders, analyze=True)  # cost-sized -> deferred
    sess.register("dims", dims, analyze=True)

    # mixed plan shapes; 'sel' uses an inline keyless lambda on purpose —
    # the serving cache must keep a re-created lambda hot (content keys
    # over code + captures), or every client submission would recompile it
    workload = [
        ("gb", lambda s: s.frame("orders")
            .groupby("k", (("d0", "sum"), ("d0", "count")))),
        ("topn", lambda s: s.frame("orders").sort("k").limit(32)),
        ("sel", lambda s: s.frame("orders")
            .select(lambda c: c["d0"] > 0.0)
            .groupby("k", (("d0", "mean"),))),
        ("join", lambda s: s.frame("orders").join(s.frame("dims"), "k")
            .groupby("k", (("w", "sum"),))),
    ]

    def phase(mode):
        report, results = sess.run_open_loop(
            workload, num_clients=args.num_clients,
            queries_per_client=args.queries_per_client, mode=mode)
        print(f"# {report.summary()}", file=sys.stderr)
        return report, results

    cold, cold_res = phase("sequential")        # fresh cache: compiles
    warm_seq, seq_res = phase("sequential")     # warm: sync-per-query
    warm_async, async_res = phase("async")      # warm: overlapped dispatch

    identical = all(
        tables_bitwise_equal(a.to_table(), b.to_table())
        for a, b in zip(async_res, seq_res))
    cold_identical = all(
        tables_bitwise_equal(a.to_table(), b.to_table())
        for a, b in zip(cold_res, seq_res))

    print("RESULT:" + json.dumps({
        "rows": n, "clients": args.num_clients,
        "queries": cold.num_queries,
        "cold_sequential": cold.to_dict(),
        "warm_sequential": warm_seq.to_dict(),
        "warm_async": warm_async.to_dict(),
        "async_identical": bool(identical),
        "cold_identical": bool(cold_identical),
        "overflow_retries": ctx.overflow_retries,
    }))


def main(quick: bool = False):
    rpw = 2_000 if quick else 25_000
    clients = 4 if quick else 8
    qpc = 3 if quick else 6
    r = run_worker(rpw, num_clients=clients, queries_per_client=qpc)

    # the serving gates: never-wrong-results, never-recompile-warm,
    # and async overlap must actually buy throughput over a cold start
    assert r["async_identical"], "async results diverged from sequential"
    assert r["cold_identical"], "warm results diverged from cold"
    for ph in ("warm_sequential", "warm_async"):
        assert r[ph]["compiles"] == 0, (ph, r[ph])
        assert r[ph]["recompiles"] == 0, (ph, r[ph])
    assert r["warm_async"]["qps"] > r["cold_sequential"]["qps"], (
        r["warm_async"]["qps"], r["cold_sequential"]["qps"])

    t = Table(
        f"concurrent-query serving open loop (P={WORKERS}, "
        f"{r['rows']} rows, {r['clients']} clients x 4 shapes, "
        f"{r['queries']} queries/phase): plan-cache warmth x dispatch mode",
        ["phase", "qps", "p50_ms", "p99_ms", "compiles", "recompiles",
         "identical"])
    for ph, ident in (("cold_sequential", r["cold_identical"]),
                      ("warm_sequential", True),
                      ("warm_async", r["async_identical"])):
        d = r[ph]
        t.add(ph.replace("_", " "), round(d["qps"], 2),
              round(d["p50_ms"], 1), round(d["p99_ms"], 1),
              d["compiles"], d["recompiles"], ident)
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main([a for a in sys.argv[1:] if a != "--json"])
    else:
        import argparse

        ap = argparse.ArgumentParser(description=__doc__)
        ap.add_argument("--quick", action="store_true")
        ap.add_argument("--json", metavar="PATH", default=None)
        args = ap.parse_args()
        table = main(args.quick)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quick": args.quick,
                           "sections": {"serving": [table.to_dict()]}},
                          f, indent=2, default=str)
            print(f"[json] wrote {args.json}")
