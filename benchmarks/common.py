"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds of fn(*args) (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def timeit_host(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Table:
    def __init__(self, title: str, columns: list[str]):
        self.title = title
        self.columns = columns
        self.rows: list[list] = []

    def add(self, *row):
        self.rows.append(list(row))

    def emit(self):
        print(f"\n## {self.title}")
        print(",".join(self.columns))
        for r in self.rows:
            print(",".join(
                f"{v:.4g}" if isinstance(v, float) else str(v) for v in r))

    def to_dict(self) -> dict:
        """Machine-readable form for the --json trajectory output."""
        return {"title": self.title, "columns": self.columns,
                "rows": self.rows}
