"""Staged (pipelined) shuffles vs the monolithic AllToAll on 8 devices.

The repartition exchange splits its ``(p, bucket)`` send buckets into S
chunks along the capacity axis — one collective per chunk — so XLA can
overlap one chunk's wire time with its neighbours' pack/unpack compute
inside the single fused shard_map program (plus a ``ppermute``-ring
strategy for comparison). The contract is bit-identity: every (stages,
shuffle_mode) produces the same rows, the same overflow accounting, and
the same dense wire bytes — staging only re-chunks the collective.

The table reports per-mode AllToAll/ppermute counts (from the traced
jaxpr), plan_report wire bytes, wall clock, and the bitwise row-multiset
check. Asserts — also enforced when CI uploads the JSON — that S=1 issues
exactly one collective per column (the folded-counts program: no extra
counts exchange, no added AllToAll), that staged and ring runs are
bit-identical to monolithic, and that wire bytes match across modes.

Each measurement runs in a fresh subprocess: the 8-device host platform
must be fixed before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Table

WORKERS = 8


def run_worker(rows_per_worker: int, stages: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={WORKERS}"
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shuffle", "--worker",
         "--rows-per-worker", str(rows_per_worker),
         "--stages", str(stages)],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[7:])


def _worker_main(argv) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--stages", type=int, required=True)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from benchmarks.common import timeit
    from repro.core import ops_dist as D
    from repro.core.context import DistContext
    from repro.core.table import Table as T
    from repro.testing.compare import tables_bitwise_equal
    from repro.utils import shard_map

    assert jax.device_count() == WORKERS, jax.device_count()
    ctx = DistContext(axis_name="shuffle")
    cap, staged_s = args.rows_per_worker, args.stages

    def part(seed):
        rng = np.random.default_rng(seed)
        return T.from_arrays({
            "k": rng.integers(0, cap * 4, cap).astype(np.int32),
            # (cap, 8) payload: enough bytes/row that the exchange (not
            # the pack) dominates, the regime staging targets
            "v": rng.integers(-50, 50, (cap, 8)).astype(np.float32)})

    parts = [part(100 + i) for i in range(WORKERS)]
    dt = ctx.from_local_parts(parts)
    bucket = 2 * cap  # skew-proof: no overflow, latency compares clean

    modes = (("mono", dict(stages=1)),
             ("staged", dict(stages=staged_s)),
             ("ring", dict(shuffle_mode="ring")))

    # collective counts from the traced program, per mode
    mesh, ax = ctx.mesh, ctx.axis_name
    gk = np.concatenate([np.asarray(q.columns["k"]) for q in parts])
    gv = np.concatenate([np.asarray(q.columns["v"]) for q in parts])
    grc = np.full((WORKERS,), cap, np.int32)

    # the shared traced-jaxpr collective counters (also what
    # verify.audit_collectives uses to cross-check plan_report)
    from repro.core.verify import count_collectives

    def counts_for(kw):
        def body(k, v, rc):
            tab = T({"k": k, "v": v}, rc[0])
            out, _ = D.dist_repartition_by(
                tab, ["k"], axis_name=ax, bucket_capacity=bucket, **kw)
            return out.columns["k"]

        with mesh:
            jaxpr = str(jax.make_jaxpr(shard_map(
                body, mesh=mesh, in_specs=(P(ax), P(ax), P(ax)),
                out_specs=P(ax)))(gk, gv, grc))
        c = count_collectives(jaxpr)
        return c["all_to_all"], c["ppermute"]

    out = {"rows": cap * WORKERS, "bucket": bucket, "stages": staged_s}
    results = {}
    for name, kw in modes:
        rep: list = []
        res, (st,) = ctx.partition_by(dt, "k", bucket_capacity=bucket,
                                      report=rep, **kw)
        a2a, pperm = counts_for(kw)
        secs = timeit(
            lambda kw=kw: ctx.partition_by(dt, "k", bucket_capacity=bucket,
                                           **kw)[0].row_counts,
            warmup=2, iters=5)
        results[name] = res
        out[name] = {
            "alltoalls": a2a, "ppermutes": pperm,
            "wire_mb": rep[0]["wire_bytes"] / 1e6,
            "report_stages": rep[0]["stages"], "mode": rep[0]["mode"],
            "overflow": int(np.asarray(st.overflow).sum()),
            "seconds": secs,
        }

    n_cols = 2  # k + v: the folded-counts program is 1 collective/column
    out["mono_collectives_ok"] = out["mono"]["alltoalls"] == n_cols
    out["staged_chunked"] = out["staged"]["alltoalls"] > out["mono"]["alltoalls"]
    out["ring_no_alltoall"] = out["ring"]["alltoalls"] == 0 \
        and out["ring"]["ppermutes"] > 0
    out["staged_identical"] = tables_bitwise_equal(results["mono"],
                                                   results["staged"])
    out["ring_identical"] = tables_bitwise_equal(results["mono"],
                                                 results["ring"])
    out["wire_identical"] = (out["mono"]["wire_mb"] == out["staged"]["wire_mb"]
                             == out["ring"]["wire_mb"])
    print("RESULT:" + json.dumps(out))


def main(quick: bool = False):
    rpw = 4_000 if quick else 50_000
    stages = 4
    t = Table(
        f"staged shuffle (P={WORKERS}, {rpw} rows/worker, 36 B/row): "
        f"S={stages} pipelined chunks and the ppermute ring vs one "
        "monolithic AllToAll — bit-identical rows, identical wire bytes, "
        "only the collective decomposition differs",
        ["mode", "stages", "alltoalls", "ppermutes", "wire_mb", "seconds",
         "identical"])
    r = run_worker(rpw, stages)
    # the contract gates (CI fails on any of these):
    assert r["mono_collectives_ok"], \
        f"S=1 must be 1 collective/column (counts folded): {r['mono']}"
    assert r["staged_chunked"], r
    assert r["ring_no_alltoall"], r
    assert r["staged_identical"] and r["ring_identical"], \
        "staged/ring shuffle not bit-identical to monolithic"
    assert r["wire_identical"], r
    for name in ("mono", "staged", "ring"):
        m = r[name]
        assert m["overflow"] == 0, (name, m["overflow"])
        t.add(name, m["report_stages"], m["alltoalls"], m["ppermutes"],
              round(m["wire_mb"], 3), m["seconds"],
              True if name == "mono" else r[f"{name}_identical"])
    t.emit()
    return t


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main([a for a in sys.argv[1:] if a != "--json"])
    else:
        import argparse

        ap = argparse.ArgumentParser(description=__doc__)
        ap.add_argument("--quick", action="store_true")
        ap.add_argument("--json", metavar="PATH", default=None)
        args = ap.parse_args()
        table = main(args.quick)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quick": args.quick,
                           "sections": {"shuffle": [table.to_dict()]}},
                          f, indent=2, default=str)
            print(f"[json] wrote {args.json}")
