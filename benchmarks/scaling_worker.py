"""SPMD scaling worker: one (op, workers, rows) measurement in a fresh
process (device count must be fixed before jax initializes).

Prints ``RESULT:{json}``. Invoked by bench_weak_scaling / bench_strong_-
scaling via subprocess with XLA_FLAGS=--xla_force_host_platform_device_-
count=<P>.
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", required=True,
                    choices=["join_hash", "join_sort", "union"])
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--rows-per-worker", type=int, required=True)
    ap.add_argument("--key-range-factor", type=float, default=1.0)
    args = ap.parse_args()

    import jax

    from benchmarks.common import timeit
    from repro.core.context import DistContext
    from repro.data.synthetic import random_table

    assert jax.device_count() == args.workers, (
        jax.device_count(), args.workers)
    ctx = DistContext(axis_name="shuffle")
    p = args.workers
    n = args.rows_per_worker * p
    key_range = max(4, int(n * args.key_range_factor))
    cap = args.rows_per_worker
    a = ctx.from_local_parts([
        random_table(cap, key_range=key_range, seed=1, shard=i)
        for i in range(p)])
    b = ctx.from_local_parts([
        random_table(cap, key_range=key_range, seed=2, shard=i)
        for i in range(p)])
    bucket = max(64, int(cap * 2.0 / p))

    if args.op == "join_hash":
        fn = lambda: ctx.join(a, b, "k", algorithm="hash",
                              bucket_capacity=bucket,
                              out_capacity=4 * cap)[0].row_counts
    elif args.op == "join_sort":
        fn = lambda: ctx.join(a, b, "k", algorithm="sort",
                              bucket_capacity=bucket,
                              out_capacity=4 * cap)[0].row_counts
    else:
        fn = lambda: ctx.union(ctx.project(a, ["k"]), ctx.project(b, ["k"]),
                               bucket_capacity=bucket)[0].row_counts

    t = timeit(fn, warmup=2, iters=5)
    print("RESULT:" + json.dumps({
        "op": args.op, "workers": p, "rows_per_worker": args.rows_per_worker,
        "total_rows": n, "seconds": t,
        "rows_per_second": n / t,
    }))


if __name__ == "__main__":
    main()
