"""Staged-shuffle unit tests (single-device, subprocess-free).

The pipelined AllToAll's contracts that don't need an 8-device world:
chunking edge cases (non-divisible widths, S=1, S > capacity clamping),
the cost model's stage pick, canonical-key stability (S=1 and default
plans must hit the exact pre-staging cache entries), the empty-table
pack/repartition guards, and bit-identity of every (stages, shuffle_mode)
on a 1-device mesh — including the N-D counts-carrier path and the
no-4-byte-column fallback. The skew/overflow and multi-device identity
checks live in dist_cases (``staged_shuffle``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as PL
from repro.core import stats as S
from repro.core.repartition import (_chunk_bounds, pack_by_partition,
                                    repartition, staged_all_to_all)
from repro.core.table import Table
from repro.utils import shard_map


# --- chunking -----------------------------------------------------------------


def test_chunk_bounds_cover_exactly_once():
    for width in (1, 2, 5, 7, 8, 64, 100):
        for stages in (1, 2, 3, 4, 7, 64, 200):
            bounds = _chunk_bounds(width, stages)
            assert bounds[0][0] == 0 and bounds[-1][1] == width
            for (_, hi), (lo2, _) in zip(bounds, bounds[1:]):
                assert hi == lo2  # contiguous, no overlap, no gap
            assert len(bounds) <= min(stages, width)


def test_chunk_bounds_edges():
    assert _chunk_bounds(0, 4) == []
    assert _chunk_bounds(10, 1) == [(0, 10)]
    assert _chunk_bounds(10, 0) == [(0, 10)]
    # non-divisible width: remainder in the last chunk
    assert _chunk_bounds(10, 3) == [(0, 4), (4, 8), (8, 10)]
    # S > width clamps to one slot per chunk
    assert _chunk_bounds(3, 100) == [(0, 1), (1, 2), (2, 3)]


def test_staged_all_to_all_rejects_unknown_mode():
    with pytest.raises(ValueError):
        staged_all_to_all(jnp.zeros((1, 4)), "x", shuffle_mode="butterfly")


# --- cost-model stage pick ----------------------------------------------------


def test_pick_stages_threshold_and_cap():
    thr = S.STAGE_WIRE_THRESHOLD
    assert S.pick_stages(0, 64) == 1
    assert S.pick_stages(thr, 64) == 1          # at the threshold: still 1
    assert S.pick_stages(thr + 1, 64) == 2
    assert S.pick_stages(4 * thr, 64) == 4
    assert S.pick_stages(1 << 40, 64) == S.MAX_SHUFFLE_STAGES
    # clamped so every chunk keeps >= 1 capacity slot
    assert S.pick_stages(1 << 40, 3) == 3
    assert S.pick_stages(1 << 40, 1) == 1


# --- canonical plan keys ------------------------------------------------------


def test_stage_knobs_at_identity_keep_canonical_key():
    base = PL.Sort(PL.Scan(0), ("k",))
    assert PL.canonical_key(base) == PL.canonical_key(
        PL.Sort(PL.Scan(0), ("k",), stages=1))
    assert PL.canonical_key(base) == PL.canonical_key(
        PL.Sort(PL.Scan(0), ("k",), stages=None))
    assert PL.canonical_key(base) == PL.canonical_key(
        PL.Sort(PL.Scan(0), ("k",), shuffle_mode="alltoall"))


def test_stage_knobs_off_identity_change_canonical_key():
    base = PL.canonical_key(PL.Sort(PL.Scan(0), ("k",)))
    assert base != PL.canonical_key(PL.Sort(PL.Scan(0), ("k",), stages=2))
    assert base != PL.canonical_key(
        PL.Sort(PL.Scan(0), ("k",), shuffle_mode="ring"))


# --- empty-table guards -------------------------------------------------------


def test_pack_by_partition_empty_input():
    send_idx, hist = pack_by_partition(jnp.zeros((0,), jnp.int32), 4, 8)
    assert send_idx.shape == (4, 8) and bool(jnp.all(send_idx == -1))
    assert hist.shape == (4,) and bool(jnp.all(hist == 0))


# --- single-device repartition bit-identity -----------------------------------


def _mesh1():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))


def _repart(table, pid, bucket, **kw):
    mesh = _mesh1()
    P = jax.sharding.PartitionSpec

    def body(t):
        out, st = repartition(t, pid, axis_name="x", bucket_capacity=bucket,
                              **kw)
        return out.columns, out.row_count, st.overflow, st.received

    with mesh:
        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),),
                                 out_specs=P()))(table)


def test_repartition_empty_table():
    t = Table({"k": jnp.zeros((0,), jnp.int32),
               "v": jnp.zeros((0, 2), jnp.float32)},
              jnp.asarray(0, jnp.int32))
    cols, rc, ov, recv = _repart(t, jnp.zeros((0,), jnp.int32), 4, stages=2)
    assert int(rc) == 0 and int(ov) == 0 and int(recv) == 0
    assert cols["k"].shape == (4,) and cols["v"].shape == (4, 2)


def test_repartition_stagings_bit_identical():
    # "a" sorts before "k": the 2-D float32 payload is the counts carrier,
    # exercising the N-D meta-slot pack/unpack
    n = 24
    t = Table({"a": jnp.arange(2 * n, dtype=jnp.float32).reshape(n, 2) * 0.5,
               "k": jnp.arange(n, dtype=jnp.int32)},
              jnp.asarray(n, jnp.int32))
    pid = jnp.zeros((n,), jnp.int32)
    runs = {name: _repart(t, pid, 10, **kw)  # bucket 10 < 24 rows: overflow
            for name, kw in (("s1", dict(stages=1)),
                             ("s3", dict(stages=3)),       # 10 % 3 != 0
                             ("s99", dict(stages=99)),     # clamps to 10
                             ("ring", dict(shuffle_mode="ring")))}
    c1, rc1, ov1, recv1 = runs["s1"]
    assert int(ov1) == n - 10 and int(recv1) == 10
    for name, (c, rc, ov, recv) in runs.items():
        assert int(rc) == int(rc1) and int(ov) == int(ov1), name
        for col in c1:
            assert bool(jnp.all(c[col] == c1[col])), (name, col)


def test_repartition_counts_fallback_without_4byte_column():
    # no 4-byte column -> the separate counts exchange (carrier None)
    n = 8
    t = Table({"b": jnp.arange(n, dtype=jnp.uint8)}, jnp.asarray(n, jnp.int32))
    pid = jnp.zeros((n,), jnp.int32)
    c1, rc1, ov1, _ = _repart(t, pid, n, stages=1)
    c2, rc2, ov2, _ = _repart(t, pid, n, stages=2)
    assert int(rc1) == int(rc2) == n and int(ov1) == int(ov2) == 0
    assert bool(jnp.all(c1["b"] == c2["b"]))
