"""Training substrate: optimizer math, microbatch equivalence, loss
decreases on a learnable task, checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PipelineConfig, RelationalTokenPipeline
from repro.models.common import ModelConfig
from repro.models.factory import build_model
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import (OptConfig, apply_updates, global_norm,
                                   init_opt, schedule)
from repro.train.steps import (TrainState, init_train_state, make_train_step,
                               _microbatch)

TINY = ModelConfig(arch="t", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, rope_theta=1e4, remat="none")


def test_adamw_against_reference():
    """One step vs a NumPy AdamW (matrices get weight decay)."""
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10, b1=0.9, b2=0.95,
                    weight_decay=0.1, clip_norm=1e9)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    grads = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    state = init_opt(params)
    new_p, new_s, m = apply_updates(params, grads, state, cfg)
    g = np.asarray(grads["w"])
    mm = 0.1 * g
    vv = 0.05 * g * g
    mh = mm / (1 - 0.9)
    vh = vv / (1 - 0.95)
    lr = float(schedule(cfg, jnp.asarray(1)))
    step = mh / (np.sqrt(vh) + cfg.eps) + 0.1 * np.asarray(params["w"])
    want = np.asarray(params["w"]) - lr * step
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_s.count) == 1


def test_grad_clipping():
    cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=0.1,
                    weight_decay=0.0)
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    grads = {"w": jnp.full((4, 4), 100.0, jnp.float32)}
    _, _, metrics = apply_updates(params, grads, init_opt(params), cfg)
    assert float(metrics["grad_norm"]) == 400.0


def test_microbatch_slicing_partition():
    """Every row lands in exactly one microbatch; union is the batch."""
    batch = {"x": jnp.arange(24).reshape(12, 2)}
    seen = []
    for k in range(4):
        mb = _microbatch(batch, jnp.asarray(k, jnp.int32), 4)
        assert mb["x"].shape == (3, 2)
        seen.append(np.asarray(mb["x"]))
    rows = np.concatenate(seen).tolist()
    assert sorted(map(tuple, rows)) == sorted(
        map(tuple, np.arange(24).reshape(12, 2).tolist()))


def test_microbatch_equivalence():
    """mb=1 and mb=4 produce (nearly) identical updates."""
    model = build_model(TINY)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, 256, (8, 16)), jnp.int32),
             "weight": jnp.ones((8,), jnp.float32)}
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    outs = []
    for mb in (1, 4):
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, ocfg, microbatches=mb))
        state, metrics = step(state, batch)
        outs.append(state.params)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(outs[0]),
                             jax.tree.leaves(outs[1]))]
    # loss weighting per-token differs slightly between mean-of-means and
    # global mean; bf16 params quantize the tiny delta
    assert max(diffs) < 1e-2, max(diffs)


def test_loss_decreases_overfit():
    model = build_model(TINY)
    pipe = RelationalTokenPipeline(PipelineConfig(
        seq_len=32, global_batch=8, vocab_size=256, seed=7))
    # overfit a single repeated batch -> loss must drop markedly
    batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(0).items()}
    ocfg = OptConfig(lr=3e-3, warmup_steps=10, total_steps=200,
                     weight_decay=0.0)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, ocfg), donate_argnums=(0,))
    first = None
    for i in range(60):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first - 1.0, (first, last)


def test_master_params_track_bf16():
    model = build_model(TINY)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((4, 8), jnp.int32),
             "weight": jnp.ones((4,), jnp.float32)}
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, warmup_steps=0,
                                                    total_steps=5)))
    state, _ = step(state, batch)
    for p, mst in zip(jax.tree.leaves(state.params),
                      jax.tree.leaves(state.opt.master)):
        assert mst.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(p, np.float32),
                                   np.asarray(mst.astype(p.dtype), np.float32))
