"""Table invariants: construction, gather, concat, N-D columns (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.table import Table, concat_tables

ints = st.integers(-1000, 1000)


@st.composite
def table_data(draw, max_rows=20, with_2d=False):
    n = draw(st.integers(0, max_rows))
    cols = {"k": np.asarray(draw(st.lists(ints, min_size=n, max_size=n)),
                            np.int32)}
    cols["v"] = np.asarray(
        draw(st.lists(st.floats(-10, 10, width=32), min_size=n, max_size=n)),
        np.float32)
    if with_2d:
        cols["tok"] = np.arange(n * 3, dtype=np.int32).reshape(n, 3)
    return cols


@given(table_data())
def test_from_arrays_roundtrip(cols):
    t = Table.from_arrays(cols)
    out = t.to_numpy()
    for k in cols:
        np.testing.assert_array_equal(out[k], cols[k])


@given(table_data(), st.integers(1, 10))
def test_capacity_padding(cols, extra):
    n = len(cols["k"])
    t = Table.from_arrays(cols, capacity=n + extra)
    assert t.capacity == n + extra
    assert int(t.row_count) == n
    out = t.to_numpy()
    np.testing.assert_array_equal(out["k"], cols["k"])
    assert bool(np.all(np.asarray(t.valid_mask())[:n]))
    assert not np.any(np.asarray(t.valid_mask())[n:])


@given(table_data(max_rows=10), table_data(max_rows=10))
def test_concat_preserves_rows(a_cols, b_cols):
    a = Table.from_arrays(a_cols, capacity=len(a_cols["k"]) + 3)
    b = Table.from_arrays(b_cols, capacity=len(b_cols["k"]) + 2)
    c = concat_tables(a, b)
    assert int(c.row_count) == int(a.row_count) + int(b.row_count)
    out = c.to_numpy()
    np.testing.assert_array_equal(
        out["k"], np.concatenate([a_cols["k"], b_cols["k"]]))


def test_nd_columns():
    cols = {"id": np.arange(4, dtype=np.int32),
            "tok": np.arange(12, dtype=np.int32).reshape(4, 3)}
    t = Table.from_arrays(cols, capacity=6)
    g = t.gather(jnp.asarray([2, 0, -1, 1, -1, -1]), 2)
    out = np.asarray(g.columns["tok"])
    np.testing.assert_array_equal(out[0], cols["tok"][2])
    np.testing.assert_array_equal(out[1], cols["tok"][0])
    np.testing.assert_array_equal(out[2], 0)  # -1 fills zeros

    c = concat_tables(t, t)
    assert int(c.row_count) == 8
    np.testing.assert_array_equal(
        c.to_numpy()["tok"], np.concatenate([cols["tok"], cols["tok"]]))


def test_rename_and_project_names():
    t = Table.from_arrays({"a": np.arange(3, dtype=np.int32)})
    r = t.rename({"a": "b"})
    assert r.column_names == ["b"]
