"""Relational ETL pipeline: determinism, shapes, filter/join semantics."""
import numpy as np

from repro.core import ops_local as L
from repro.data import synthetic
from repro.data.pipeline import PipelineConfig, Prefetcher, RelationalTokenPipeline


def test_batch_shapes_and_determinism():
    p = RelationalTokenPipeline(PipelineConfig(
        seq_len=48, global_batch=12, vocab_size=999, seed=3))
    b0 = p.global_batch(0)
    assert b0["tokens"].shape == (12, 48)
    assert b0["weight"].shape == (12,)
    assert b0["tokens"].dtype == np.int32
    np.testing.assert_array_equal(b0["tokens"], p.global_batch(0)["tokens"])
    assert not np.array_equal(b0["tokens"], p.global_batch(1)["tokens"])


def test_quality_filter_semantics():
    """Every emitted row passed the quality filter + label join."""
    cfg = PipelineConfig(seq_len=16, global_batch=8, vocab_size=100,
                         quality_threshold=0.5, seed=11)
    p = RelationalTokenPipeline(cfg)
    b = p.global_batch(0)
    # re-derive the oracle set of surviving token rows across refills
    surviving = []
    for refill in range(cfg.max_refills):
        samples, labels = p._round(0, refill)
        sn = samples.to_numpy()
        ln = labels.to_numpy()
        lab = set(ln["sample_id"].tolist())
        for i in range(len(sn["sample_id"])):
            if sn["quality"][i] > 0.5 and sn["sample_id"][i] in lab:
                surviving.append(tuple(sn["tokens"][i].tolist()))
        if len(surviving) >= cfg.global_batch:
            break
    got = {tuple(r.tolist()) for r in b["tokens"]}
    assert got <= set(surviving)
    assert (b["weight"] > 0).all()


def test_tokens_in_vocab():
    p = RelationalTokenPipeline(PipelineConfig(
        seq_len=16, global_batch=8, vocab_size=77, seed=1))
    b = p.global_batch(5)
    assert b["tokens"].min() >= 1 and b["tokens"].max() < 77


def test_prefetcher_order():
    p = RelationalTokenPipeline(PipelineConfig(
        seq_len=8, global_batch=4, vocab_size=50, seed=2))
    direct = [p.global_batch(i)["tokens"] for i in range(3)]
    import itertools
    pf = list(itertools.islice(Prefetcher(p, depth=2), 3))
    for a, b in zip(direct, pf):
        np.testing.assert_array_equal(a, b["tokens"])


def test_quality_stats_stage():
    """The groupby stats stage: per-source mean/var/count over ALL refill
    rounds consumed for the batch (partial -> combine, the two-phase path)."""
    from repro.data.pipeline import source_quality_stats

    cfg = PipelineConfig(seq_len=8, global_batch=16, vocab_size=50,
                         quality_threshold=0.9, collect_stats=True, seed=5)
    p = RelationalTokenPipeline(cfg)
    p.global_batch(0)
    s = p.last_stats
    assert s is not None
    # oracle: concatenate the raw sample rounds the batch actually consumed
    n_rounds = int(round(s["quality_count"].sum())) // p._raw_rows
    src, qual = [], []
    for refill in range(max(n_rounds, 1)):
        samples, _ = p._round(0, refill)
        d = samples.to_numpy()
        src.append(d["source"]); qual.append(d["quality"])
    src, qual = np.concatenate(src), np.concatenate(qual)
    assert s["quality_count"].sum() == len(src)
    for i, b in enumerate(s["source"]):
        g = qual[src == b]
        assert s["quality_count"][i] == len(g)
        np.testing.assert_allclose(s["quality_mean"][i], g.mean(), atol=1e-5)
        np.testing.assert_allclose(s["quality_var"][i], g.var(), atol=1e-4)

    # standalone stage on a single table
    t = synthetic.lm_samples_table(300, 8, 50, seed=9)
    d = t.to_numpy()
    st = source_quality_stats(t).to_numpy()
    assert st["quality_count"].sum() == 300
    assert set(st["source"].tolist()) == set(d["source"].tolist())


def test_synthetic_streams_independent():
    a = synthetic.random_table(100, seed=0, step=0, shard=0)
    b = synthetic.random_table(100, seed=0, step=0, shard=1)
    c = synthetic.random_table(100, seed=0, step=1, shard=0)
    ka = np.asarray(a.columns["k"])
    assert not np.array_equal(ka, np.asarray(b.columns["k"]))
    assert not np.array_equal(ka, np.asarray(c.columns["k"]))
    a2 = synthetic.random_table(100, seed=0, step=0, shard=0)
    np.testing.assert_array_equal(ka, np.asarray(a2.columns["k"]))


def test_zipf_skew():
    t = synthetic.zipf_table(5000, a=1.3, key_range=1000, seed=4)
    k = np.asarray(t.columns["k"])
    # heavy head: the most common key appears far above uniform expectation
    _, counts = np.unique(k, return_counts=True)
    assert counts.max() > 20 * (5000 / 1000)


def test_prefetcher_propagates_worker_error():
    """A crash in the source iterator must re-raise in the CONSUMER —
    not vanish in the worker thread as a silent early end-of-data."""
    def flaky():
        yield {"tokens": np.zeros((2, 4), np.int32)}
        yield {"tokens": np.ones((2, 4), np.int32)}
        raise RuntimeError("source blew up")

    pf = Prefetcher(flaky(), depth=2)
    got = [next(pf), next(pf)]
    assert len(got) == 2
    try:
        next(pf)
    except RuntimeError as e:
        assert "source blew up" in str(e)
    else:
        raise AssertionError("worker error was swallowed")


def test_prefetcher_clean_stop_unaffected():
    def fine():
        for i in range(3):
            yield i

    assert list(Prefetcher(fine(), depth=2)) == [0, 1, 2]
