"""Serving correctness: prefill + stepwise decode must reproduce the full
causal forward's logits (KV/state-cache consistency), per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models.factory import build_model
from repro.train.steps import make_decode_step, make_prefill_step

# MLA decode uses the absorbed latent path (different op order than the
# materialized prefill path) -> slightly larger fp tolerance.
CASES = [
    ("llama3-8b", 3e-2),
    ("minicpm3-4b", 8e-2),
    ("zamba2-1.2b", 5e-2),
    ("xlstm-1.3b", 5e-2),
    ("whisper-base", 5e-2),
    ("qwen2-moe-a2.7b", 5e-2),
]


@pytest.mark.parametrize("arch,tol", CASES)
def test_prefill_decode_matches_causal(arch, tol):
    cfg = get_tiny(arch)
    if cfg.moe_num_experts:
        # capacity drops depend on the routed token set, so prefill(8 toks)
        # and causal(12 toks) legitimately differ under drops — test the
        # cache path itself with a no-drop capacity factor.
        cfg = cfg.replace(moe_capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S_p, S_gen = 2, 8, 4
    total = S_p + S_gen
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, total)),
                         jnp.int32)
    embeds = None
    if cfg.family == "audio":
        embeds = jnp.asarray(rng.standard_normal((B, S_p, cfg.d_model)),
                             jnp.float32)

    # reference: one full causal pass over all `total` tokens
    ref_logits, _, _ = model.forward(params, tokens=tokens, embeds=embeds,
                                     mode="causal", cache=None, pos=None)
    ref = np.asarray(ref_logits.astype(jnp.float32))[:, :, : cfg.vocab_size]

    # prefill on the first S_p tokens, then decode the rest one by one
    prefill = make_prefill_step(model, total, enc_len=S_p)
    batch = {"tokens": tokens[:, :S_p]}
    if embeds is not None:
        batch["embeds"] = embeds
    last, cache = prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(last.astype(jnp.float32))[:, : cfg.vocab_size],
        ref[:, S_p - 1], atol=tol, rtol=tol)

    decode = make_decode_step(model)
    for i in range(S_gen):
        pos = jnp.asarray(S_p + i, jnp.int32)
        logits, cache = decode(params, cache, tokens[:, S_p + i : S_p + i + 1],
                               pos)
        np.testing.assert_allclose(
            np.asarray(logits.astype(jnp.float32)), ref[:, S_p + i],
            atol=tol, rtol=tol, err_msg=f"{arch} step {i}")


def test_vlm_prefill_decode():
    cfg = get_tiny("internvl2-76b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S_p, S_gen = 2, 8, 3
    nf = cfg.num_frontend_tokens
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S_p + S_gen)),
                         jnp.int32)
    embeds = jnp.asarray(rng.standard_normal((B, nf, cfg.d_model)), jnp.float32)
    ref_logits, _, _ = model.forward(params, tokens=tokens, embeds=embeds,
                                     mode="causal", cache=None, pos=None)
    ref = np.asarray(ref_logits.astype(jnp.float32))[:, nf:, : cfg.vocab_size]

    prefill = make_prefill_step(model, nf + S_p + S_gen)
    last, cache = prefill(params, {"tokens": tokens[:, :S_p],
                                   "embeds": embeds})
    np.testing.assert_allclose(
        np.asarray(last.astype(jnp.float32))[:, : cfg.vocab_size],
        ref[:, S_p - 1], atol=3e-2, rtol=3e-2)
    decode = make_decode_step(model)
    for i in range(S_gen):
        pos = jnp.asarray(nf + S_p + i, jnp.int32)
        logits, cache = decode(params, cache,
                               tokens[:, S_p + i : S_p + i + 1], pos)
        np.testing.assert_allclose(np.asarray(logits.astype(jnp.float32)),
                                   ref[:, S_p + i], atol=3e-2, rtol=3e-2)
