"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Assignment contract: for each kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.bitonic import bitonic_sort_tiles
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hash64 import hash32
from repro.kernels.histogram import bucket_histogram

RNG = np.random.default_rng(0)


# --- hash32 -----------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 7, 128, 8192, 8193, 100_000])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32, jnp.float32])
def test_hash32_sweep(n, dtype):
    if dtype == jnp.float32:
        x = jnp.asarray(RNG.standard_normal(n), dtype)
    else:
        x = jnp.asarray(RNG.integers(-2**31, 2**31 - 1, n), jnp.int64) \
            .astype(dtype)
    got = hash32(x, seed=17)
    want = ref.hash32_ref(x, seed=17)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash32_seed_sensitivity():
    x = jnp.arange(100, dtype=jnp.int32)
    a = np.asarray(hash32(x, seed=0))
    b = np.asarray(hash32(x, seed=1))
    assert (a != b).mean() > 0.99


def test_hash_columns_multicolumn():
    a = jnp.asarray(RNG.integers(0, 100, 50), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 100, 50), jnp.int32)
    h_ab = np.asarray(kops.hash_columns([a, b]))
    h_ba = np.asarray(kops.hash_columns([b, a]))
    assert (h_ab != h_ba).any()  # order-sensitive


# --- histogram ----------------------------------------------------------------


@pytest.mark.parametrize("n,buckets", [(1, 2), (100, 7), (5000, 16),
                                       (4096, 256), (9999, 64)])
def test_histogram_sweep(n, buckets):
    ids = jnp.asarray(RNG.integers(-1, buckets, n), jnp.int32)
    got = bucket_histogram(ids, buckets)
    want = ref.histogram_ref(ids, buckets)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.asarray(got).sum()) == int((np.asarray(ids) >= 0).sum())


# --- segment reduce: segment-axis tiling across the one-tile boundary ----------


@pytest.mark.parametrize("n,g", [
    (3000, 1023),   # just under one tile (single output block, old path)
    (3000, 1024),   # exactly one tile
    (3000, 1025),   # first tiled case: 2 segment tiles
    (9999, 2048),   # tile-aligned multi-tile
    (5000, 3000),   # ragged final tile
])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_segment_reduce_tiled_boundary_sweep(n, g, op, dtype):
    from repro.kernels.segment_reduce import MAX_SEGMENTS, segment_reduce_tiles
    assert MAX_SEGMENTS == 1024  # the sweep brackets this boundary
    vals = jnp.asarray(RNG.integers(-40, 40, n), dtype)
    seg = jnp.asarray(RNG.integers(-1, g, n), jnp.int32)  # -1 = padding
    want = np.asarray(ref.segment_reduce_ref(vals, seg, g, op))
    got = np.asarray(segment_reduce_tiles(vals, seg, g, op))
    np.testing.assert_array_equal(got, want)
    # the public wrapper routes oversize counts to the SAME kernel now;
    # the XLA scatter path stays available as the use_kernel=False oracle
    via_ops = np.asarray(kops.segment_reduce(vals, seg, g, op,
                                             use_kernel=True))
    fallback = np.asarray(kops.segment_reduce(vals, seg, g, op,
                                              use_kernel=False))
    np.testing.assert_array_equal(via_ops, want)
    np.testing.assert_array_equal(fallback, want)


def test_segment_reduce_tiled_values_land_in_correct_tile():
    # one value per segment, segments chosen to straddle every tile edge:
    # any offset error between tiles would misplace them
    from repro.kernels.segment_reduce import MAX_SEGMENTS, segment_reduce_tiles
    g = 3 * MAX_SEGMENTS
    targets = np.asarray([0, MAX_SEGMENTS - 1, MAX_SEGMENTS,
                          2 * MAX_SEGMENTS - 1, 2 * MAX_SEGMENTS, g - 1],
                         np.int32)
    vals = jnp.asarray(np.arange(1, len(targets) + 1), jnp.int32)
    out = np.asarray(segment_reduce_tiles(vals, jnp.asarray(targets), g,
                                          "sum"))
    expect = np.zeros((g,), np.int32)
    expect[targets] = np.arange(1, len(targets) + 1)
    np.testing.assert_array_equal(out, expect)


# --- segment scan: carry across the row-block (1024) boundary -------------------


@pytest.mark.parametrize("n", [
    1,        # single row
    1023,     # one row short of a block
    1024,     # exactly one block
    1025,     # first carried case: 2 blocks, segment spans the edge
    3000,     # ragged multi-block
])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_segment_scan_block_boundary_sweep(n, op, inclusive, dtype):
    from repro.kernels.segment_scan import BLOCK, segment_scan_tiles
    assert BLOCK == 1024  # the sweep brackets this boundary
    # contiguous non-decreasing runs, ids sparse (skipped ids = empty
    # segments), run lengths down to 1 (single-row segments)
    seg = np.sort(RNG.integers(0, max(1, n // 2), n) * 3).astype(np.int32)
    vals = jnp.asarray(RNG.integers(-40, 40, n), dtype)
    segj = jnp.asarray(seg)
    want = np.asarray(ref.segment_scan_ref(vals, segj, op, inclusive))
    got = np.asarray(segment_scan_tiles(vals, segj, op, inclusive=inclusive))
    np.testing.assert_array_equal(got, want)
    # the public wrapper: forced kernel and forced oracle both match
    via_ops = np.asarray(kops.segment_scan(vals, segj, op,
                                           inclusive=inclusive,
                                           use_kernel=True))
    fallback = np.asarray(kops.segment_scan(vals, segj, op,
                                            inclusive=inclusive,
                                            use_kernel=False))
    np.testing.assert_array_equal(via_ops, want)
    np.testing.assert_array_equal(fallback, want)


def test_segment_scan_single_segment_spans_blocks():
    # ONE segment over 3 blocks: any carry bug accumulates visibly
    from repro.kernels.segment_scan import BLOCK, segment_scan_tiles
    n = 3 * BLOCK
    vals = jnp.ones((n,), jnp.int32)
    seg = jnp.zeros((n,), jnp.int32)
    got = np.asarray(segment_scan_tiles(vals, seg, "sum"))
    np.testing.assert_array_equal(got, np.arange(1, n + 1))
    excl = np.asarray(segment_scan_tiles(vals, seg, "sum", inclusive=False))
    np.testing.assert_array_equal(excl, np.arange(n))


def test_segment_scan_boundary_straddling_runs():
    # segments chosen to cut exactly AT the block edges (1024±1): a new
    # segment beginning at the first row of a block must ignore the carry
    from repro.kernels.segment_scan import BLOCK, segment_scan_tiles
    n = 2 * BLOCK + 2
    edges = [0, BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK, n]
    seg = np.zeros((n,), np.int32)
    for s_id, (lo, hi) in enumerate(zip(edges, edges[1:])):
        seg[lo:hi] = s_id
    vals = jnp.asarray(RNG.integers(-9, 9, n), jnp.int32)
    segj = jnp.asarray(seg)
    for op in ("sum", "min", "max"):
        want = np.asarray(ref.segment_scan_ref(vals, segj, op, True))
        got = np.asarray(segment_scan_tiles(vals, segj, op))
        np.testing.assert_array_equal(got, want)


def test_segment_scan_rejects_bad_shapes():
    vals = jnp.zeros((8, 2), jnp.float32)
    seg = jnp.zeros((8,), jnp.int32)
    with pytest.raises(Exception):
        kops.segment_scan(vals, seg, "sum", use_kernel=True)


# --- bitonic sort ---------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 512, 2048])
@pytest.mark.parametrize("dtype", [jnp.uint32, jnp.int32, jnp.float32])
def test_bitonic_tile_sorted(n, dtype):
    if dtype == jnp.float32:
        keys = jnp.asarray(RNG.standard_normal(n), dtype)
    else:
        keys = jnp.asarray(RNG.integers(0, 10_000, n), dtype)
    payload = jnp.arange(n, dtype=jnp.int32)
    ko, vo = bitonic_sort_tiles(keys, payload, tile=n)
    kr, vr = ref.sort_pairs_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vr))


@pytest.mark.parametrize("n", [10, 300, 1000])
def test_sort_pairs_wrapper(n):
    keys = jnp.asarray(RNG.integers(0, 50, n), jnp.uint32)  # dups: stability
    payload = jnp.arange(n, dtype=jnp.int32)
    ko, vo = kops.sort_pairs(keys, payload)
    kr, vr = ref.sort_pairs_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vr))


# --- flash attention -------------------------------------------------------------


@pytest.mark.parametrize("shape", [
    # (B, S, H, KV, hd, bq, bk)
    (2, 256, 4, 2, 64, 128, 128),
    (1, 512, 8, 8, 32, 256, 128),
    (1, 256, 4, 1, 128, 128, 256),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, causal):
    b, s, h, kv, hd, bq, bk = shape
    q = jnp.asarray(RNG.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=2e-2, rtol=2e-2)


# --- model-layer chunked attention vs flash kernel (cross-validation) -----------


def test_chunked_sdpa_matches_flash_kernel():
    from repro.models import layers as NN
    from repro.models.common import ModelConfig
    cfg = ModelConfig(arch="x", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      time_unroll=True)
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.float32)
    # force both chunked paths
    ch_q = NN._chunked_q(q, NN._repeat_kv(k, 2), NN._repeat_kv(v, 2),
                         causal=True, q_offset=0, kv_len=None, cfg=cfg)
    ch_k = NN._chunked_k(q, NN._repeat_kv(k, 2), NN._repeat_kv(v, 2),
                         causal=True, q_offset=0, kv_len=None, cfg=cfg)
    want = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    np.testing.assert_allclose(np.asarray(ch_q), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ch_k), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
