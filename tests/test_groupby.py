"""GroupBy vs the NumPy oracle + segment-reduce kernel sweeps.

Deliberately hypothesis-free: this module is part of the minimal-environment
tier-1 gate (conftest skips the property-test modules when hypothesis is
absent; the groupby coverage must survive that).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops_agg as A
from repro.core.table import Table, concat_tables
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.segment_reduce import segment_reduce_tiles

from oracle import groupby_oracle

RNG = np.random.default_rng(7)

ALL_AGGS = [("v", op) for op in A.AGG_OPS]


def check_vs_oracle(out: Table, table_dict, keys, aggs, atol=1e-4):
    """out rows (sorted by key, front-compacted) == oracle, column-wise.
    Float results compare with allclose (reduction order differs); integer
    results must match exactly."""
    want = groupby_oracle(table_dict, keys, [(c, o) for c, o in aggs])
    got = out.to_numpy()
    assert sorted(got) == sorted(want), (sorted(got), sorted(want))
    n_groups = len(want[keys[0]])
    assert int(out.row_count) == n_groups
    for name, w in want.items():
        g = got[name]
        assert g.shape == w.astype(g.dtype).shape, name
        if np.issubdtype(g.dtype, np.floating):
            np.testing.assert_allclose(g, w, atol=atol, rtol=1e-4,
                                       err_msg=name)
        else:
            np.testing.assert_array_equal(g, w, err_msg=name)


# --- segment_reduce kernel vs oracle -----------------------------------------


@pytest.mark.parametrize("n,g", [(1, 1), (100, 7), (1024, 128), (5000, 37),
                                 (9999, 1000)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_segment_reduce_kernel_sweep(n, g, op, dtype):
    vals = jnp.asarray(RNG.integers(-40, 40, n), dtype)
    seg = jnp.asarray(RNG.integers(-1, g, n), jnp.int32)  # -1 = padding
    want = np.asarray(ref.segment_reduce_ref(vals, seg, g, op))
    got = segment_reduce_tiles(vals, seg, g, op)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_reduce_xla_fallback_matches_kernel(op):
    n, g = 3000, 50
    vals = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    seg = jnp.asarray(RNG.integers(-1, g, n), jnp.int32)
    a = np.asarray(kops.segment_reduce(vals, seg, g, op, use_kernel=True))
    b = np.asarray(kops.segment_reduce(vals, seg, g, op, use_kernel=False))
    np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_reduce_nd_payload(op):
    n, g, d = 500, 9, 6
    vals = jnp.asarray(RNG.integers(-40, 40, (n, d)), jnp.int32)
    seg = jnp.asarray(RNG.integers(-1, g, n), jnp.int32)
    got = np.asarray(kops.segment_reduce(vals, seg, g, op))
    want = np.asarray(ref.segment_reduce_ref(vals, seg, g, op))
    np.testing.assert_array_equal(got, want)


def test_segment_reduce_empty_segments_hold_identity():
    vals = jnp.asarray([1.0, 2.0], jnp.float32)
    seg = jnp.asarray([0, 0], jnp.int32)
    out = np.asarray(kops.segment_reduce(vals, seg, 4, "min"))
    assert out[0] == 1.0 and np.all(np.isinf(out[1:]))


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_reduce_oversize_runs_in_kernel(op):
    # num_segments beyond one VMEM tile now tiles the segment axis in a
    # second grid dimension — the kernel path must match the oracle AND
    # the XLA scatter fallback (use_kernel=False) exactly
    n, g = 4000, kops.MAX_SEGMENTS + 300
    vals = jnp.asarray(RNG.integers(-40, 40, n), jnp.int32)
    seg = jnp.asarray(RNG.integers(-1, g, n), jnp.int32)
    want = np.asarray(ref.segment_reduce_ref(vals, seg, g, op))
    for use_kernel in (None, True, False):
        got = np.asarray(kops.segment_reduce(vals, seg, g, op,
                                             use_kernel=use_kernel))
        np.testing.assert_array_equal(got, want)
    # and the raw tiled kernel agrees on its own
    np.testing.assert_array_equal(
        np.asarray(segment_reduce_tiles(vals, seg, g, op)), want)


# --- local groupby vs oracle -------------------------------------------------


def make_table(n, key_range, pad=5, seed=0, int_payload=True):
    rng = np.random.default_rng(seed)
    cols = {
        "k": rng.integers(0, key_range, n).astype(np.int32),
        "v": (rng.integers(-30, 30, n).astype(np.int32) if int_payload
              else rng.standard_normal(n).astype(np.float32)),
    }
    return cols, Table.from_arrays(cols, capacity=n + pad)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("key_range", [1, 4, 50])
def test_groupby_randomized(seed, key_range):
    cols, t = make_table(60, key_range, seed=seed)
    out = A.groupby(t, "k", ALL_AGGS)
    check_vs_oracle(out, cols, ["k"], ALL_AGGS)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_groupby_kernel_paths_agree(use_kernel):
    cols, t = make_table(200, 11, seed=3)
    out = A.groupby(t, "k", ALL_AGGS, use_kernel=use_kernel)
    check_vs_oracle(out, cols, ["k"], ALL_AGGS)


def test_groupby_float_payload():
    cols, t = make_table(80, 6, seed=2, int_payload=False)
    aggs = [("v", op) for op in ("sum", "mean", "var", "min", "max", "first")]
    out = A.groupby(t, "k", aggs)
    check_vs_oracle(out, cols, ["k"], aggs)


def test_groupby_empty_table():
    t = Table.empty({"k": jnp.int32, "v": jnp.int32}, capacity=8)
    out = A.groupby(t, "k", [("v", "sum"), ("v", "count")])
    assert int(out.row_count) == 0
    assert out.to_numpy()["v_sum"].shape == (0,)


def test_groupby_all_one_group():
    cols = {"k": np.full(30, 5, np.int32),
            "v": np.arange(30, dtype=np.int32)}
    t = Table.from_arrays(cols, capacity=33)
    out = A.groupby(t, "k", ALL_AGGS)
    check_vs_oracle(out, cols, ["k"], ALL_AGGS)
    assert int(out.row_count) == 1


def test_groupby_multikey():
    rng = np.random.default_rng(11)
    cols = {"a": rng.integers(0, 4, 50).astype(np.int32),
            "b": rng.integers(0, 3, 50).astype(np.int32),
            "v": rng.integers(-9, 9, 50).astype(np.int32)}
    t = Table.from_arrays(cols, capacity=54)
    aggs = [("v", "sum"), ("v", "count"), ("v", "first")]
    out = A.groupby(t, ["a", "b"], aggs)
    check_vs_oracle(out, cols, ["a", "b"], aggs)


def test_groupby_nd_payload():
    """Token-vector payload: per-group element-wise aggregation."""
    rng = np.random.default_rng(4)
    cols = {"k": rng.integers(0, 5, 40).astype(np.int32),
            "v": rng.integers(0, 100, (40, 7)).astype(np.int32)}
    t = Table.from_arrays(cols, capacity=44)
    aggs = [("v", op) for op in ("sum", "min", "max", "mean", "first")]
    out = A.groupby(t, "k", aggs)
    check_vs_oracle(out, cols, ["k"], aggs)


def test_groupby_dict_aggs_and_out_capacity():
    cols, t = make_table(64, 32, seed=9)
    out = A.groupby(t, "k", {"v": ["sum", "mean"]}, out_capacity=8)
    assert out.capacity == 8
    assert int(out.row_count) <= 8  # overflow truncates, like join
    # kept groups (key order) match the untruncated result exactly
    full = A.groupby(t, "k", {"v": ["sum", "mean"]})
    fa, tr = full.to_numpy(), out.to_numpy()
    n = int(out.row_count)
    for name in tr:
        np.testing.assert_array_equal(tr[name][:n], fa[name][:n],
                                      err_msg=name)


def test_groupby_kernel_on_large_table_via_out_capacity():
    """out_capacity bounds the segment count, so low-cardinality groupby
    rides the Pallas kernel even when the table itself is large."""
    cols, t = make_table(3000, 12, seed=13)
    out = A.groupby(t, "k", ALL_AGGS, out_capacity=64, use_kernel=True)
    check_vs_oracle(out, cols, ["k"], ALL_AGGS)


def test_segment_reduce_forced_kernel_shape_mismatch_still_raises():
    # oversize segment counts now run in the kernel via segment-axis
    # tiling (see above); a shape/dtype the kernel can never take errors
    with pytest.raises(ValueError, match="1-D"):
        kops.segment_reduce(jnp.zeros((8, 2), jnp.float32),
                            jnp.zeros((8,), jnp.int32), 4, "sum",
                            use_kernel=True)


# --- two-phase decomposition (the distributed combine path, run locally) ------


@pytest.mark.parametrize("n_parts", [1, 3])
def test_partial_combine_equals_direct(n_parts):
    cols, t = make_table(90, 7, seed=6)
    direct = A.groupby(t, "k", ALL_AGGS)
    # split rows into contiguous chunks = "shards" in global row order
    bounds = np.linspace(0, 90, n_parts + 1).astype(int)
    parts = []
    for i in range(n_parts):
        sub = {k: v[bounds[i]:bounds[i + 1]] for k, v in cols.items()}
        parts.append(Table.from_arrays(sub, capacity=len(sub["k"]) + 3))
    partials = [A.partial_groupby(p, "k", ALL_AGGS) for p in parts]
    cat = partials[0]
    for p in partials[1:]:
        cat = concat_tables(cat, p)
    combined = A.combine_groupby(cat, "k", ALL_AGGS)
    da, db = direct.to_numpy(), combined.to_numpy()
    assert sorted(da) == sorted(db)
    for name in da:
        if np.issubdtype(da[name].dtype, np.floating):
            np.testing.assert_allclose(da[name], db[name], atol=1e-4,
                                       rtol=1e-4, err_msg=name)
        else:
            np.testing.assert_array_equal(da[name], db[name], err_msg=name)
    check_vs_oracle(combined, cols, ["k"], ALL_AGGS)


def test_partial_groupby_shrinks_rows():
    """The two-phase win: partials carry <= cardinality rows per shard."""
    cols, t = make_table(500, 8, seed=1)
    part = A.partial_groupby(t, "k", [("v", "mean")], out_capacity=16)
    assert part.capacity == 16
    assert int(part.row_count) == len(set(cols["k"].tolist()))


# --- concat_tables edge cases (zero-valid-row inputs) -------------------------


def _kv(n, cap, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_arrays(
        {"k": rng.integers(0, 9, n).astype(np.int32)}, capacity=cap)


def test_concat_empty_left():
    a = Table.empty({"k": jnp.int32}, capacity=4)
    b = _kv(3, 5, seed=1)
    out = concat_tables(a, b)
    assert int(out.row_count) == 3
    np.testing.assert_array_equal(out.to_numpy()["k"], b.to_numpy()["k"])


def test_concat_empty_right():
    a = _kv(3, 5, seed=2)
    b = Table.empty({"k": jnp.int32}, capacity=4)
    out = concat_tables(a, b)
    assert int(out.row_count) == 3
    np.testing.assert_array_equal(out.to_numpy()["k"], a.to_numpy()["k"])


def test_concat_both_empty():
    a = Table.empty({"k": jnp.int32}, capacity=4)
    b = Table.empty({"k": jnp.int32}, capacity=2)
    out = concat_tables(a, b)
    assert int(out.row_count) == 0
    assert out.capacity == 6
    assert out.to_numpy()["k"].shape == (0,)


def test_concat_empty_then_groupby():
    """Zero-valid concat feeding groupby (the pipeline stats path)."""
    a = Table.empty({"k": jnp.int32, "v": jnp.int32}, capacity=4)
    cols = {"k": np.asarray([1, 1, 2], np.int32),
            "v": np.asarray([10, 20, 30], np.int32)}
    b = Table.from_arrays(cols, capacity=6)
    out = A.groupby(concat_tables(a, b), "k", [("v", "sum"), ("v", "count")])
    check_vs_oracle(out, cols, ["k"], [("v", "sum"), ("v", "count")])
