"""Statistics layer: NDV sketch accuracy, sizing math, analyze() contract.

Deliberately hypothesis-free: part of the minimal-environment tier-1 gate.
"""
import numpy as np
import pytest

from repro.core import stats as S
from repro.core.context import DistContext
from repro.core.table import Table


# --- sketch / linear counting -------------------------------------------------


@pytest.mark.parametrize("ndv", [1, 16, 200, 2000])
def test_analyze_table_ndv_accuracy(ndv):
    rng = np.random.default_rng(ndv)
    t = Table.from_arrays({
        "k": rng.integers(0, ndv, 8000).astype(np.int32)})
    true_ndv = len(np.unique(np.asarray(t.columns["k"])[:8000]))
    st = S.analyze_table(t)
    got = st.col("k").ndv
    assert abs(got - true_ndv) <= max(4.0, 0.15 * true_ndv), (got, true_ndv)


def test_analyze_table_min_max_and_rows():
    t = Table.from_arrays({
        "k": np.asarray([5, -3, 9, 9], np.int32),
        "v": np.asarray([1.5, -2.5, 0.0, 3.0], np.float32)}, capacity=10)
    st = S.analyze_table(t)
    assert st.rows == 4.0
    assert st.col("k").lo == -3.0 and st.col("k").hi == 9.0
    assert st.col("v").lo == -2.5 and st.col("v").hi == 3.0
    # garbage rows past row_count must not leak into the sketch
    assert st.col("k").ndv <= 4.0 + 1e-6


def test_linear_count_saturation_and_empty():
    assert S.linear_count(0, 0) == 0.0
    assert S.linear_count(0, 100) == 0.0
    # saturated bitmap: every value looks distinct -> clamp to rows
    assert S.linear_count(S.SKETCH_BUCKETS, 10_000) == 10_000.0
    assert S.linear_count(10, 5) <= 5.0  # never exceeds the row count


# --- TableStats algebra -------------------------------------------------------


def test_joint_ndv_caps_and_unknown_columns():
    st = S.TableStats(rows=1000.0, columns=(
        ("a", S.ColumnStats(50.0)), ("b", S.ColumnStats(40.0))))
    assert st.ndv(("a",)) == 50.0
    assert st.ndv(("a", "b")) == 1000.0  # 50*40 capped by rows
    assert st.ndv(("a", "missing")) is None  # unknown column poisons joint


def test_cap_rows_caps_column_ndv_and_filters():
    st = S.TableStats(rows=1000.0, columns=(
        ("a", S.ColumnStats(500.0, 0.0, 9.0)), ("b", S.ColumnStats(40.0))))
    out = S.cap_rows(st, 100.0, keep=("a",))
    assert out.rows == 100.0
    assert out.col("a").ndv == 100.0  # 500 capped to the new row count
    assert out.col("a").lo == 0.0 and out.col("a").hi == 9.0
    assert out.col("b") is None
    assert out.max_shard_rows is None  # placement knowledge doesn't survive


# --- sizing math --------------------------------------------------------------


def test_with_skew_margin_properties():
    assert S.with_skew_margin(0.0) >= 1  # never a zero-capacity bucket
    assert S.with_skew_margin(100.0) > 100  # mean alone is not enough
    # margin is sublinear: large buckets approach the mean
    assert S.with_skew_margin(10_000.0) < 1.1 * 10_000


def test_size_bucket_beats_fallback_slack_at_scale():
    # the whole point: estimated occupancy << capacity-based fallback
    p, cap, rows = 8, 4000, 2000  # half-full table
    from repro.core.repartition import default_bucket_capacity
    fallback = default_bucket_capacity(cap, p)  # FALLBACK_SLACK path
    sized = S.size_bucket(rows / p, p)
    assert sized < fallback, (sized, fallback)


def test_fallback_slack_is_the_single_source():
    # the documented no-stats constant feeds default_bucket_capacity
    from repro.core.repartition import default_bucket_capacity
    assert default_bucket_capacity(1000, 8) == \
        default_bucket_capacity(1000, 8, slack=S.FALLBACK_SLACK)


# --- DistContext.analyze ------------------------------------------------------


@pytest.fixture(scope="module")
def ctx():
    return DistContext(axis_name="stats_test")


def test_analyze_exact_rows_and_idempotence(ctx):
    rng = np.random.default_rng(3)
    t = Table.from_arrays({
        "k": rng.integers(0, 64, 500).astype(np.int32),
        "d0": rng.standard_normal(500).astype(np.float32)}, capacity=600)
    dt = ctx.scatter(t)
    assert dt.stats is None
    a = ctx.analyze(dt)
    assert a.stats is not None and a.stats.rows == 500.0
    assert a.stats.max_shard_rows is not None
    assert ctx.analyze(a) is a  # cached: second analyze is free
    true_ndv = len(np.unique(np.asarray(t.columns["k"])[:500]))
    assert abs(a.stats.col("k").ndv - true_ndv) <= max(4.0, 0.15 * true_ndv)


def test_analyze_skips_nd_payload_columns(ctx):
    t = Table.from_arrays({
        "k": np.arange(8, dtype=np.int32),
        "tokens": np.zeros((8, 16), np.int32)})
    a = ctx.analyze(ctx.scatter(t))
    assert a.stats.col("k") is not None
    assert a.stats.col("tokens") is None  # N-D: no placement/sketch role


def test_collect_propagates_estimated_stats(ctx):
    rng = np.random.default_rng(9)
    t = Table.from_arrays({
        "k": rng.integers(0, 16, 300).astype(np.int32),
        "d0": rng.integers(-5, 5, 300).astype(np.float32)})
    dt = ctx.analyze(ctx.scatter(t))
    out = ctx.frame(dt).groupby("k", (("d0", "sum"),)).collect()
    assert out.stats is not None
    # NDV-capped output estimate: ~16 groups, never the input row count
    assert out.stats.rows <= 32.0
    # unanalyzed inputs propagate nothing
    out2 = ctx.frame(ctx.scatter(t)).groupby("k", (("d0", "sum"),)).collect()
    assert out2.stats is None
