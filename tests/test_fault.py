"""Fault tolerance: crash mid-run -> resume -> bitwise-identical training."""
import jax
import numpy as np
import pytest

from repro.data.pipeline import PipelineConfig, RelationalTokenPipeline
from repro.models.common import ModelConfig
from repro.models.factory import build_model
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import OptConfig

CFG = ModelConfig(arch="t", family="dense", num_layers=2, d_model=48,
                  num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128,
                  head_dim=12, rope_theta=1e4, remat="none")


def _pipe():
    return RelationalTokenPipeline(PipelineConfig(
        seq_len=24, global_batch=8, vocab_size=128, seed=5))


def test_crash_resume_bitwise(tmp_path):
    model = build_model(CFG)
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)

    # ground truth: uninterrupted run
    ref, _ = run(model, _pipe(), ocfg,
                 LoopConfig(total_steps=14, log_every=100),
                 log=lambda s: None)

    # run that crashes at step 10 (after checkpoint at 8), then resumes
    d = str(tmp_path / "ckpt")
    lcfg = LoopConfig(total_steps=14, ckpt_dir=d, ckpt_every=4,
                      log_every=100)
    with pytest.raises(RuntimeError, match="injected failure"):
        run(model, _pipe(), ocfg, lcfg, fail_at_step=10, log=lambda s: None)
    resumed, _ = run(model, _pipe(), ocfg, lcfg, log=lambda s: None)

    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(resumed.step) == 14


def test_double_crash_resume(tmp_path):
    """Two failures in a row still converge to the same state."""
    model = build_model(CFG)
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    ref, _ = run(model, _pipe(), ocfg, LoopConfig(total_steps=12,
                                                  log_every=100),
                 log=lambda s: None)
    d = str(tmp_path / "ckpt2")
    lcfg = LoopConfig(total_steps=12, ckpt_dir=d, ckpt_every=3, log_every=100)
    for fail_at in (5, 9):
        with pytest.raises(RuntimeError):
            run(model, _pipe(), ocfg, lcfg, fail_at_step=fail_at,
                log=lambda s: None)
    final, _ = run(model, _pipe(), ocfg, lcfg, log=lambda s: None)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
