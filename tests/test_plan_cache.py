"""PlanCache + async-dispatch unit tests (single-device, subprocess-free).

Covers the serving-path cache contracts: canonical-key stability across
structurally-equal plans, LRU admission/eviction order and budgets,
recompile accounting, content keys for keyless user lambdas (a re-created
lambda from the same definition site hits; a changed capture, rebound
global, or differing kw-only default misses; unhashable captures and
opaque callables stay uncached), guard pinning/invalidation,
safe-capacity variants under distinct key namespaces, and interleaved
``collect_async`` futures resolving bit-identical to sequential
``collect`` calls.

Deliberately hypothesis-free: part of the minimal-environment tier-1 gate.
"""
import numpy as np
import pytest

from repro.core import plan as PL
from repro.core.context import DistContext
from repro.core.plan_cache import PlanCache
from repro.core.serving import ServingSession
from repro.core.table import Table
from repro.testing.compare import tables_bitwise_equal


# --- canonical keys -----------------------------------------------------------


def _gb_plan(strategy="auto"):
    return PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"), ("d0", "count")),
                      strategy=strategy)


def test_canonical_key_stable_across_structurally_equal_plans():
    a = PL.Limit(PL.Sort(_gb_plan(), ("k",)), 10)
    b = PL.Limit(PL.Sort(_gb_plan(), ("k",)), 10)
    assert a is not b
    assert PL.canonical_key(a) == PL.canonical_key(b)
    assert hash(PL.canonical_key(a)) == hash(PL.canonical_key(b))


def test_canonical_key_distinguishes_parameters():
    base = PL.canonical_key(_gb_plan())
    assert PL.canonical_key(_gb_plan("shuffle")) != base
    assert PL.canonical_key(PL.Limit(_gb_plan(), 10)) != base


def test_canonical_key_rejects_keyless_select():
    plan = PL.Select(PL.Scan(0), lambda c: c["d0"] > 0)
    assert PL.canonical_key(plan) is None
    keyed = PL.Select(PL.Scan(0), lambda c: c["d0"] > 0, key="pos")
    assert PL.canonical_key(keyed) is not None


def test_identity_key_stable_for_recreated_lambda():
    """The serving pattern: a client re-builds the same query, re-creating
    the inline lambda — same code content, same captured values -> same
    content key (cache-hot)."""
    def build(pred):
        return PL.Select(PL.Scan(0), pred)

    def make():
        return lambda c: c["d0"] > 0.0

    k1 = PL.identity_key(build(make()))
    k2 = PL.identity_key(build(make()))
    assert k1 is not None and k1 == k2


def test_identity_key_differs_when_capture_changes():
    def make(th):
        return lambda c: c["d0"] > th

    th_a, th_b = np.float32(1.0), np.float32(2.0)
    k1 = PL.identity_key(PL.Select(PL.Scan(0), make(th_a)))
    k2 = PL.identity_key(PL.Select(PL.Scan(0), make(th_b)))
    k3 = PL.identity_key(PL.Select(PL.Scan(0), make(th_a)))
    assert k1 != k2      # different captured value: different executable
    assert k1 == k3      # same captured value: hit


_G_THRESH = 1.0


def test_identity_key_sees_global_rebinding():
    """A lambda reading a module-level global must MISS once the global is
    rebound — identical ids of ``__globals__`` are not enough (the stale-
    result hazard the content key exists to close)."""
    global _G_THRESH

    def make():
        return lambda c: c["d0"] > _G_THRESH

    k1 = PL.identity_key(PL.Select(PL.Scan(0), make()))
    _G_THRESH = 2.0
    try:
        k2 = PL.identity_key(PL.Select(PL.Scan(0), make()))
    finally:
        _G_THRESH = 1.0
    k3 = PL.identity_key(PL.Select(PL.Scan(0), make()))
    assert k1 != k2      # rebound global: recompile with the new value
    assert k1 == k3      # restored: hit again


def test_identity_key_distinguishes_kwonly_defaults():
    """Factory-made predicates sharing one code object but differing only
    in kw-only defaults must not collide."""
    def make(t):
        return lambda c, *, _t=t: c["d0"] > _t

    k1 = PL.identity_key(PL.Select(PL.Scan(0), make(np.float32(1.0))))
    k2 = PL.identity_key(PL.Select(PL.Scan(0), make(np.float32(2.0))))
    k3 = PL.identity_key(PL.Select(PL.Scan(0), make(np.float32(1.0))))
    assert k1 != k2 and k1 == k3


def test_identity_key_rejects_unhashable_capture():
    """Mutable-in-place values (ndarray, list) cannot be content-keyed:
    the plan stays uncached and re-traces per dispatch (always correct)."""
    arr = np.zeros(4, np.float32)
    assert PL.identity_key(
        PL.Select(PL.Scan(0), lambda c: c["d0"] > arr[0])) is None
    lst = [0.0]
    assert PL.identity_key(
        PL.Select(PL.Scan(0), lambda c: c["d0"] > lst[0])) is None


def test_identity_key_rejects_opaque_callable():
    class Pred:
        def __call__(self, c):
            return c["d0"] > 0

    assert PL.identity_key(PL.Select(PL.Scan(0), Pred())) is None


# --- LRU admission / eviction -------------------------------------------------


def test_lru_evicts_least_recently_used_first():
    c = PlanCache(max_entries=3)
    for k in "abc":
        c.put(k, k.upper())
    assert c.get("a") == "A"      # refresh 'a': 'b' is now LRU
    c.put("d", "D")               # evicts 'b'
    assert "b" not in c and "a" in c and "c" in c and "d" in c
    assert c.evictions == 1
    # recompile accounting: a miss on the evicted key counts
    assert c.get("b") is None
    assert c.recompiles == 1
    # a miss on a never-admitted key does NOT
    assert c.get("z") is None
    assert c.recompiles == 1


def test_weight_budget_evicts_until_under():
    c = PlanCache(max_entries=100, max_weight=10)
    c.put("a", 1, weight=4)
    c.put("b", 2, weight=4)
    c.put("c", 3, weight=4)       # 12 > 10: evicts 'a'
    assert "a" not in c and c.weight == 8
    c.put("big", 4, weight=40)    # over budget alone: keeps only itself
    assert list(c.keys()) == ["big"]


def test_put_replaces_and_stats_snapshot():
    c = PlanCache(max_entries=4)
    c.put("a", 1, weight=2)
    c.put("a", 2, weight=5)       # replace: weight updated, no growth
    assert len(c) == 1 and c.weight == 5 and c.get("a") == 2
    s = c.stats()
    assert s == {"entries": 1, "weight": 5, "hits": 1, "misses": 0,
                 "evictions": 0, "recompiles": 0}


def test_clear_resets_recompile_accounting():
    c = PlanCache()
    c.put("a", 1)
    c.clear()
    assert len(c) == 0 and c.evictions == 1
    # a fresh cache starts with fresh accounting: no phantom recompile
    assert c.get("a") is None
    assert c.recompiles == 0 and c.misses == 1


def test_guard_death_invalidates_entry():
    class Guard:
        pass

    c = PlanCache()
    g = Guard()
    c.put("k", "V", guards=(g,))
    assert c.get("k") == "V"
    # the cache pins the guard: external deletion alone cannot kill it
    # while resident — simulate decay by dropping our ref AND the pin
    entry_guards = c._entries["k"].guards
    assert g in entry_guards
    del g, entry_guards
    c._entries["k"].guards = ()   # release the pin
    import gc

    gc.collect()
    assert "k" not in c           # weakref callback invalidated the entry


# --- context integration ------------------------------------------------------


def _ctx_tables():
    ctx = DistContext()
    rng = np.random.default_rng(3)
    t = Table.from_arrays({
        "k": rng.integers(0, 16, 128).astype(np.int32),
        "d0": rng.integers(-9, 9, 128).astype(np.float32)})
    return ctx, ctx.scatter(t)


def test_collect_uses_shared_plan_cache():
    ctx, dt = _ctx_tables()
    aggs = (("d0", "sum"),)
    ctx.frame(dt).groupby("k", aggs).collect()
    misses = ctx.cache_stats()["misses"]
    ctx.frame(dt).groupby("k", aggs).collect()   # fresh frame, same shape
    s = ctx.cache_stats()
    assert s["misses"] == misses and s["hits"] >= 1


def test_keyless_lambda_cached_by_identity():
    """The PR's perf fix: a keyless Select no longer re-jits per collect."""
    ctx, dt = _ctx_tables()

    def q():
        return ctx.frame(dt).select(lambda c: c["d0"] > 0.0)

    q().collect()
    misses = ctx.cache_stats()["misses"]
    out = q().collect()                         # re-created lambda: hit
    s = ctx.cache_stats()
    assert s["misses"] == misses, s
    assert int(out.global_rows()) > 0


_SERVE_THRESH = 0.0


def test_keyless_lambda_global_rebinding_stays_correct():
    """Rebinding a module global a cached keyless predicate reads must not
    serve stale results — the high-severity hazard of id-based keys."""
    global _SERVE_THRESH
    ctx, dt = _ctx_tables()

    def q():
        return ctx.frame(dt).select(lambda c: c["d0"] > _SERVE_THRESH)

    a = q().collect()
    a2 = q().collect()               # unchanged global: cache-hit, same rows
    _SERVE_THRESH = 5.0
    try:
        b = q().collect()
    finally:
        _SERVE_THRESH = 0.0
    assert int(a.global_rows()) == int(a2.global_rows())
    assert int(b.global_rows()) < int(a.global_rows())  # new value honored


def test_keyless_unhashable_capture_runs_uncached_and_fresh():
    """An ndarray capture cannot be content-keyed: every collect re-traces
    (no cache entry) and in-place mutation is therefore always visible."""
    ctx, dt = _ctx_tables()
    th = np.zeros((), np.float32)

    def q():
        return ctx.frame(dt).select(lambda c: c["d0"] > th)

    entries_before = ctx.cache_stats()["entries"]
    a = q().collect()
    assert ctx.cache_stats()["entries"] == entries_before  # never admitted
    th += 5.0                        # in-place mutation, same object id
    b = q().collect()
    assert int(b.global_rows()) < int(a.global_rows())


def test_safe_capacity_entries_use_distinct_keys():
    """One logical plan, two executables: the sized first pass and the
    safe-capacity retry must never collide in the cache."""
    ctx = DistContext()
    p = ctx.num_shards
    n = 256
    t = Table.from_arrays({
        "k": np.zeros(n, np.int32),
        "d0": np.arange(n, dtype=np.float32)})
    dt = ctx.analyze(ctx.scatter(t))
    out, _ = ctx.partition_by(dt, "k")
    namespaces = {k[0][0] for k in ctx.plan_cache.keys()}
    if ctx.overflow_retries:     # estimates failed: both variants resident
        assert "plan-safe" in namespaces, namespaces
    assert "plan" in namespaces, namespaces
    got = out.to_table().to_numpy()
    assert np.array_equal(np.sort(got["d0"]), np.arange(n, dtype=np.float32))


def test_interleaved_collect_async_bit_identical_to_sequential():
    """N interleaved async clients == sequential collects, per query."""
    ctx, dt = _ctx_tables()
    sess = ServingSession(ctx)
    sess.register("t", dt, analyze=True)
    workload = [
        ("gb", lambda s: s.frame("t").groupby("k", (("d0", "sum"),))),
        ("topn", lambda s: s.frame("t").sort("k").limit(8)),
        ("sel", lambda s: s.frame("t").select(lambda c: c["d0"] > 0.0)
            .groupby("k", (("d0", "mean"),))),
    ]
    seq_rep, seq = sess.run_open_loop(workload, num_clients=2,
                                      queries_per_client=3,
                                      mode="sequential")
    asy_rep, asy = sess.run_open_loop(workload, num_clients=2,
                                      queries_per_client=3, mode="async")
    assert seq_rep.shapes == asy_rep.shapes
    assert all(tables_bitwise_equal(a.to_table(), b.to_table())
               for a, b in zip(asy, seq))
    assert asy_rep.compiles == 0 and asy_rep.recompiles == 0
    assert len(asy) == seq_rep.num_queries == 6


def test_future_resolves_once_and_drain():
    ctx, dt = _ctx_tables()
    fut = ctx.frame(ctx.analyze(dt)).groupby(
        "k", (("d0", "sum"),)).collect_async()
    out1 = fut.result()
    assert fut.done
    assert fut.result() is out1      # idempotent, no re-execution
    # drain() clears any pending deferred verifications
    ctx.frame(ctx.analyze(dt)).sort("k").collect_async()
    ctx.drain()
    assert ctx._pending == []


def test_run_open_loop_rejects_bad_mode():
    ctx, dt = _ctx_tables()
    sess = ServingSession(ctx)
    sess.register("t", dt)
    with pytest.raises(AssertionError):
        sess.run_open_loop([("q", lambda s: s.frame("t").sort("k"))],
                           mode="threaded")
