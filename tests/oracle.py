"""NumPy relational-algebra oracle for the property tests.

Plain-Python row semantics — the ground truth the JAX operators must match
(same contract Cylon verifies against Spark output counts, §IV-A).
"""
from __future__ import annotations

import numpy as np


def rows(table_dict):
    names = sorted(table_dict)
    cols = [np.asarray(table_dict[n]) for n in names]
    return names, list(zip(*[c.tolist() for c in cols])) if names else []


def select_oracle(table, pred):
    names, rs = rows(table)
    out = [r for r in rs if pred(dict(zip(names, r)))]
    return sorted(out)


def distinct_oracle(table):
    _, rs = rows(table)
    return sorted(set(rs))


def union_oracle(a, b):
    _, ra = rows(a)
    _, rb = rows(b)
    return sorted(set(ra) | set(rb))


def intersect_oracle(a, b):
    _, ra = rows(a)
    _, rb = rows(b)
    return sorted(set(ra) & set(rb))


def difference_oracle(a, b, mode="symmetric"):
    _, ra = rows(a)
    _, rb = rows(b)
    if mode == "symmetric":
        return sorted(set(ra) ^ set(rb))
    return sorted(set(ra) - set(rb))


def join_oracle(left, right, on, how="inner", suffix="_r"):
    """Returns sorted list of joined row tuples, columns sorted by name."""
    lnames = sorted(left)
    rnames = sorted(right)
    out_names = lnames + [n + suffix if n in left else n
                          for n in rnames if n not in on or True]
    # build output column order: left cols + right cols (renamed on clash)
    rmap = {n: (n + suffix if n in left else n) for n in rnames}
    all_names = sorted(lnames + [rmap[n] for n in rnames])

    lrows = list(zip(*[np.asarray(left[n]).tolist() for n in lnames])) \
        if lnames else []
    rrows = list(zip(*[np.asarray(right[n]).tolist() for n in rnames])) \
        if rnames else []
    lkey = [tuple(r[lnames.index(k)] for k in on) for r in lrows]
    rkey = [tuple(r[rnames.index(k)] for k in on) for r in rrows]

    out = []
    l_matched = [False] * len(lrows)
    r_matched = [False] * len(rrows)
    for i, lr in enumerate(lrows):
        for j, rr in enumerate(rrows):
            if lkey[i] == rkey[j]:
                l_matched[i] = r_matched[j] = True
                d = dict(zip(lnames, lr))
                d.update({rmap[n]: v for n, v in zip(rnames, rr)})
                out.append(tuple(d[n] for n in all_names))
    if how in ("left", "full"):
        for i, lr in enumerate(lrows):
            if not l_matched[i]:
                d = {n: 0 for n in all_names}
                d.update(dict(zip(lnames, lr)))
                out.append(tuple(d[n] for n in all_names))
    if how in ("right", "full"):
        for j, rr in enumerate(rrows):
            if not r_matched[j]:
                d = {n: 0 for n in all_names}
                d.update({rmap[n]: v for n, v in zip(rnames, rr)})
                out.append(tuple(d[n] for n in all_names))
    return all_names, sorted(out)


def groupby_oracle(table, keys, aggs):
    """Keyed-aggregation ground truth, plain-Python row semantics.

    table: dict col -> np.ndarray (N-D payloads allowed); keys: list of 1-D
    key column names; aggs: list of (col, op) with op in repro's AGG_OPS.
    Returns dict col -> np.ndarray with one row per group, rows sorted by
    key tuple (the order repro's sort-based groupby emits). mean/var are
    float64 (compare with allclose); 'first' is first occurrence in input
    row order; var is the population variance.
    """
    n = len(np.asarray(table[keys[0]]))
    key_cols = [np.asarray(table[k]) for k in keys]
    order = {}
    members: dict[tuple, list[int]] = {}
    for i in range(n):
        kt = tuple(c[i].item() for c in key_cols)
        members.setdefault(kt, []).append(i)
    out_keys = sorted(members)
    out: dict[str, list] = {k: [] for k in keys}
    for col, op in aggs:
        out[f"{col}_{op}"] = []
    for kt in out_keys:
        idx = members[kt]
        for k, v in zip(keys, kt):
            out[k].append(v)
        for col, op in aggs:
            g = np.asarray(table[col])[idx]
            if op == "sum":
                r = g.sum(axis=0)
            elif op == "count":
                r = len(idx)
            elif op == "min":
                r = g.min(axis=0)
            elif op == "max":
                r = g.max(axis=0)
            elif op == "mean":
                r = g.astype(np.float64).mean(axis=0)
            elif op == "var":
                r = g.astype(np.float64).var(axis=0)
            elif op == "first":
                r = g[0]
            else:
                raise ValueError(op)
            out[f"{col}_{op}"].append(r)
    return {k: np.asarray(v) for k, v in out.items()}


def window_oracle(table, by, order_by, funcs):
    """Window-function ground truth, plain-Python row semantics.

    table: dict col -> 1-D np.ndarray; by/order_by: lists of column names;
    funcs: normalized [(fn, col, offset), ...] (ops_agg.normalize_funcs).
    Returns dict col -> np.ndarray holding the input rows STABLY sorted by
    (by + order_by) — the order repro's window emits — plus one result
    column per function (ops_agg.window_output_name). rank/dense_rank tie
    on the full (by + order_by) tuple; lag/lead fill 0 outside the group;
    running_mean is float32 of the float32 running sum (matching the JAX
    arithmetic bit-for-bit on integer-valued inputs).
    """
    names = sorted(table)
    n = len(np.asarray(table[names[0]])) if names else 0
    keys = lambda i: tuple(np.asarray(table[k])[i].item()
                           for k in by + order_by)
    order = sorted(range(n), key=lambda i: (keys(i), i))  # stable
    out = {k: np.asarray(table[k])[order] for k in names}

    groups: dict[tuple, list[int]] = {}
    for pos, i in enumerate(order):
        gk = tuple(np.asarray(table[k])[i].item() for k in by)
        groups.setdefault(gk, []).append(pos)

    from repro.core.ops_agg import window_output_name

    res: dict[str, list] = {}
    for fn, col, off in funcs:
        res[window_output_name(fn, col, off)] = np.zeros(
            (n,), np.int32 if col is None else (
                np.float32 if fn == "running_mean"
                else out[col].dtype))
    for gk, members in groups.items():  # members: positions, sorted order
        ordv = [tuple(out[k][p].item() for k in order_by) for p in members]
        for j, p in enumerate(members):
            for fn, col, off in funcs:
                name = window_output_name(fn, col, off)
                if fn == "row_number":
                    res[name][p] = j + 1
                elif fn == "rank":
                    res[name][p] = ordv.index(ordv[j]) + 1
                elif fn == "dense_rank":
                    res[name][p] = len(set(ordv[: j + 1]))
                elif fn == "lag":
                    res[name][p] = out[col][members[j - off]] \
                        if j - off >= 0 else 0
                elif fn == "lead":
                    res[name][p] = out[col][members[j + off]] \
                        if j + off < len(members) else 0
                elif fn == "cumsum":
                    res[name][p] = out[col][members[: j + 1]].sum()
                elif fn == "cummax":
                    res[name][p] = out[col][members[: j + 1]].max()
                elif fn == "running_mean":
                    s = np.float32(0)
                    for q in members[: j + 1]:
                        s = np.float32(s + np.float32(out[col][q]))
                    res[name][p] = s / np.float32(j + 1)
    return {**out, **{k: np.asarray(v) for k, v in res.items()}}


def table_rows_sorted(t):
    """Valid rows of a repro Table as sorted tuples (cols sorted by name)."""
    d = t.to_numpy()
    names = sorted(d)
    return sorted(zip(*[d[n].tolist() for n in names])) if names else []
