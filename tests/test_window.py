"""Local window functions vs the plain-Python oracle (tests/oracle.py).

Example-based sweeps (no hypothesis — part of the minimal-env tier-1
gate): every function, multi-column partition keys, tie handling, offset
lags, empty/degenerate tables, and the kernel-vs-oracle scan routing.
Integer-valued float payloads keep sums exact, so every comparison is
bit-for-bit.
"""
import numpy as np
import pytest

from oracle import window_oracle
from repro.core import ops_agg as A
from repro.core.table import Table

RNG = np.random.default_rng(42)

ALL_FUNCS = ["rank", "dense_rank", "row_number",
             ("lag", "d0"), ("lead", "d0"), ("lag", "d1", 3),
             ("lead", "d1", 2), ("cumsum", "d0"), ("cumsum", "d1"),
             ("cummax", "d1"), ("running_mean", "d0")]


def _table(n, key_range, order_range=None, seed=0):
    rng = np.random.default_rng(seed)
    order = (rng.permutation(n).astype(np.int32) if order_range is None
             else rng.integers(0, order_range, n).astype(np.int32))
    return {"k": rng.integers(0, key_range, n).astype(np.int32),
            "o": order,
            "d0": rng.integers(-30, 30, n).astype(np.float32),
            "d1": rng.integers(-9, 9, n).astype(np.int32)}


def _check(cols, by, order_by, funcs):
    pairs = A.normalize_funcs(funcs)
    got = A.window(Table.from_arrays(cols), by, funcs,
                   order_by=order_by).to_numpy()
    want = window_oracle(cols, [by] if isinstance(by, str) else list(by),
                        [order_by] if isinstance(order_by, str)
                        else list(order_by), pairs)
    assert set(got) == set(want)
    for name in want:
        np.testing.assert_array_equal(got[name], want[name], err_msg=name)


@pytest.mark.parametrize("n,key_range", [(1, 1), (7, 3), (200, 10),
                                         (500, 1), (300, 300)])
def test_window_all_funcs_unique_order(n, key_range):
    _check(_table(n, key_range, seed=n), "k", "o", ALL_FUNCS)


def test_window_ties_share_rank():
    # repeated (k, o) tuples: rank/dense_rank tie on the full tuple, and
    # the stable sort keeps cumsum/lag deterministic vs the oracle
    cols = _table(300, 4, order_range=5, seed=9)
    _check(cols, "k", "o", ALL_FUNCS)


def test_window_multikey_no_order():
    rng = np.random.default_rng(3)
    cols = {"a": rng.integers(0, 4, 250).astype(np.int32),
            "b": rng.integers(0, 3, 250).astype(np.int32),
            "d0": rng.integers(-20, 20, 250).astype(np.float32),
            "d1": rng.integers(-5, 5, 250).astype(np.int32)}
    funcs = ["rank", "dense_rank", "row_number", ("cumsum", "d0"),
             ("lag", "d1")]
    pairs = A.normalize_funcs(funcs)
    got = A.window(Table.from_arrays(cols), ["a", "b"], funcs).to_numpy()
    want = window_oracle(cols, ["a", "b"], [], pairs)
    for name in want:
        np.testing.assert_array_equal(got[name], want[name], err_msg=name)
    # with no order columns every group is one value run
    assert (got["rank"] == 1).all()
    assert (got["dense_rank"] == 1).all()


def test_window_empty_and_capacity_padding():
    empty = Table.from_arrays({"k": np.zeros(0, np.int32),
                               "d0": np.zeros(0, np.float32)})
    out = A.window(empty, "k", [("cumsum", "d0"), "rank"])
    assert int(out.row_count) == 0
    # padded capacity: invalid rows must not leak into any output
    cols = _table(40, 3, seed=1)
    t = Table.from_arrays(cols, capacity=128)
    got = A.window(t, "k", ALL_FUNCS, order_by="o").to_numpy()
    want = window_oracle(cols, ["k"], ["o"], A.normalize_funcs(ALL_FUNCS))
    for name in want:
        np.testing.assert_array_equal(got[name], want[name], err_msg=name)


def test_window_kernel_and_oracle_paths_agree():
    cols = _table(400, 6, seed=8)
    t = Table.from_arrays(cols)
    funcs = ["rank", "dense_rank", ("cumsum", "d0"), ("cummax", "d1"),
             ("running_mean", "d0")]
    a = A.window(t, "k", funcs, order_by="o", use_kernel=True).to_numpy()
    b = A.window(t, "k", funcs, order_by="o", use_kernel=False).to_numpy()
    for name in a:
        np.testing.assert_array_equal(a[name], b[name], err_msg=name)


def test_normalize_funcs_canonical_and_validating():
    pairs = A.normalize_funcs(["rank", ("lag", "d0"), ("lag", "d0", 2),
                               ("cumsum", "d0")])
    assert pairs == (("rank", None, 0), ("lag", "d0", 1), ("lag", "d0", 2),
                     ("cumsum", "d0", 0))
    assert A.window_output_name("lag", "d0", 1) == "d0_lag"
    assert A.window_output_name("lag", "d0", 2) == "d0_lag2"
    assert A.window_output_name("rank", None) == "rank"
    with pytest.raises(AssertionError):
        A.normalize_funcs(["median"])  # not a window function
    with pytest.raises(AssertionError):
        A.normalize_funcs([("rank", "d0")])  # rank takes no column
    with pytest.raises(AssertionError):
        A.normalize_funcs([("cumsum", None)])  # cumsum needs a column
    with pytest.raises(AssertionError):
        A.normalize_funcs([("lag", "d0", -1)])  # bad offset


def test_window_output_collision_rejected():
    t = Table.from_arrays({"k": np.zeros(4, np.int32),
                           "rank": np.zeros(4, np.float32)})
    with pytest.raises(AssertionError):
        A.window(t, "k", ["rank"])


def test_window_scan_funcs_reject_unsupported_dtype():
    t = Table.from_arrays({"k": np.zeros(4, np.int32),
                           "u": np.zeros(4, np.uint32)})
    with pytest.raises(AssertionError):
        A.window(t, "k", [("cumsum", "u")])
    # lag/lead are gathers: any 1-D dtype is fine
    out = A.window(t, "k", [("lag", "u")]).to_numpy()
    assert out["u_lag"].dtype == np.uint32
