"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
contract), plus a gradient-flow check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_tiny
from repro.models.factory import build_model

B, S = 2, 24


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "weight": jnp.ones((B,), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = get_tiny(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)

    # forward: logits shape + finite
    logits, _, aux = model.forward(params, tokens=batch["tokens"],
                                   embeds=batch.get("embeds"), mode="causal",
                                   cache=None, pos=None)
    s_total = S + (cfg.num_frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one train step: loss finite, grads finite and nonzero somewhere
    loss, metrics = model.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    sq = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(sq) and sq > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_smoke(arch):
    cfg = get_tiny(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 16, 16)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, 1)), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok,
                                       jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (the 10 x config table)."""
    expect = {
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        got_ff = cfg.moe_d_ff if cfg.moe_num_experts else cfg.d_ff
        assert got_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # family-specific details
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("qwen2-moe-a2.7b").moe_num_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe_top_k == 4
    assert get_config("dbrx-132b").moe_num_experts == 16
    assert get_config("minicpm3-4b").attn_kind == "mla"
