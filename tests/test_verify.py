"""Plan verifier: every rule has clean-plan and broken-plan coverage.

Static rules run offline (pure plan-to-plan, explicit num_shards=8 — no
mesh needed), mirroring test_plan's golden style: real optimizer output
must come back with zero findings, and a hand-mutated violation of each
registered rule must be caught. The fuzzer's generator is checked for
seed-determinism, and the wired-in surfaces (optimize() raising under
``REPRO_VERIFY_PLANS``, ``explain(verify=True)``, ``cache_stats``
counters) are exercised on the single-device context.

Deliberately hypothesis-free: part of the minimal-environment tier-1 gate.
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as PL
from repro.core import verify as V
from repro.core.context import DistContext
from repro.core.repartition import Partitioning, RangePartitioning
from repro.core.table import Table

I32, F32 = jnp.dtype(jnp.int32), jnp.dtype(jnp.float32)

ORDERS = {"k": jax.ShapeDtypeStruct((), I32),
          "o": jax.ShapeDtypeStruct((), I32),
          "d0": jax.ShapeDtypeStruct((), F32)}
USERS = {"k": jax.ShapeDtypeStruct((), I32),
         "v0": jax.ShapeDtypeStruct((), F32)}

P8 = 8


def check(logical, schemas=(ORDERS,), p=P8, stats=None):
    """Optimize + verify; returns (optimized, findings)."""
    opt = PL.optimize(logical, list(schemas), p, stats, verify=False)
    return opt, V.verify_plan(logical, opt, list(schemas), p, stats)


def rules_of(findings):
    return {f.rule for f in findings}


def replace_first(plan, cls, **changes):
    """dataclasses.replace on the first (preorder) node of type ``cls``."""
    done = [False]

    def walk(node):
        if not done[0] and isinstance(node, cls):
            done[0] = True
            return dataclasses.replace(node, **changes)
        kids = PL.children(node)
        if not kids:
            return node
        return PL._with_children(node, tuple(walk(c) for c in kids))

    out = walk(plan)
    assert done[0], f"no {cls.__name__} in plan"
    return out


# --- clean plans: real optimizer output has zero findings --------------------


def test_clean_join_groupby_chain():
    plan = PL.GroupBy(
        PL.Select(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                  lambda c: c["d0"] > 0.0, key="pos"),
        ("k",), (("d0", "sum"), ("d0", "count")), strategy="shuffle")
    _, findings = check(plan, (ORDERS, USERS))
    assert findings == [], [str(f) for f in findings]


def test_clean_sort_join_window_chain():
    funcs = (("rank", None, 0), ("cumsum", "d0", 0))
    plan = PL.Limit(
        PL.Window(PL.Sort(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                          ("k", "o")),
                  ("k",), ("o",), funcs), 9)
    _, findings = check(plan, (ORDERS, USERS))
    assert findings == [], [str(f) for f in findings]


def test_clean_setop_distinct_repartition():
    plan = PL.Distinct(PL.Union(
        PL.Repartition(PL.Scan(0), ("k",), stages=2), PL.Scan(1)))
    _, findings = check(plan, (ORDERS, ORDERS))
    assert findings == [], [str(f) for f in findings]


def test_clean_under_partitioned_scan():
    # a pre-partitioned input justifies the elision the optimizer takes
    tag = Partitioning(("k",), P8, 7)
    plan = PL.GroupBy(PL.Scan(0, partitioning=tag), ("k",),
                      (("d0", "sum"),))
    opt, findings = check(plan)
    assert opt.skip_shuffle  # the elision actually fired...
    assert findings == []    # ...and the verifier agrees it is justified


# --- rule 1: schema preservation ---------------------------------------------


def test_schema_rule_catches_dropped_column():
    logical = PL.Sort(PL.Scan(0), ("k",))
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    broken = PL.Project(opt, ("k", "o"))  # optimizer "lost" d0
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "schema" in rules_of(findings), [str(f) for f in findings]


def test_schema_rule_catches_column_reorder():
    logical = PL.Sort(PL.Scan(0), ("k",))
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    broken = PL.Project(opt, ("d0", "o", "k"))  # same set, wrong order
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "schema" in rules_of(findings), [str(f) for f in findings]


# --- rule 2: partitioning soundness ------------------------------------------


def test_partitioning_rule_catches_unjustified_groupby_skip():
    logical = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),))
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    assert not opt.skip_shuffle  # unpartitioned input: shuffle required
    broken = replace_first(opt, PL.GroupBy, skip_shuffle=True)
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "partitioning" in rules_of(findings), [str(f) for f in findings]


def test_partitioning_rule_catches_unjustified_join_skip():
    logical = PL.Join(PL.Scan(0), PL.Scan(1), ("k",))
    opt = PL.optimize(logical, [ORDERS, USERS], P8, verify=False)
    broken = replace_first(opt, PL.Join, skip_left_shuffle=True)
    findings = V.verify_plan(logical, broken, [ORDERS, USERS], P8)
    assert "partitioning" in rules_of(findings), [str(f) for f in findings]


def test_partitioning_rule_rejects_forged_range_fingerprint():
    # Scan tags are INPUT facts. A hand-mutated "optimized" plan whose
    # Scans claim a range fingerprint the logical plan's inputs never
    # carried would falsely authorize a ZERO-shuffle range-range join —
    # silently wrong rows. The forged-provenance check must reject it.
    logical = PL.Join(PL.Scan(0), PL.Scan(1), ("k",))
    forged = RangePartitioning(("k",), P8, ("table", 7))
    tagged = PL.Join(PL.Scan(0, partitioning=forged),
                     PL.Scan(1, partitioning=forged), ("k",))
    broken = PL.optimize(tagged, [ORDERS, USERS], P8, verify=False)
    assert broken.skip_left_shuffle and broken.skip_right_shuffle
    findings = V.verify_plan(logical, broken, [ORDERS, USERS], P8)
    assert "partitioning" in rules_of(findings), [str(f) for f in findings]
    assert any("forged" in f.message for f in findings)


def test_partitioning_rule_allows_legitimate_self_join_fingerprint():
    # The SAME materialized table scanned in two slots legitimately
    # shares one fingerprint (tokens are unique per table): the skip-both
    # range-range join is exactly the fast path, not a forgery.
    part = RangePartitioning(("k",), P8, ("table", 7))
    logical = PL.Join(PL.Scan(0, partitioning=part),
                      PL.Scan(1, partitioning=part), ("k",))
    opt, findings = check(logical, (ORDERS, USERS))
    assert opt.skip_left_shuffle and opt.skip_right_shuffle
    assert findings == [], [str(f) for f in findings]


def test_partitioning_rule_catches_wrong_seed_elision():
    tag = Partitioning(("k",), P8, seed=99)  # partitioned under seed 99
    logical = PL.Repartition(PL.Scan(0, partitioning=tag), ("k",), seed=7)
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    assert not opt.skip_shuffle  # seed mismatch: must re-shuffle
    broken = replace_first(opt, PL.Repartition, skip_shuffle=True)
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "partitioning" in rules_of(findings)


# --- rule 3: pushdown legality -----------------------------------------------


def test_pushdown_rule_catches_select_below_window():
    funcs = (("rank", None, 0),)
    pred = lambda c: c["rank"] <= 3
    logical = PL.Select(PL.Window(PL.Scan(0), ("k",), ("o",), funcs),
                        pred, key="top3", columns=("rank",))
    # hand-push the select BELOW the window whose output it probes
    broken = PL.Window(PL.Select(PL.Scan(0), pred, key="top3",
                                 columns=("rank",)),
                       ("k",), ("o",), funcs)
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "pushdown" in rules_of(findings), [str(f) for f in findings]


def test_pushdown_rule_catches_projection_dropping_probed_column():
    pred = lambda c: c["d0"] > 0.0
    logical = PL.Select(PL.Scan(0), pred, key="pos", columns=("d0",))
    broken = PL.Select(PL.Project(PL.Scan(0), ("k",)), pred, key="pos",
                       columns=("d0",))
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "pushdown" in rules_of(findings), [str(f) for f in findings]


def test_pushdown_rule_catches_limit_crossing_sort():
    logical = PL.Limit(PL.Sort(PL.Scan(0), ("k",)), 5)  # global top-5
    broken = PL.Sort(PL.Limit(PL.Scan(0), 5), ("k",))   # head-5, sorted
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "pushdown" in rules_of(findings), [str(f) for f in findings]


def test_pushdown_rule_allows_limit_project_swap():
    # Project is the one node a Limit may legally cross
    logical = PL.Limit(PL.Project(PL.Scan(0), ("k", "d0")), 5)
    _, findings = check(logical)
    assert findings == [], [str(f) for f in findings]


# --- rule 4: cost-sizing consistency -----------------------------------------


def test_cost_sizing_rule_catches_sized_without_stats():
    logical = PL.Sort(PL.Scan(0), ("k",))
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    broken = replace_first(opt, PL.Sort, sized=True)  # no stats given
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "cost-sizing" in rules_of(findings), [str(f) for f in findings]


def test_cost_sizing_rule_catches_bad_stage_counts():
    logical = PL.Repartition(PL.Scan(0), ("k",), bucket_capacity=256)
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    for bad in (0, -1, 99):
        broken = replace_first(opt, PL.Repartition, stages=bad)
        findings = V.verify_plan(logical, broken, [ORDERS], P8)
        assert "cost-sizing" in rules_of(findings), (bad, findings)


def test_cost_sizing_rule_catches_stages_above_bucket():
    logical = PL.Repartition(PL.Scan(0), ("k",), bucket_capacity=2)
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    broken = replace_first(opt, PL.Repartition, stages=3)
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "cost-sizing" in rules_of(findings), [str(f) for f in findings]


def test_cost_sizing_rule_catches_unresolved_auto_strategy():
    logical = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),),
                         strategy="auto")
    opt = PL.optimize(logical, [ORDERS], P8, verify=False)
    assert opt.strategy != "auto"  # the optimizer resolves it...
    broken = replace_first(opt, PL.GroupBy, strategy="auto")
    findings = V.verify_plan(logical, broken, [ORDERS], P8)
    assert "cost-sizing" in rules_of(findings)


# --- rule 5: idempotence + cache-key stability -------------------------------


def test_idempotence_rule_catches_unoptimized_plan():
    logical = PL.Select(PL.Sort(PL.Scan(0), ("k",)),
                        lambda c: c["d0"] > 0.0, key="pos")
    # claim the LOGICAL tree is the optimizer's output: re-optimizing
    # moves the select below the sort, so the fixed point fails
    findings = V.verify_plan(logical, logical, [ORDERS], P8)
    assert "idempotence" in rules_of(findings), [str(f) for f in findings]


def test_optimizer_is_idempotent_on_representative_plans():
    plans = [
        PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)), ("k",),
                   (("d0", "sum"),)),
        PL.Limit(PL.Sort(PL.Select(PL.Scan(0), lambda c: c["d0"] > 0.0,
                                   key="pos"), ("k",)), 7),
        PL.Window(PL.Sort(PL.Scan(0), ("k", "o")), ("k",), ("o",),
                  (("rank", None, 0),)),
    ]
    for plan in plans:
        opt = PL.optimize(plan, [ORDERS, USERS], P8, verify=False)
        re_opt = PL.optimize(opt, [ORDERS, USERS], P8, verify=False)
        assert re_opt == opt
        assert PL.canonical_key(re_opt) == PL.canonical_key(opt)


# --- totality: the verifier reports on garbage, it never crashes -------------


def test_verifier_is_total_on_garbage_plans():
    logical = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),))
    garbage = PL.GroupBy(PL.Scan(5), ("nope",), (("gone", "sum"),))
    findings = V.verify_plan(logical, garbage, [ORDERS], P8)
    assert findings  # reported, not raised


def test_verify_or_raise_carries_findings():
    logical = PL.Sort(PL.Scan(0), ("k",))
    broken = PL.Project(PL.optimize(logical, [ORDERS], P8, verify=False),
                        ("k",))
    with pytest.raises(V.PlanVerificationError) as ei:
        V.verify_or_raise(logical, broken, [ORDERS], P8)
    assert ei.value.findings
    assert "schema" in str(ei.value)


# --- wiring: env gate, explain, counters -------------------------------------


@pytest.fixture(scope="module")
def ctx():
    return DistContext(axis_name="verify_test")


def _small_frame(ctx):
    rng = np.random.default_rng(3)
    t = ctx.scatter(Table.from_arrays({
        "k": rng.integers(0, 8, 64).astype(np.int32),
        "d0": rng.integers(-9, 9, 64).astype(np.float32)}))
    return ctx.frame(t)


def test_optimize_env_gate_runs_verifier(ctx, monkeypatch):
    monkeypatch.setenv(V.ENV_FLAG, "1")
    before = V.counter_snapshot()["verify_runs"]
    fr = _small_frame(ctx).groupby("k", (("d0", "sum"),))
    PL.optimize(fr.logical_plan(), [t.schema for t in fr._inputs],
                ctx.num_shards)
    assert V.counter_snapshot()["verify_runs"] > before
    monkeypatch.setenv(V.ENV_FLAG, "0")
    mid = V.counter_snapshot()["verify_runs"]
    PL.optimize(fr.logical_plan(), [t.schema for t in fr._inputs],
                ctx.num_shards)
    assert V.counter_snapshot()["verify_runs"] == mid  # gate off: no run


def test_cache_stats_carries_verifier_counters(ctx):
    stats = ctx.cache_stats()
    assert "verify_runs" in stats and "verify_findings" in stats


def test_explain_verify_reports_clean(ctx):
    fr = _small_frame(ctx).groupby("k", (("d0", "sum"),))
    text = fr.explain(verify=True)
    assert "verification: clean" in text


def test_collect_verified_end_to_end(ctx, monkeypatch):
    monkeypatch.setenv(V.ENV_FLAG, "1")
    before = V.counter_snapshot()
    fr = _small_frame(ctx).sort("k").limit(5)
    out = fr.collect().to_table().to_numpy()
    after = V.counter_snapshot()
    assert after["verify_runs"] > before["verify_runs"]
    assert after["verify_findings"] == before["verify_findings"]
    assert len(out["k"]) == 5


# --- fuzzer: seed determinism + a single-device end-to-end pass --------------


def test_fuzzer_is_seed_deterministic(ctx):
    from repro.testing import plan_fuzz

    inputs = plan_fuzz.make_inputs(ctx, 5, analyze=False)
    frames = [plan_fuzz.random_frame(ctx, inputs, random.Random("7:3"),
                                     max_ops=6) for _ in range(2)]
    assert frames[0].ops == frames[1].ops
    keys = [PL.canonical_key(PL.optimize(
        f.frame.logical_plan(), [t.schema for t in f.frame._inputs],
        ctx.num_shards, verify=False)) for f in frames]
    assert keys[0] == keys[1]


def test_fuzzer_passes_single_device(ctx):
    from repro.testing import plan_fuzz

    summary = plan_fuzz.run_fuzz(4, 77, max_ops=4, ctx=ctx)
    assert summary["plans"] == 4
    assert summary["verify"]["verify_runs"] > 0
