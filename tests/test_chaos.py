"""Fault injection + recovery-ladder tests.

Two layers:

* single-device unit tests of ``repro.core.faults`` (deterministic
  firing, the ``REPRO_FAULTS`` spec parser, retry/backoff math) and of
  the ``PlanFuture`` failure paths (exceptional resolution exactly once,
  no broken executable left in the plan cache);
* subprocess chaos cases on 8 host devices
  (``repro.testing.chaos_cases``): every injected fault class must
  recover through its documented ladder rung with results bit-identical
  to the fault-free oracle, and a ServingSession open loop must survive
  mid-workload failures with only the affected query impacted.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_case(case: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "repro.testing.chaos_cases", case],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"{case} failed:\n{out.stdout}\n{out.stderr}"
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")][-1]
    return json.loads(line[5:])


# --------------------------------------------------------------------------
# the fault registry (single device, no jax needed)
# --------------------------------------------------------------------------


def test_fault_plan_validation():
    from repro.core import faults as FLT

    with pytest.raises(ValueError):
        FLT.FaultPlan("no.such.site")
    with pytest.raises(ValueError):
        FLT.FaultRegistry([FLT.FaultPlan("compile"),
                           FLT.FaultPlan("compile")])  # duplicate site


def test_registry_nth_and_max_fires():
    from repro.core import faults as FLT

    reg = FLT.FaultRegistry([FLT.FaultPlan("compile", nth=2, max_fires=1)])
    with FLT.scope(reg):
        fires = [FLT.check("compile") is not None for _ in range(5)]
    assert fires == [False, True, False, False, False]
    assert reg.stats() == {"fault_calls": 5, "fault_fires": 1}
    assert reg.fires_by_site() == {"compile": 1}
    reg.reset()
    assert reg.stats() == {"fault_calls": 0, "fault_fires": 0}


def test_registry_probability_deterministic():
    from repro.core import faults as FLT

    def trace(seed):
        reg = FLT.FaultRegistry([FLT.FaultPlan(
            "kernel.dispatch", probability=0.5, seed=seed, max_fires=100)])
        with FLT.scope(reg):
            return [FLT.check("kernel.dispatch") is not None
                    for _ in range(32)]

    a, b, c = trace(7), trace(7), trace(8)
    assert a == b          # same seed -> same firing pattern
    assert a != c          # different seed -> different pattern
    assert any(a) and not all(a)


def test_check_unarmed_is_inert():
    from repro.core import faults as FLT

    assert FLT.current() is None
    assert FLT.check("compile") is None
    reg = FLT.FaultRegistry([])
    assert not reg.active
    with FLT.scope(reg):          # empty registry: scope not armed
        assert FLT.current() is None


def test_parse_spec_and_env(monkeypatch):
    from repro.core import faults as FLT

    plans = FLT.parse_spec(
        "shuffle.chunk:mode=raise,nth=3;compile:probability=0.25,seed=9")
    assert len(plans) == 2
    assert plans[0].site == "shuffle.chunk" and plans[0].nth == 3
    assert plans[1].probability == 0.25 and plans[1].seed == 9
    with pytest.raises(ValueError):
        FLT.parse_spec("compile:bogus_field=1")
    monkeypatch.setenv("REPRO_FAULTS", "kernel.dispatch:mode=nan")
    reg = FLT.from_env()
    assert reg is not None and reg.active
    assert reg.plan("kernel.dispatch").effective_mode == "nan"
    monkeypatch.delenv("REPRO_FAULTS")
    assert FLT.from_env() is None


def test_retry_policy_backoff():
    from repro.core import faults as FLT

    p = FLT.RetryPolicy(max_attempts=5, base_delay_s=0.1, backoff=2.0,
                        jitter=0.25, seed=3)
    d = [p.delay_s(a) for a in range(1, 5)]
    assert d == [p.delay_s(a) for a in range(1, 5)]  # deterministic
    # exponential envelope with ±25% jitter
    for i, (lo_exp) in enumerate(d):
        base = 0.1 * 2.0 ** i
        assert 0.75 * base <= d[i] <= 1.25 * base
    assert FLT.RetryPolicy().delay_s(3) == 0.0  # default: no sleeping


def test_rung_classification():
    from repro.core import faults as FLT

    assert FLT.rung_for(FLT.FaultError("kernel.dispatch")) \
        == FLT.ORACLE_KERNEL
    assert FLT.rung_for(FLT.FaultError("shuffle.chunk")) == FLT.MONO_SHUFFLE
    assert FLT.rung_for(FLT.FaultError("compile")) == "recompile"
    assert FLT.rung_for(RuntimeError("x")) == "retry"


# --------------------------------------------------------------------------
# PlanFuture failure paths (single device)
# --------------------------------------------------------------------------


def _mini():
    import jax.numpy as jnp
    import numpy as np
    from repro.core.context import DistContext
    from repro.core.table import Table

    ctx = DistContext()
    t = Table.from_arrays({
        "k": jnp.asarray(np.arange(32) % 5, jnp.int32),
        "v": jnp.asarray((np.arange(32) % 7).astype(np.float32))})
    return ctx, ctx.scatter(t)


def test_failed_future_resolves_exceptionally_once():
    from repro.core.context import PlanFuture

    boom = ValueError("nope")
    fut = PlanFuture.failed(boom)
    assert fut.done and fut.ready()
    with pytest.raises(ValueError):
        fut.result()
    with pytest.raises(ValueError):       # sticky: same error every time
        fut.result_with_stats()


def test_finalize_error_exactly_once_and_pending_cleanup():
    from repro.core.context import PlanFuture

    calls = []

    def finalize():
        calls.append(1)
        raise RuntimeError("finalize blew up")

    fut = PlanFuture(finalize)
    assert not fut.done
    with pytest.raises(RuntimeError):
        fut.result()
    with pytest.raises(RuntimeError):
        fut.result()
    assert calls == [1]                   # the closure ran exactly once
    assert fut.done


def test_dispatch_error_returns_failed_future_and_counts():
    from repro.core import plan as PL

    ctx, dt = _mini()

    def bad_predicate(cols):
        raise TypeError("user predicate bug")

    fut = ctx.submit(PL.Select(PL.Scan(0), bad_predicate, key=("bad",)),
                     [dt])
    assert fut.done                        # pre-failed, never dispatched
    with pytest.raises(TypeError):
        fut.result()
    assert ctx.cache_stats()["failed_queries"] == 1
    # the context is not poisoned: a good query still runs
    out, _ = ctx.groupby(dt, "k", (("v", "sum"),))
    assert int(out.global_rows()) == 5


def test_no_broken_executable_cached():
    """A trace that dies mid-compile must not leave a cache entry; the
    next submit of the same plan recompiles cleanly."""
    from repro.core import plan as PL

    ctx, dt = _mini()
    state = {"boom": True}

    def flaky(cols):
        if state["boom"]:
            raise RuntimeError("trace-time crash")
        return cols["v"] > 0.0

    plan = PL.Select(PL.Scan(0), flaky, key=("flaky",))
    entries0 = ctx.cache_stats()["entries"]
    with pytest.raises(RuntimeError):
        ctx.submit(plan, [dt]).result()
    assert ctx.cache_stats()["entries"] == entries0   # nothing admitted
    state["boom"] = False
    out = ctx.submit(plan, [dt]).result()
    assert int(out.global_rows()) > 0


def test_drain_collects_errors():
    from repro.core import plan as PL

    ctx, dt = _mini()

    def bad(cols):
        raise ValueError("late")

    ctx.submit(PL.Select(PL.Scan(0), bad, key=("late",)), [dt])
    good = ctx.submit(PL.Project(PL.Scan(0), ("k",)), [dt])
    # a pre-failed future never enters the pending list, so drain stays
    # clean; resolving it re-raises for its owner only
    errs = ctx.drain(raise_errors=False)
    assert errs == []
    assert int(good.result().global_rows()) == 32


# --------------------------------------------------------------------------
# validation + quarantine (single device)
# --------------------------------------------------------------------------


def test_validation_flags_nan(monkeypatch):
    import jax.numpy as jnp
    import numpy as np
    from repro.core.context import DistContext
    from repro.core.table import Table

    ctx = DistContext(validate=True)
    t = Table.from_arrays({
        "k": jnp.asarray(np.arange(8) % 2, jnp.int32),
        "v": jnp.asarray([1.0, np.nan, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
                         jnp.float32)})
    dt = ctx.scatter(t)
    problems = ctx._validate_result(dt, [], [dt])
    assert any("nan" in p.lower() for p in problems)


def test_env_spec_arms_context(monkeypatch):
    from repro.core.context import DistContext

    monkeypatch.setenv("REPRO_FAULTS", "compile:nth=1")
    ctx = DistContext()
    assert ctx.faults.active
    assert ctx.faults.plans[0].site == "compile"


# --------------------------------------------------------------------------
# 8-shard chaos cases (subprocess)
# --------------------------------------------------------------------------


def test_chaos_shuffle_recovery():
    r = run_case("shuffle_recovery")
    assert r["all_identical"], r
    for tag in ("staged", "ring"):
        assert r[f"{tag}_raise_degraded_shuffle"] >= 1, r
        assert r[f"{tag}_garble_quarantines"] >= 1, r
        assert r[f"{tag}_raise_failed"] == 0, r
        assert r[f"{tag}_garble_failed"] == 0, r


def test_chaos_kernel_recovery():
    r = run_case("kernel_recovery")
    assert r["raise_identical"] and r["raise_rung"] >= 1, r
    assert r["nan_identical"] and r["nan_rung"] >= 1, r
    assert r["persistent_identical"], r
    assert r["persistent_failed"] == 0, r


def test_chaos_stats_overflow_recovery():
    r = run_case("stats_overflow_recovery")
    assert r["identical"] and r["identical_second"], r
    assert r["overflow_retries"] == 1, r
    assert r["second_submit_retries"] == 0, r      # bad key remembered
    assert r["failed"] == 0, r


def test_chaos_cache_and_compile():
    r = run_case("cache_and_compile")
    for mode in ("miss", "evict"):
        assert r[f"{mode}_identical"], r
        assert r[f"{mode}_recompiles"] >= 1, r
        assert r[f"{mode}_failed"] == 0, r
    assert r["compile_identical"] and r["compile_retries"] >= 1, r
    assert r["compile_failed"] == 0, r


def test_chaos_serving_survival():
    r = run_case("serving_survival")
    assert r["fault_all_succeeded"], r
    assert r["fault_failed"] == 0 and r["fault_degraded"] >= 1, r
    assert r["fault_retries_bounded"], r
    assert r["boom_failed"] == 1, r       # the boom shape ran exactly once
    assert r["boom_failed_labels"] == ["boom"], r
    assert r["boom_succeeded"] == r["boom_queries"] - 1, r
    assert r["ref_failed"] == 0, r


def test_explain_recovery_annotations():
    import jax
    import jax.numpy as jnp
    from repro.core import plan as PL

    # plan-layer explain at p=8 (nothing executes), so the shuffle is
    # live and every rung shows
    plan = PL.GroupBy(PL.Scan(0), ("k",), (("v", "sum"),),
                      strategy="shuffle", bucket_capacity=64)
    schemas = [{"k": jax.ShapeDtypeStruct((64,), jnp.int32),
                "v": jax.ShapeDtypeStruct((64,), jnp.float32)}]
    physical = PL.apply_cost_model(plan, schemas, 8, None)
    plain = PL.explain(physical)
    annotated = PL.explain(physical, recovery=True)
    assert "recovery=" not in plain          # opt-in only: goldens stable
    assert "oracle-kernel" in annotated      # GroupBy has a kernel rung
    assert "mono-alltoall" in annotated      # live shuffle has a mono rung

    # a single-device session elides the shuffle: only the kernel rung
    ctx, dt = _mini()
    fr = ctx.frame(dt).groupby("k", (("v", "sum"),))
    assert "recovery=oracle-kernel" in fr.explain(recovery=True)
    assert "recovery=" not in fr.explain()
