"""Logical-plan layer: golden optimizer shapes + fused == eager execution.

Golden tests drive the optimizer passes offline (pure plan-to-plan, an
explicit num_shards — no mesh needed) and assert the rewrites actually
fire: projection/predicate pushdown below the shuffle boundaries, shuffle
elision from Partitioning tags. Execution tests run fused LazyFrame chains
on the single-device context and compare against the eager op-by-op result
(which keeps its shuffles — the two paths exercise different programs).

Deliberately hypothesis-free: part of the minimal-environment tier-1 gate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as PL
from repro.core.context import DistContext
from repro.core.repartition import Partitioning, RangePartitioning
from repro.core.table import Table

I32, F32 = jnp.dtype(jnp.int32), jnp.dtype(jnp.float32)

ORDERS = {"k": jax.ShapeDtypeStruct((), I32),
          "d0": jax.ShapeDtypeStruct((), F32),
          "d1": jax.ShapeDtypeStruct((), F32)}
USERS = {"k": jax.ShapeDtypeStruct((), I32),
         "d0": jax.ShapeDtypeStruct((), F32),
         "v0": jax.ShapeDtypeStruct((), F32)}


def find(node, cls):
    """All nodes of type `cls` in depth-first order."""
    out = [node] if isinstance(node, cls) else []
    for c in PL.children(node):
        out += find(c, cls)
    return out


# --- golden plan-shape tests --------------------------------------------------


def test_projection_pushdown_narrows_join_inputs():
    plan = PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                      ("k",), (("d0", "sum"),))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    join = find(opt, PL.Join)[0]
    assert isinstance(join.left, PL.Project)
    assert set(join.left.columns) == {"k", "d0"}  # d1 dropped pre-shuffle
    assert isinstance(join.right, PL.Project)
    # right d0 would surface as the unused d0_r: only the key survives
    assert set(join.right.columns) == {"k"}


def test_predicate_pushdown_below_join_left():
    pred = lambda c: c["d0"] > 0.5
    plan = PL.Select(PL.Join(PL.Scan(0), PL.Scan(1), ("k",), how="inner"),
                     pred, key="p")
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert isinstance(opt, PL.Join)  # select no longer on top
    selects = find(opt.left, PL.Select)
    assert selects and selects[0].columns == ("d0",)
    assert not find(opt.right, PL.Select)


def test_predicate_pushdown_blocked_for_full_join():
    plan = PL.Select(PL.Join(PL.Scan(0), PL.Scan(1), ("k",), how="full"),
                     lambda c: c["d0"] > 0.5, key="p")
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    # pushing a one-sided filter through a full outer join is unsound
    assert isinstance(opt, PL.Select)


def test_predicate_pushdown_below_sort_and_project():
    plan = PL.Select(PL.Sort(PL.Project(PL.Scan(0), ("k", "d0")), ("k",)),
                     lambda c: c["d0"] > 0.0, key="p")
    opt = PL.optimize(plan, [ORDERS], num_shards=8)
    assert isinstance(opt, PL.Sort)
    assert find(opt, PL.Select), "select should sink below the sort shuffle"


def test_probe_unprobeable_predicate_pins_select():
    # reads via values() — the recorder sees no key access, footprint None
    plan = PL.Select(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                     lambda c: list(c.values())[0] > 0, key="p")
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert isinstance(opt, PL.Select) and opt.columns is None


def test_shuffle_elision_co_partitioned_join():
    part = Partitioning(("k",), 8, 7)
    plan = PL.Join(PL.Scan(0, partitioning=part),
                   PL.Scan(1, partitioning=part), ("k",))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    join = find(opt, PL.Join)[0]
    assert join.skip_left_shuffle and join.skip_right_shuffle


def test_shuffle_elision_one_side_adopts_other_seed():
    part = Partitioning(("k",), 8, 3)  # non-default seed
    plan = PL.Join(PL.Scan(0, partitioning=part), PL.Scan(1), ("k",), seed=7)
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    join = find(opt, PL.Join)[0]
    assert join.skip_left_shuffle and not join.skip_right_shuffle
    assert join.shuffle_seed == 3  # right side reshuffles INTO the tag


def test_groupby_elides_after_join_on_same_key():
    plan = PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                      ("k",), (("d0", "sum"),))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert opt.skip_shuffle  # join output is already partitioned on k
    join = find(opt, PL.Join)[0]
    assert not (join.skip_left_shuffle or join.skip_right_shuffle)


def test_outer_join_output_carries_no_partitioning():
    # unmatched-side rows of right/full joins have zero-filled key columns,
    # so a downstream groupby must NOT elide its shuffle on the join's keys
    for how in ("right", "full"):
        plan = PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",), how=how),
                          ("k",), (("d0", "sum"),))
        opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
        assert not opt.skip_shuffle, how
        assert PL.output_partitioning(
            PL.Join(PL.Scan(0), PL.Scan(1), ("k",), how=how),
            [ORDERS, USERS], 8) is None, how
    # inner and left keep their true keys on the hash shard: tag survives
    for how in ("inner", "left"):
        assert PL.output_partitioning(
            PL.Join(PL.Scan(0), PL.Scan(1), ("k",), how=how),
            [ORDERS, USERS], 8) is not None, how


def test_projection_pushdown_keeps_collision_for_suffixed_column():
    # consuming d0_r (right's d0, suffixed only WHILE the name clashes)
    # must keep left's otherwise-dead d0 alive below the join
    plan = PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                      ("k",), (("d0_r", "max"),))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    join = find(opt, PL.Join)[0]
    assert "d0" in join.left.columns
    assert "d0" in join.right.columns


def test_projection_dropping_key_kills_partitioning():
    part = Partitioning(("k",), 8, 7)
    plan = PL.GroupBy(PL.Project(PL.Scan(0, partitioning=part), ("d0",)),
                      ("d0",), (("d0", "count"),))
    opt = PL.optimize(plan, [ORDERS], num_shards=8)
    assert not opt.skip_shuffle  # tag does not survive losing its key column


def test_mismatched_modulus_blocks_elision():
    part = Partitioning(("k",), 4, 7)  # partitioned for a 4-shard mesh
    plan = PL.GroupBy(PL.Scan(0, partitioning=part), ("k",),
                      (("d0", "sum"),))
    opt = PL.optimize(plan, [ORDERS], num_shards=8)
    assert not opt.skip_shuffle


def test_range_tag_not_equal_to_hash_tag():
    # RangePartitioning is a dataclass precisely so coincident fields never
    # tuple-compare equal to a hash Partitioning (NamedTuple == tuple)
    assert Partitioning(("k",), 8, 7) != RangePartitioning(("k",), 8, 7)
    assert RangePartitioning(("k",), 8, 7) != Partitioning(("k",), 8, 7)


def test_sort_join_range_aligns_one_side():
    # the tentpole golden shape: sort(k) -> join(on=k) keeps the sorted
    # side in place and range-aligns the other — ONE AllToAll for the join
    plan = PL.Join(PL.Sort(PL.Scan(0), ("k",)), PL.Scan(1), ("k",),
                   algorithm="sort")
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert opt.skip_left_shuffle and not opt.skip_right_shuffle
    assert opt.align == "left" and opt.align_keys == ("k",)
    assert "align=left" in PL.explain(opt)
    # mirrored: the sorted side on the right
    plan = PL.Join(PL.Scan(0), PL.Sort(PL.Scan(1), ("k",)), ("k",))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert opt.skip_right_shuffle and opt.align == "right"


def test_sort_join_range_alignment_key_prefix_only():
    # range keys must be a PREFIX of the join keys (placement is a function
    # of the prefix); sort on a non-prefix key must not elide
    plan = PL.Join(PL.Sort(PL.Scan(0), ("d0",)), PL.Scan(1), ("k",))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert opt.align is None
    assert not opt.skip_left_shuffle and not opt.skip_right_shuffle


def test_sort_output_partitioning_and_groupby_elision():
    part = PL.output_partitioning(PL.Sort(PL.Scan(0), ("k", "d0")),
                                  [ORDERS], 8)
    assert isinstance(part, RangePartitioning)
    assert part.keys == ("k", "d0") and part.num_partitions == 8
    # groupby on keys that EXTEND the range prefix elides (prefix rule) ...
    plan = PL.GroupBy(PL.Sort(PL.Scan(0), ("k",)), ("k", "d1"),
                      (("d0", "sum"),))
    assert PL.optimize(plan, [ORDERS], 8).skip_shuffle
    # ... but a range partitioning on (k, d0) does NOT satisfy keys (k,):
    # equal k can straddle shards when d0 differs
    plan = PL.GroupBy(PL.Sort(PL.Scan(0), ("k", "d0")), ("k",),
                      (("d1", "sum"),))
    assert not PL.optimize(plan, [ORDERS], 8).skip_shuffle


def test_sort_sort_elision_both_directions():
    # by a prefix of the range keys, and by an extension of them
    for outer in (("k",), ("k", "d0", "d1")):
        plan = PL.Sort(PL.Sort(PL.Scan(0), ("k", "d0")), outer)
        opt = PL.optimize(plan, [ORDERS], num_shards=8)
        assert opt.skip_shuffle, outer
    plan = PL.Sort(PL.Sort(PL.Scan(0), ("d0",)), ("k",))
    assert not PL.optimize(plan, [ORDERS], 8).skip_shuffle


def test_limit_preserves_range_tag_project_kills_it():
    # limit only drops rows: the surviving placement still satisfies a
    # downstream groupby; projecting a range key away kills the tag
    plan = PL.GroupBy(PL.Limit(PL.Sort(PL.Scan(0), ("k",)), 10), ("k",),
                      (("d0", "sum"),))
    assert PL.optimize(plan, [ORDERS], 8).skip_shuffle
    plan = PL.GroupBy(
        PL.Project(PL.Sort(PL.Scan(0), ("k",)), ("d0",)),
        ("d0",), (("d0", "count"),))
    assert not PL.optimize(plan, [ORDERS], 8).skip_shuffle


def test_scan_range_tag_from_materialized_sort():
    # a Scan carrying a RangePartitioning (eager ctx.sort output) feeds the
    # same elision rules as a plan-internal Sort
    part = RangePartitioning(("k",), 8, ("table", 999))
    plan = PL.GroupBy(PL.Scan(0, partitioning=part), ("k",),
                      (("d0", "sum"),))
    assert PL.optimize(plan, [ORDERS], 8).skip_shuffle
    # mismatched modulus: dropped, shuffle stays
    part4 = RangePartitioning(("k",), 4, ("table", 999))
    plan = PL.GroupBy(PL.Scan(0, partitioning=part4), ("k",),
                      (("d0", "sum"),))
    assert not PL.optimize(plan, [ORDERS], 8).skip_shuffle


def test_self_join_same_range_fingerprint_skips_both():
    part = RangePartitioning(("k",), 8, ("table", 7))
    plan = PL.Join(PL.Scan(0, partitioning=part),
                   PL.Scan(1, partitioning=part), ("k",))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert opt.skip_left_shuffle and opt.skip_right_shuffle
    assert opt.align is None
    # different fingerprints = different splitters: align, don't skip both
    other = RangePartitioning(("k",), 8, ("table", 8))
    plan = PL.Join(PL.Scan(0, partitioning=part),
                   PL.Scan(1, partitioning=other), ("k",))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=8)
    assert opt.skip_left_shuffle and not opt.skip_right_shuffle
    assert opt.align == "left"


def test_single_shard_elides_everything():
    plan = PL.Sort(PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                              ("k",), (("d0", "sum"),)), ("k",))
    opt = PL.optimize(plan, [ORDERS, USERS], num_shards=1)
    assert "alltoall" not in PL.explain(opt)


def test_canonical_key_stability_and_uncacheable_select():
    mk = lambda: PL.GroupBy(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                            ("k",), (("d0", "sum"),))
    assert PL.canonical_key(mk()) == PL.canonical_key(mk())
    assert PL.canonical_key(
        PL.Select(mk(), lambda c: c["d0"] > 0)) is None  # no key -> no cache
    k1 = PL.canonical_key(PL.Select(mk(), lambda c: c["d0"] > 0, key="a"))
    k2 = PL.canonical_key(PL.Select(mk(), lambda c: c["d0"] > 1, key="b"))
    assert k1 is not None and k1 != k2


# --- execution: fused == eager on the single-device context -------------------


@pytest.fixture(scope="module")
def ctx():
    return DistContext(axis_name="plan_test")


def int_table(n, key_range, seed, names=("d0", "d1")):
    rng = np.random.default_rng(seed)
    cols = {"k": rng.integers(0, key_range, n).astype(np.int32)}
    for nm in names:
        # integer-valued floats: aggregation order cannot perturb bits
        cols[nm] = rng.integers(-40, 40, n).astype(np.float32)
    return Table.from_arrays(cols)


def assert_tables_equal(a, b):
    from repro.testing.compare import table_rows, tables_bitwise_equal
    assert tables_bitwise_equal(a, b), (table_rows(a), table_rows(b))


@pytest.mark.parametrize("seed", [0, 1])
def test_collect_matches_eager_join_select_groupby(ctx, seed):
    orders = ctx.scatter(int_table(300, 500, seed))
    users = ctx.scatter(int_table(300, 500, seed + 50))
    aggs = (("d0", "sum"), ("d0", "mean"), ("d0", "count"), ("d0_r", "max"))

    j, _ = ctx.join(orders, users, "k")
    s = ctx.select(j, lambda c: c["d0"] > 0.0, key="pos")
    ge, _ = ctx.groupby(s, "k", aggs, strategy="shuffle")

    fused = (ctx.frame(orders).join(ctx.frame(users), "k")
             .select(lambda c: c["d0"] > 0.0, key="pos")
             .groupby("k", aggs, strategy="shuffle"))
    assert_tables_equal(ge, fused.collect())


def test_collect_matches_eager_outer_join_groupby(ctx):
    # the review repro: fused full-join -> groupby must match eager
    a = ctx.scatter(int_table(150, 80, 61))
    b = ctx.scatter(int_table(150, 80, 62))
    j, _ = ctx.join(a, b, "k", how="full")
    ge, _ = ctx.groupby(j, "k", (("d0", "count"),), strategy="shuffle")
    fused = (ctx.frame(a).join(ctx.frame(b), "k", how="full")
             .groupby("k", (("d0", "count"),), strategy="shuffle"))
    assert_tables_equal(ge, fused.collect())


def test_collect_suffixed_column_aggregation(ctx):
    # the review repro: aggregating d0_r after projection pushdown
    a = ctx.scatter(int_table(150, 60, 63))
    b = ctx.scatter(int_table(150, 60, 64))
    j, _ = ctx.join(a, b, "k")
    ge, _ = ctx.groupby(j, "k", (("d0_r", "max"),), strategy="shuffle")
    fused = (ctx.frame(a).join(ctx.frame(b), "k")
             .groupby("k", (("d0_r", "max"),), strategy="shuffle"))
    assert_tables_equal(ge, fused.collect())


def test_collect_matches_eager_set_ops(ctx):
    a = ctx.scatter(int_table(120, 40, 3, names=()))
    b = ctx.scatter(int_table(120, 40, 4, names=()))
    for eager, frame in [
        (ctx.union(a, b)[0], ctx.frame(a).union(ctx.frame(b))),
        (ctx.intersect(a, b)[0], ctx.frame(a).intersect(ctx.frame(b))),
        (ctx.difference(a, b)[0], ctx.frame(a).difference(ctx.frame(b))),
        (ctx.distinct(a)[0], ctx.frame(a).distinct()),
    ]:
        assert_tables_equal(eager, frame.collect())


def test_multikey_sort_matches_lexsort(ctx):
    t = int_table(200, 12, 7)  # many key ties -> d0 breaks them
    s, _ = ctx.sort(ctx.scatter(t), ["k", "d0"])
    got = s.to_table().to_numpy()
    d = t.to_numpy()
    order = np.lexsort((d["d0"], d["k"]))  # primary key last in lexsort
    np.testing.assert_array_equal(got["k"], d["k"][order])
    np.testing.assert_array_equal(got["d0"], d["d0"][order])


def test_lazy_sort_and_limit(ctx):
    t = int_table(150, 30, 11)
    out = ctx.frame(ctx.scatter(t)).sort(["k", "d0"]).limit(10).collect()
    d = out.to_table().to_numpy()
    ref = t.to_numpy()
    order = np.lexsort((ref["d0"], ref["k"]))
    np.testing.assert_array_equal(d["k"], ref["k"][order][:10])


def test_global_limit_matches_oracle(ctx):
    # limit(n) == the first n rows of the global table, for every n regime
    t = int_table(120, 40, 71)
    dt = ctx.scatter(t)
    ref = t.to_numpy()
    for n in (0, 1, 13, 120, 200):
        d = ctx.limit(dt, n).to_table().to_numpy()
        assert len(d["k"]) == min(n, 120), n
        np.testing.assert_array_equal(d["k"], ref["k"][:n])
        lazy = ctx.frame(dt).limit(n).collect().to_table().to_numpy()
        np.testing.assert_array_equal(lazy["k"], ref["k"][:n])


def test_fused_sort_join_matches_eager(ctx):
    # sort -> sort-merge join: the range fast path vs eager's re-shuffles
    a = ctx.scatter(int_table(200, 300, 81))
    b = ctx.scatter(int_table(200, 300, 82))
    s, _ = ctx.sort(a, "k")
    eager, _ = ctx.join(s, b, "k", algorithm="sort")
    fused = (ctx.frame(a).sort("k")
             .join(ctx.frame(b), "k", algorithm="sort"))
    assert_tables_equal(eager, fused.collect())


def test_eager_sort_tag_rides_frame_boundary(ctx):
    # ctx.sort tags its output; a frame over it elides the groupby shuffle
    s, _ = ctx.sort(ctx.scatter(int_table(150, 30, 91)), "k")
    assert isinstance(s.partitioning, RangePartitioning)
    assert s.partitioning.keys == ("k",)
    f = ctx.frame(s).groupby("k", (("d0", "sum"),))
    assert all(r["elided"] for r in f.plan_report())
    eager, _ = ctx.groupby(s, "k", (("d0", "sum"),))
    assert_tables_equal(eager, f.collect())
    # two materializations never share splitter provenance
    s2, _ = ctx.sort(ctx.scatter(int_table(150, 30, 92)), "k")
    assert s.partitioning.fingerprint != s2.partitioning.fingerprint


def test_plan_report_attributes_limit_at_zero_bytes(ctx):
    rep = (ctx.frame(ctx.scatter(int_table(64, 16, 93)))
           .sort("k").limit(5).plan_report())
    ops = [r["op"] for r in rep]
    assert "sort" in ops and "limit" in ops, ops
    lim = rep[ops.index("limit")]
    assert lim["elided"] and lim["wire_bytes"] == 0 and lim["bucket"] == 0


def test_co_partitioned_fast_path_matches_shuffled(ctx):
    # partition_by tags its output; the tagged join must equal the untagged
    raw = ctx.scatter(int_table(200, 64, 21))
    dims = ctx.scatter(int_table(64, 64, 22, names=("v0",)))
    part_raw, _ = ctx.partition_by(raw, "k")
    part_dims, _ = ctx.partition_by(dims, "k")
    assert part_raw.partitioning == Partitioning(("k",), ctx.num_shards, 7)
    fast = ctx.frame(part_raw).join(ctx.frame(part_dims), "k")
    rep = fast.plan_report()
    assert all(r["elided"] for r in rep), rep
    slow, _ = ctx.join(raw, dims, "k")
    assert_tables_equal(slow, fast.collect())


def test_plan_report_accounts_wire_bytes(ctx):
    orders = ctx.scatter(int_table(100, 50, 31))
    users = ctx.scatter(int_table(100, 50, 32))
    f = (ctx.frame(orders).join(ctx.frame(users), "k", bucket_capacity=64)
         .groupby("k", (("d0", "sum"),)))
    rep = f.plan_report()
    assert len(rep) == 3  # join L, join R, groupby
    assert [r["elided"] for r in rep].count(True) >= 1  # groupby elides
    p = ctx.num_shards
    for r in rep:
        expect = 0 if r["elided"] else p * p * r["bucket"] * r["row_bytes"]
        assert r["wire_bytes"] == expect


def test_select_cache_key_controls_recompilation(ctx):
    t = ctx.scatter(int_table(64, 16, 41))
    n0 = len(ctx.plan_cache)
    ctx.select(t, lambda c: c["d0"] > 0, key="cached_pred")
    n1 = len(ctx.plan_cache)
    assert n1 == n0 + 1
    ctx.select(t, lambda c: c["d0"] > 0, key="cached_pred")
    assert len(ctx.plan_cache) == n1  # hit
    # keyless: cached under a code-identity key — one entry, and a
    # re-created lambda from the same definition site HITS it
    def keyless():
        return ctx.select(t, lambda c: c["d0"] < 0)

    keyless()
    assert len(ctx.plan_cache) == n1 + 1
    hits = ctx.cache_stats()["hits"]
    keyless()
    assert len(ctx.plan_cache) == n1 + 1
    assert ctx.cache_stats()["hits"] == hits + 1


def test_same_key_different_predicate_not_conflated(ctx):
    # the bytecode fingerprint keeps a reused key from serving stale code
    t = ctx.scatter(int_table(64, 16, 51))
    a = ctx.select(t, lambda c: c["d0"] > 0, key="same")
    b = ctx.select(t, lambda c: c["d0"] < 0, key="same")
    da, db = a.to_table().to_numpy(), b.to_table().to_numpy()
    assert (da["d0"] > 0).all()
    assert (db["d0"] < 0).all()


def test_collect_caches_on_canonical_plan(ctx):
    t = ctx.scatter(int_table(64, 16, 43))
    f = lambda: (ctx.frame(t)
                 .select(lambda c: c["d0"] > 0, key="q")
                 .groupby("k", (("d0", "sum"),)))
    f().collect()
    n1 = len(ctx.plan_cache)
    f().collect()  # same canonical plan + shapes -> cache hit
    assert len(ctx.plan_cache) == n1


# --- cost model: limit pushdown, strategy choice, capacity sizing -------------


from repro.core import stats as S  # noqa: E402  (groups the cost tests)

LO_STATS = S.TableStats(rows=8000.0, columns=(("k", S.ColumnStats(32.0)),))
HI_STATS = S.TableStats(rows=8000.0, columns=(("k", S.ColumnStats(7000.0)),))


def test_limit_pushdown_below_project():
    # Limit(Project(x)) -> Project(Limit(x)): truncate before wide-row work
    opt = PL.optimize(PL.Limit(PL.Project(PL.Scan(0), ("k", "d0")), 5),
                      [ORDERS], 8)
    assert isinstance(opt, PL.Project) and isinstance(opt.child, PL.Limit)
    assert opt.child.n == 5
    # chains of projects: the limit sinks below every one of them
    opt = PL.optimize(
        PL.Limit(PL.Project(PL.Project(PL.Scan(0), ("k", "d0")), ("k",)), 3),
        [ORDERS], 8)
    assert isinstance(opt, PL.Project)
    limits = find(opt, PL.Limit)
    assert limits and isinstance(limits[0].child, PL.Scan), PL.explain(opt)


def test_limit_not_pushed_below_select_or_sort():
    # Select changes row membership, Sort changes order: both pin Limit
    opt = PL.optimize(PL.Limit(PL.Select(PL.Scan(0),
                                         lambda c: c["d0"] > 0, key="p"), 5),
                      [ORDERS], 8)
    assert isinstance(opt, PL.Limit) and isinstance(opt.child, PL.Select)
    opt = PL.optimize(PL.Limit(PL.Sort(PL.Scan(0), ("k",)), 5), [ORDERS], 8)
    assert isinstance(opt, PL.Limit) and isinstance(opt.child, PL.Sort)


def test_groupby_auto_strategy_resolution():
    plan = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),))
    # no stats: the documented two_phase fallback, nothing sized
    o = PL.optimize(plan, [ORDERS], 8)
    assert o.strategy == "two_phase" and not o.sized
    assert o.bucket_capacity is None
    # low key NDV: p * ndv << rows -> two_phase, bucket sized from NDV
    o = PL.optimize(plan, [ORDERS], 8, [LO_STATS])
    assert o.strategy == "two_phase" and o.sized
    assert o.bucket_capacity == S.size_bucket(32.0, 8)
    # high key NDV: partials don't dedup -> raw shuffle, bucket from rows
    o = PL.optimize(plan, [ORDERS], 8, [HI_STATS])
    assert o.strategy == "shuffle" and o.sized
    assert o.bucket_capacity == S.size_bucket(8000.0 / 8, 8)
    # an explicit strategy is never overridden
    o = PL.optimize(PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),),
                               strategy="shuffle"), [ORDERS], 8, [LO_STATS])
    assert o.strategy == "shuffle"
    # stats present but the KEY column was never sketched (e.g. a derived
    # aggregate column): missing information takes the two_phase
    # fallback, never worst-case shuffle
    no_key = S.TableStats(rows=8000.0, columns=(
        ("d0", S.ColumnStats(100.0)),))
    o = PL.optimize(plan, [ORDERS], 8, [no_key])
    assert o.strategy == "two_phase"
    assert o.bucket_capacity == S.size_bucket(8000.0 / 8, 8)  # rows-based


def test_cost_sizing_fills_unset_capacities_only():
    plan = PL.Join(PL.Scan(0), PL.Scan(1), ("k",))
    o = PL.optimize(plan, [ORDERS, USERS], 8, [HI_STATS, HI_STATS])
    assert o.sized and o.bucket_capacity is not None
    assert o.out_capacity is not None  # estimated match count, not c_l+c_r
    assert o.out_sized
    assert PL.plan_cost_sized(o)
    # a user-set bucket survives; the join is still out-sized
    plan_u = PL.Join(PL.Scan(0), PL.Scan(1), ("k",), bucket_capacity=999)
    o = PL.optimize(plan_u, [ORDERS, USERS], 8, [HI_STATS, HI_STATS])
    assert o.bucket_capacity == 999
    assert not o.sized and o.out_sized
    # a USER-set out_capacity is deliberate truncation, never an estimate:
    # out_sized must stay False (no truncation counting, no retry)
    plan_o = PL.Join(PL.Scan(0), PL.Scan(1), ("k",), out_capacity=50)
    o = PL.optimize(plan_o, [ORDERS, USERS], 8, [HI_STATS, HI_STATS])
    assert o.out_capacity == 50 and not o.out_sized
    assert o.sized  # only the bucket came from the estimate
    # no stats: nothing sized at all (the byte-compat guard)
    o = PL.optimize(plan, [ORDERS, USERS], 8)
    assert o.bucket_capacity is None and o.out_capacity is None
    assert not PL.plan_cost_sized(o)


def test_cost_sizing_skipped_on_single_shard():
    # p == 1: no wire to save; capacities stay at the local defaults so a
    # stats-tagged table executes byte-identically to an untagged one
    plan = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),))
    o = PL.optimize(plan, [ORDERS], 1, [LO_STATS])
    assert o.strategy == "two_phase" and not o.sized
    assert o.bucket_capacity is None
    jp = PL.Join(PL.Scan(0), PL.Scan(1), ("k",))
    oj = PL.optimize(jp, [ORDERS, USERS], 1, [HI_STATS, HI_STATS])
    assert not PL.plan_cost_sized(oj)


def test_cost_sizing_leaves_aligned_join_bucket_alone():
    # a range-aligned join keeps the runtime capacity-bump bucket (a whole
    # source shard may pile into one anchor range); only out is sized
    plan = PL.Join(PL.Sort(PL.Scan(0), ("k",)), PL.Scan(1), ("k",))
    o = PL.optimize(plan, [ORDERS, USERS], 8, [HI_STATS, HI_STATS])
    assert o.align == "left"
    assert o.bucket_capacity is None and not o.sized
    assert o.out_capacity is not None and o.out_sized


def test_estimator_propagates_through_operators():
    est = PL.estimate_output_stats(
        PL.Select(PL.Scan(0), lambda c: c["d0"] > 0, key="p"),
        [ORDERS], [LO_STATS])
    assert est.rows == 8000.0 * S.DEFAULT_SELECTIVITY
    est = PL.estimate_output_stats(
        PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),)),
        [ORDERS], [LO_STATS])
    assert est.rows == 32.0  # NDV-capped
    est = PL.estimate_output_stats(PL.Limit(PL.Scan(0), 7),
                                   [ORDERS], [LO_STATS])
    assert est.rows == 7.0
    # containment join: rows_l * rows_r / max(ndv_l, ndv_r)
    est = PL.estimate_output_stats(
        PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
        [ORDERS, USERS], [LO_STATS, HI_STATS])
    assert est.rows == pytest.approx(8000.0 * 8000.0 / 7000.0)
    # an unknown input poisons the estimate (conservative path downstream)
    assert PL.estimate_output_stats(
        PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
        [ORDERS, USERS], [LO_STATS, None]) is None


def test_explain_annotates_estimates_and_sizing():
    plan = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),))
    opt = PL.optimize(plan, [ORDERS], 8, [LO_STATS])
    txt = PL.explain(opt, [ORDERS], [LO_STATS])
    assert "~rows=32" in txt and "cost-sized" in txt and "bucket=" in txt
    # without stats the old golden format is unchanged
    plain = PL.explain(PL.optimize(plan, [ORDERS], 8))
    assert "~rows" not in plain and "cost-sized" not in plain


def test_analyzed_collect_matches_eager_and_attaches_stats(ctx):
    # stats-driven planning must never change results: fused-over-analyzed
    # == eager-over-raw, bit for bit (single shard: sizing disabled, the
    # byte-compat contract; the 8-shard sizing path is covered by
    # dist_cases 'cost_groupby'/'overflow_retry' and bench_cost)
    t = int_table(300, 40, 77)
    raw = ctx.scatter(t)
    analyzed = ctx.analyze(raw)
    assert analyzed.stats is not None and analyzed.stats.rows == 300.0
    aggs = (("d0", "sum"), ("d0", "count"), ("d0", "min"))
    eager, _ = ctx.groupby(raw, "k", aggs)
    fused = ctx.frame(analyzed).groupby("k", aggs).collect()
    assert_tables_equal(eager, fused)
    assert fused.stats is not None and fused.stats.rows <= 80.0
    assert ctx.overflow_retries == 0
    # the propagated estimate feeds a SECOND hop without re-analyzing
    hop2 = ctx.frame(fused).sort("k").collect()
    assert hop2.stats is not None


def test_cost_sized_stats_mask_mirrors_executor_order(ctx):
    # the retry gate attributes each ShuffleStats entry to its node via a
    # static walk — it must line up 1:1 with what execute_plan emits
    frame = (ctx.frame(ctx.scatter(int_table(60, 10, 5)))
             .join(ctx.frame(ctx.scatter(int_table(60, 10, 6))), "k")
             .groupby("k", (("d0", "sum"),))
             .sort("k").limit(5))
    plan = frame.optimized()
    _, stats = frame.collect_with_stats()
    mask = PL.cost_sized_stats_mask(plan)
    assert len(mask) == len(stats), (len(mask), len(stats))
    assert not any(mask)  # nothing sized without stats
    # sized nodes flag exactly their own entries
    sized_join = PL.optimize(PL.Join(PL.Scan(0), PL.Scan(1), ("k",)),
                             [ORDERS, USERS], 8, [HI_STATS, HI_STATS])
    assert PL.cost_sized_stats_mask(sized_join) == [True, True]
    user_out = PL.optimize(
        PL.Join(PL.Scan(0), PL.Scan(1), ("k",), bucket_capacity=9,
                out_capacity=9), [ORDERS, USERS], 8, [HI_STATS, HI_STATS])
    assert PL.cost_sized_stats_mask(user_out) == [False, False]


def test_retry_replan_is_the_no_stats_plan():
    # what the overflow retry executes: the same logical plan re-optimized
    # WITHOUT stats — nothing sized, distinct jit cache key from the
    # sized first attempt (end-to-end retry: dist_cases 'overflow_retry')
    plan = PL.GroupBy(PL.Scan(0), ("k",), (("d0", "sum"),))
    sized = PL.optimize(plan, [ORDERS], 8, [LO_STATS])
    safe = PL.optimize(plan, [ORDERS], 8)
    assert PL.plan_cost_sized(sized) and not PL.plan_cost_sized(safe)
    assert PL.canonical_key(sized) != PL.canonical_key(safe)
    assert safe.bucket_capacity is None  # executor fallback sizing applies


def test_safe_capacity_mode_uses_unoverflowable_buckets(ctx):
    # execute_plan(safe_capacity=True) must size every unset bucket at the
    # full source capacity — the retry mode a skewed send cannot overflow
    plan = PL.Repartition(PL.Scan(0), ("k",))
    t = ctx.scatter(Table.from_arrays({"k": np.arange(64, dtype=np.int32)}))
    report: list = []

    def body(*tabs):
        return PL.execute_plan(plan, tabs, axis_name=ctx.axis_name,
                               num_shards=ctx.num_shards, report=report,
                               safe_capacity=True)

    jax.eval_shape(ctx._make_global(body), (t.columns, t.row_counts))
    assert report[0]["bucket"] == 64  # == capacity, not capacity*slack/p


# --- Table.empty N-D schemas (satellite) --------------------------------------


def test_table_empty_nd_schema():
    t = Table.empty({"k": jnp.int32,
                     "tokens": (jnp.int32, (16,)),
                     "emb": jax.ShapeDtypeStruct((4, 2), jnp.float32)},
                    capacity=8)
    assert t.columns["k"].shape == (8,)
    assert t.columns["tokens"].shape == (8, 16)
    assert t.columns["tokens"].dtype == jnp.int32
    assert t.columns["emb"].shape == (8, 4, 2)
    assert int(t.row_count) == 0


# --- window functions: golden plan shapes + fused execution -------------------


WFUNCS = (("rank", None, 0), ("cumsum", "d0", 0), ("lag", "d0", 1))


def test_window_schema_appends_result_columns():
    plan = PL.Window(PL.Scan(0), ("k",), ("d1",), WFUNCS)
    an = PL._Analysis([ORDERS])
    sch = an.schema(plan)
    assert set(sch) == {"k", "d0", "d1", "rank", "d0_cumsum", "d0_lag"}
    assert sch["rank"].dtype == I32
    assert sch["d0_cumsum"].dtype == F32  # input dtype preserved
    assert sch["d0_lag"].dtype == F32


def test_window_elides_shuffle_after_matching_sort():
    # sort on (k, d1) -> window by k order d1: exact key match, elided
    plan = PL.Window(PL.Sort(PL.Scan(0), ("k", "d1")), ("k",), ("d1",),
                     WFUNCS)
    opt = PL.optimize(plan, [ORDERS], 8)
    assert opt.skip_shuffle, PL.explain(opt)
    # sort on the PARTITION prefix alone also elides (placement is a
    # function of a prefix of the window keys)
    plan = PL.Window(PL.Sort(PL.Scan(0), ("k",)), ("k",), ("d1",), WFUNCS)
    assert PL.optimize(plan, [ORDERS], 8).skip_shuffle
    # a range-partitioned Scan (a materialized sort output) elides too
    part = RangePartitioning(("k", "d1"), 8, ("table", 3))
    plan = PL.Window(PL.Scan(0, partitioning=part), ("k",), ("d1",), WFUNCS)
    assert PL.optimize(plan, [ORDERS], 8).skip_shuffle
    # different leading key: must NOT elide
    plan = PL.Window(PL.Sort(PL.Scan(0), ("d0",)), ("k",), ("d1",), WFUNCS)
    assert not PL.optimize(plan, [ORDERS], 8).skip_shuffle


def test_window_placement_tag_elides_downstream_ops():
    # windows are row/placement-preserving: the range tag survives, so a
    # downstream groupby on the partition key elides its shuffle
    plan = PL.GroupBy(PL.Window(PL.Sort(PL.Scan(0), ("k",)), ("k",), (),
                                WFUNCS),
                      ("k",), (("d0", "sum"),))
    opt = PL.optimize(plan, [ORDERS], 8)
    assert opt.skip_shuffle, PL.explain(opt)
    gb_children = find(opt, PL.Window)
    assert gb_children and gb_children[0].skip_shuffle
    # placement on (k, d1) does NOT satisfy a groupby on k alone — a k
    # group can span shards with different d1 — so no elision there
    plan = PL.GroupBy(PL.Window(PL.Sort(PL.Scan(0), ("k", "d1")), ("k",),
                                ("d1",), WFUNCS),
                      ("k",), (("d0", "sum"),))
    assert not PL.optimize(plan, [ORDERS], 8).skip_shuffle
    # an UNSORTED window leaves its own range placement behind, which a
    # downstream sort on the same keys can reuse
    plan = PL.Sort(PL.Window(PL.Scan(0), ("k",), ("d1",), WFUNCS),
                   ("k", "d1"))
    opt = PL.optimize(plan, [ORDERS], 8)
    assert opt.skip_shuffle and not find(opt, PL.Window)[0].skip_shuffle


def test_window_projection_pushdown_keeps_func_inputs():
    # only d0_cumsum is consumed above: d1 is a window ORDER key and must
    # survive; unused payload columns below the window are dropped
    wide = {"k": jax.ShapeDtypeStruct((), I32),
            "d0": jax.ShapeDtypeStruct((), F32),
            "d1": jax.ShapeDtypeStruct((), F32),
            "junk": jax.ShapeDtypeStruct((), F32)}
    plan = PL.Project(PL.Window(PL.Scan(0), ("k",), ("d1",),
                                (("cumsum", "d0", 0),)),
                      ("k", "d0_cumsum"))
    opt = PL.optimize(plan, [wide], 8)
    projects = find(opt, PL.Project)
    below = [p for p in projects if isinstance(p.child, PL.Scan)]
    assert below and set(below[0].columns) == {"k", "d0", "d1"}, \
        PL.explain(opt)


def test_window_cost_sizing_mirrors_sort():
    plan = PL.Window(PL.Scan(0), ("k",), (), WFUNCS)
    o = PL.optimize(plan, [ORDERS], 8, [HI_STATS])
    assert o.sized
    assert o.bucket_capacity == S.size_bucket(
        8000.0 / 8, 8, factor=S.RANGE_SIZING_FACTOR)
    # row-preserving: estimates propagate unchanged through the window
    gb = PL.GroupBy(plan, ("k",), (("d0", "sum"),))
    est = PL._Estimator(PL._Analysis([ORDERS]), [HI_STATS])
    assert est.stats(gb.child).rows == 8000.0
    # elided window is never sized (no shuffle to size)
    plan = PL.Window(PL.Sort(PL.Scan(0), ("k",)), ("k",), (), WFUNCS)
    o = PL.optimize(plan, [ORDERS], 8, [HI_STATS])
    assert o.skip_shuffle and not o.sized and o.bucket_capacity is None


def test_window_canonical_key_and_stats_mask():
    mk = lambda: PL.Window(PL.Scan(0), ("k",), ("d1",), WFUNCS)
    assert PL.canonical_key(mk()) == PL.canonical_key(mk())
    assert PL.canonical_key(mk()) != PL.canonical_key(
        PL.Window(PL.Scan(0), ("k",), ("d1",), (("rank", None, 0),)))
    # one ShuffleStats entry per window, mirrored in the cost-sized mask
    plan = PL.Window(PL.Sort(PL.Scan(0), ("k",)), ("k",), (), WFUNCS)
    assert PL._stats_arity(plan) == 1
    assert len(PL.cost_sized_stats_mask(plan)) == 2  # sort + window


def test_select_not_pushed_below_window():
    # filtering before a window changes ranks/sums: the Select must stay
    # pinned above even when it only reads pass-through columns
    plan = PL.Select(PL.Window(PL.Scan(0), ("k",), ("d1",), WFUNCS),
                     lambda c: c["d0"] > 0.0, key="p")
    opt = PL.optimize(plan, [ORDERS], 8)
    assert isinstance(opt, PL.Select), PL.explain(opt)
    assert isinstance(opt.child, PL.Window)


def test_lazy_window_matches_local_oracle(ctx):
    from oracle import window_oracle
    from repro.core import ops_agg as A

    rng = np.random.default_rng(21)
    n = 400
    cols = {"k": rng.integers(0, 6, n).astype(np.int32),
            "o": rng.permutation(n).astype(np.int32),
            "d0": rng.integers(-30, 30, n).astype(np.float32)}
    funcs = ["rank", "dense_rank", "row_number", ("lag", "d0"),
             ("lead", "d0"), ("cumsum", "d0"), ("cummax", "d0"),
             ("running_mean", "d0")]
    dt = ctx.scatter(Table.from_arrays(cols))
    out = (ctx.frame(dt).window("k", funcs, order_by="o")
           .collect().to_table().to_numpy())
    want = window_oracle(cols, ["k"], ["o"], A.normalize_funcs(funcs))
    for name in want:
        np.testing.assert_array_equal(out[name], want[name], err_msg=name)
    # eager entry point: identical result, carries the range tag
    eager, _ = ctx.window(dt, "k", funcs, order_by="o")
    got = eager.to_table().to_numpy()
    for name in want:
        np.testing.assert_array_equal(got[name], want[name], err_msg=name)
    assert isinstance(eager.partitioning, RangePartitioning)
    assert eager.partitioning.keys == ("k", "o")


def test_window_explain_lists_funcs(ctx):
    dt = ctx.scatter(int_table(32, 4, seed=2))
    txt = (ctx.frame(dt).sort(["k", "d0"])
           .window("k", ["rank", ("cumsum", "d0")], order_by="d0")
           .explain())
    assert "Window(by=('k',), order_by=('d0',)" in txt
    assert "'d0_cumsum'" in txt and "shuffle=elided" in txt, txt
