"""Distributed correctness on 8 host devices (subprocess-isolated).

Each case runs ``python -m repro.testing.dist_cases <case>`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and asserts on the
JSON it prints: the BSP shuffle operators, MoE EP dispatch (== the
relational shuffle), flash-decode LSE merge, int8 pod-compressed training,
and elastic checkpoint restore across mesh shapes.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# partial-manual shard_map (auto=) crashes XLA on jax 0.4.x only — don't
# blanket-xfail: on jax >= 0.5 the case must actually pass
_JAX_PRE_05 = tuple(
    int(x) for x in jax.__version__.split(".")[:2] if x.isdigit()) < (0, 5)


def run_case(case: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "repro.testing.dist_cases", case],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"{case} failed:\n{out.stdout}\n{out.stderr}"
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")][-1]
    return json.loads(line[5:])


def test_dist_join_union_sort():
    r = run_case("join_union_sort")
    assert r["join_hash_rows"] == r["join_expect"], r
    assert r["join_sort_rows"] == r["join_expect"], r
    assert r["join_hash_overflow"] == 0
    assert r["union_rows"] == r["union_expect"], r
    assert r["sort_ok"], r


def test_dist_intersect_difference():
    r = run_case("intersect_difference")
    assert r["intersect_ok"] and r["difference_ok"], r


def test_dist_groupby_both_strategies():
    r = run_case("groupby")
    assert r["shuffle_ok"] and r["two_phase_ok"], r
    assert r["shuffle_overflow"] == 0 and r["two_phase_overflow"] == 0, r
    # the paper's two-phase claim: partial aggregates shuffle fewer rows
    assert r["two_phase_fewer_rows"], r


def test_plan_fused_matches_eager():
    """The tentpole contract: one fused shard_map program per chain, with
    strictly fewer AllToAlls and wire bytes, bit-identical to eager."""
    r = run_case("plan_fused")
    assert r["identical"], r
    assert r["eager_overflow"] == 0 and r["fused_overflow"] == 0, r
    assert r["fused_alltoall"] < r["eager_alltoall"], r
    assert r["fused_wire"] < r["eager_wire"], r


def test_sort_chain_elides_one_alltoall():
    """The range-provenance contract: fused sort->join runs exactly one
    fewer AllToAll than eager (the sorted side stays put, the other side
    range-aligns), with an identical row multiset; the surviving range tag
    then elides the downstream groupby shuffle entirely."""
    r = run_case("sort_chain")
    assert r["identical"], r
    assert r["eager_overflow"] == 0 and r["fused_overflow"] == 0, r
    assert r["fused_alltoall"] == r["eager_alltoall"] - 1, r
    assert r["groupby_elided"], r
    assert r["groupby_identical"], r


def test_sort_align_survives_probe_skew():
    """Default bucket sizing on the range-aligned join side must absorb a
    one-destination pileup (all probe keys in one anchor range) without
    overflow or divergence from eager."""
    r = run_case("sort_align_skew")
    assert r["identical"], r
    assert r["fused_overflow"] == 0, r


def test_global_limit_matches_local_oracle():
    """limit(n) is a true global head-n / post-sort top-n — bit-identical
    to the local oracle, never the per-shard heads."""
    r = run_case("global_limit")
    assert r["ok"], r
    assert r["limit_reported_zero"], r


def test_overflow_retry_recompiles_once_and_matches_oracle():
    """The cost model's safety contract: a skewed repartition whose
    stats-sized capacity overflows recompiles exactly once at conservative
    capacities and matches the local oracle bit-for-bit."""
    r = run_case("overflow_retry")
    assert r["retries"] == 1, r
    assert r["retries_after_repeat"] == 1, r  # repeat: straight to safe
    assert r["stats_dropped"], r  # bad estimates don't cascade downstream
    assert r["final_overflow"] == 0, r
    assert r["rows"] == r["rows_expect"], r
    assert r["identical"], r


def test_cost_model_groupby_strategy_and_wire():
    """Cost-driven physical planning: two_phase at low key cardinality,
    raw shuffle at high, strictly fewer dense wire bytes than the
    fixed-slack baseline at both ends, bit-identical results, no retry."""
    r = run_case("cost_groupby")
    assert r["retries"] == 0, r
    assert r["low"]["strategy"] == "two_phase", r
    assert r["high"]["strategy"] == "shuffle", r
    for end in ("low", "high"):
        assert r[end]["identical"], (end, r)
        assert r[end]["overflow"] == 0, (end, r)
        assert r[end]["cost_wire"] < r[end]["base_wire"], (end, r)


def test_window_chain_elides_shuffle_and_matches_oracle():
    """The window-subsystem contract: over a dist_sort output the window
    runs with 0 AllToAlls (boundary all_gather only) and is bit-identical
    to the single-host oracle for all 8 functions; the unsorted lowering
    (sort inside the window node) pays one shuffle and stays
    bit-identical too."""
    r = run_case("window_chain")
    assert r["identical"], r
    assert r["window_elided"], r
    assert r["fused_alltoall"] == 1, r  # only the sort's range partition
    assert r["naive_window_alltoall"] == 1, r
    assert r["fused_window_wire"] == 0, r
    assert r["naive_wire"] > 0, r
    assert r["naive_overflow"] == 0 and r["fused_overflow"] == 0, r
    assert r["rows"] == r["rows_expect"], r


def test_window_thin_shard_carries_match_oracle():
    """Group portions smaller than the lag/lead offset and an empty
    middle shard: the boundary buffers must merge across several shards
    and still match the single-host oracle bit-for-bit."""
    r = run_case("window_thin_shards")
    assert r["identical"], r
    assert r["window_elided"], r
    assert r["rows"] == r["rows_expect"], r


def test_dist_sort_multikey():
    r = run_case("sort_multikey")
    assert r["order_ok"] and r["multiset_ok"], r
    assert r["rows"] == r["rows_expect"], r
    assert r["overflow"] == 0, r


def test_dist_staged_shuffle():
    """The pipelined-shuffle contract on 8 devices: every staging and the
    ppermute ring are bit-identical to the monolithic exchange — same
    rows, same overflow under skew, same wire-byte accounting — and an
    empty (capacity-0) table shuffles without the old clip-bound crash."""
    r = run_case("staged_shuffle")
    assert r["overflow_positive"], r
    assert r["overflow_identical"] and r["rows_identical"], r
    assert r["staged_bitwise_equal"] and r["ring_bitwise_equal"], r
    assert r["wire_bytes_identical"], r
    assert r["stages_reported"] == [1, 3, 1], r
    assert r["modes_reported"] == ["alltoall", "alltoall", "ring"], r
    assert r["empty_rows"] == 0 and r["empty_overflow"] == 0, r


def test_verify_audit_matches_traced_collectives():
    """The collective auditor on 8 devices: verify.expected_collectives'
    static per-record accounting equals the collective counts in the
    actually-traced fused jaxpr, for every distributed operator family
    (hash groupby chain, sort->join alignment, sort->window carries,
    staged + ring repartitions, global limit)."""
    r = run_case("verify_audit")
    assert r["all_matched"], r
    # ring decomposes into ppermutes only; staging multiplies AllToAlls
    assert r["ring_shuffle"]["actual"]["all_to_all"] == 0, r
    assert r["ring_shuffle"]["actual"]["ppermute"] > 0, r
    assert (r["staged_shuffle"]["actual"]["all_to_all"]
            > r["groupby_chain"]["actual"]["all_to_all"]), r
    # range alignment and window boundary carries pay gathers, not A2As
    assert r["sort_join_align"]["actual"]["all_gather"] > 0, r
    assert r["sort_window"]["actual"]["all_gather"] > 0, r


def test_serving_async_interleaved_matches_sequential():
    """The serving contract: N interleaved collect_async clients over a
    shared session are bit-identical per query to sequential collects,
    the warm cache compiles NOTHING (inline keyless lambdas included),
    and resolving futures out of submission order changes nothing."""
    r = run_case("serving_async")
    assert r["identical"], r
    assert r["reverse_resolution_ok"], r
    assert r["cold_compiles"] > 0, r        # first pass really compiled
    assert r["warm_compiles"] == 0, r       # ... and never again
    assert r["warm_recompiles"] == 0, r
    assert r["async_qps"] > 0 and r["p99_ms"] > 0, r


def test_async_overflow_verification_is_deferred():
    """Deferred overflow verification: a wrong cost estimate is invisible
    at submit time (no host sync, future unresolved), discovered at
    result(), retried at safe capacities EXACTLY ONCE with oracle-exact
    rows; a repeat submit routes straight to the safe executable, and the
    sized + safe executables live under distinct cache namespaces."""
    r = run_case("async_overflow_deferred")
    assert r["deferred"], r
    assert r["retries"] == 1, r
    assert r["retries_after_repeat"] == 1, r
    assert r["idempotent"], r
    assert r["stats_dropped"], r
    assert r["rows"] == r["rows_expect"], r
    assert r["identical"], r
    assert "plan" in r["cache_namespaces"], r
    assert "plan-safe" in r["cache_namespaces"], r


def test_moe_ep_matches_local():
    r = run_case("moe_ep")
    assert r["moe_ep_err"] < 2e-5, r
    assert r["aux_close"], r


def test_moe_decode_psum_matches_local():
    r = run_case("moe_decode_psum")
    assert r["moe_decode_err"] < 2e-5, r


def test_flash_decode_shard_matches_plain():
    r = run_case("flash_decode_shard")
    assert r["flash_decode_err"] < 2e-4, r


@pytest.mark.xfail(
    condition=_JAX_PRE_05,
    reason="partial-manual shard_map (auto=) crashes XLA on jax<0.5 — "
           "pre-existing environment limitation, see ROADMAP open items",
    strict=False)
def test_pod_compressed_training_tracks_exact():
    r = run_case("compress_pod")
    # int8 quantization: per-step param drift stays small, loss matches
    assert r["pod_compress_max_param_diff"] < 5e-2, r
    assert r["loss_close"], r


def test_elastic_checkpoint_restore():
    r = run_case("elastic_restore")
    assert r["elastic_ok"], r
