"""Property tests: every local relational operator vs the NumPy oracle
(Cylon Table I semantics — select/project/join x4 x2 algos/union/
intersect/difference/sort/distinct)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ops_local as L
from repro.core.table import Table

from oracle import (
    difference_oracle, distinct_oracle, intersect_oracle, join_oracle,
    select_oracle, table_rows_sorted, union_oracle)

keys = st.integers(0, 8)  # small key range -> many duplicates/matches


@st.composite
def kv_table(draw, max_rows=14):
    n = draw(st.integers(0, max_rows))
    return {
        "k": np.asarray(draw(st.lists(keys, min_size=n, max_size=n)), np.int32),
        "v": np.asarray(draw(st.lists(st.integers(-50, 50), min_size=n,
                                      max_size=n)), np.int32),
    }


def as_table(cols, pad=3):
    return Table.from_arrays(cols, capacity=len(cols["k"]) + pad)


# --- select / project -------------------------------------------------------


@given(kv_table(), st.integers(0, 8))
def test_select(cols, thresh):
    t = as_table(cols)
    out = L.select(t, lambda c: c["k"] < thresh)
    assert table_rows_sorted(out) == \
        select_oracle(cols, lambda r: r["k"] < thresh)


@given(kv_table())
def test_project(cols):
    t = as_table(cols)
    out = L.project(t, ["k"])
    assert out.column_names == ["k"]
    assert sorted(out.to_numpy()["k"].tolist()) == sorted(cols["k"].tolist())


# --- sort / distinct ---------------------------------------------------------


@given(kv_table())
def test_sort_by(cols):
    t = as_table(cols)
    out = L.sort_by(t, "k")
    got = out.to_numpy()["k"]
    np.testing.assert_array_equal(got, np.sort(cols["k"], kind="stable"))


@given(kv_table())
def test_sort_bitonic_matches_xla(cols):
    t = as_table(cols)
    a = L.sort_by(t, "k", algorithm="bitonic").to_numpy()["k"]
    b = L.sort_by(t, "k", algorithm="xla").to_numpy()["k"]
    np.testing.assert_array_equal(a, b)


@given(kv_table())
def test_distinct(cols):
    t = as_table(cols)
    assert table_rows_sorted(L.distinct(t)) == distinct_oracle(cols)


# --- set operators -----------------------------------------------------------


@given(kv_table(), kv_table())
def test_union(a, b):
    assert table_rows_sorted(L.union(as_table(a), as_table(b))) == \
        union_oracle(a, b)


@given(kv_table(), kv_table())
def test_intersect(a, b):
    assert table_rows_sorted(L.intersect(as_table(a), as_table(b))) == \
        intersect_oracle(a, b)


@given(kv_table(), kv_table())
def test_difference_symmetric(a, b):
    assert table_rows_sorted(L.difference(as_table(a), as_table(b))) == \
        difference_oracle(a, b, "symmetric")


@given(kv_table(), kv_table())
def test_difference_left(a, b):
    assert table_rows_sorted(
        L.difference(as_table(a), as_table(b), mode="left")) == \
        difference_oracle(a, b, "left")


# --- join: 4 semantics x 2 algorithms ----------------------------------------


@pytest.mark.parametrize("how", ["inner", "left", "right", "full"])
@pytest.mark.parametrize("algorithm", ["sort", "hash"])
@settings(max_examples=20)
@given(left=kv_table(max_rows=10), right=kv_table(max_rows=10))
def test_join(how, algorithm, left, right):
    lt = as_table(left)
    rt = Table.from_arrays({"k": right["k"], "w": right["v"]},
                           capacity=len(right["k"]) + 2)
    out = L.join(lt, rt, "k", how=how, algorithm=algorithm,
                 out_capacity=(len(left["k"]) + 1) * (len(right["k"]) + 1)
                 + len(left["k"]) + len(right["k"]) + 2)
    _, expect = join_oracle(left, {"k": right["k"], "w": right["v"]},
                            ["k"], how=how)
    assert table_rows_sorted(out) == expect


@given(left=kv_table(max_rows=10), right=kv_table(max_rows=10))
def test_join_multikey_hash(left, right):
    """Multi-column join (hash algorithm only, as in Cylon)."""
    lt = as_table(left)
    rt = Table.from_arrays({"k": right["k"], "v": right["v"]},
                           capacity=len(right["k"]) + 2)
    out = L.join(lt, rt, ["k", "v"], how="inner", algorithm="hash",
                 out_capacity=(len(left["k"]) + 1) * (len(right["k"]) + 1))
    _, expect = join_oracle(left, right, ["k", "v"], how="inner")
    assert table_rows_sorted(out) == expect


def test_join_overflow_truncates_to_capacity():
    """out_capacity smaller than the true result: valid rows kept, count
    clamped (Cylon's explicit memory-budget failure mode)."""
    a = Table.from_arrays({"k": np.zeros(4, np.int32)})
    b = Table.from_arrays({"k": np.zeros(4, np.int32), "w": np.arange(4, dtype=np.int32)})
    out = L.join(a, b, "k", out_capacity=5)
    assert int(out.row_count) == 5
    assert out.capacity == 5


@given(kv_table())
def test_head(cols):
    t = as_table(cols)
    h = L.head(t, 3)
    assert int(h.row_count) == min(3, len(cols["k"]))
