import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# separate process; distributed tests spawn subprocesses with their own
# XLA_FLAGS). Keep CI deterministic and CPU-only.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
