import os
import pathlib
import re
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# separate process; distributed tests spawn subprocesses with their own
# XLA_FLAGS). Keep CI deterministic and CPU-only.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Every optimize() under the suite runs the static plan verifier
# (repro.core.verify) and fails loudly on invariant violations — the
# whole tier-1 suite doubles as verifier coverage. Subprocess-based
# tests (dist_cases, bench workers) inherit the env, so they verify too.
os.environ.setdefault("REPRO_VERIFY_PLANS", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings  # noqa: E402
except ImportError:
    # Minimal environments (no hypothesis): skip the property-test modules
    # instead of failing collection, so the tier-1 gate still runs the
    # example-based suite. Modules are detected by their import, so a new
    # hypothesis-based test file degrades the same way automatically.
    _here = pathlib.Path(__file__).parent
    _imports_hypothesis = re.compile(
        r"^\s*(from|import)\s+hypothesis\b", re.MULTILINE)
    collect_ignore = [
        p.name for p in _here.glob("test_*.py")
        if _imports_hypothesis.search(p.read_text(encoding="utf-8"))
    ]
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
