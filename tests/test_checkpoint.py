"""Checkpointing: round-trip exactness, atomic commit, retention, resume."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


@pytest.fixture()
def tmp(tmp_path):
    return str(tmp_path)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16), jnp.float32),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jax.random.normal(k, (3,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip_exact(tmp):
    s = _state()
    ckpt.save(tmp, 10, s)
    like = jax.eval_shape(lambda: s)
    r = ckpt.restore(tmp, 10, like)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomic_commit_ignores_partial(tmp):
    s = _state()
    ckpt.save(tmp, 1, s)
    # simulate a crash mid-save: a tmp dir with partial contents
    os.makedirs(os.path.join(tmp, "tmp.2"))
    with open(os.path.join(tmp, "tmp.2", "00000_a.npy"), "wb") as f:
        f.write(b"garbage")
    # and a committed-looking dir without a manifest
    os.makedirs(os.path.join(tmp, "step_00000003"))
    assert ckpt.list_steps(tmp) == [1]
    assert ckpt.latest_step(tmp) == 1


def test_retention(tmp):
    s = _state()
    for i in range(1, 6):
        ckpt.save(tmp, i, s, keep=2)
    assert ckpt.list_steps(tmp) == [4, 5]


def test_async_save(tmp):
    s = _state()
    t = ckpt.save(tmp, 42, s, blocking=False)
    t.join()
    assert ckpt.latest_step(tmp) == 42


def test_manager_resume(tmp):
    s = _state()
    mgr = ckpt.CheckpointManager(tmp, every=2, keep=3)
    assert mgr.maybe_save(1, s) is False
    assert mgr.maybe_save(2, s) is True
    mgr.wait()
    like = jax.eval_shape(lambda: s)
    restored, step = mgr.resume(like)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(s["a"]))


def test_resume_empty_dir(tmp):
    mgr = ckpt.CheckpointManager(tmp)
    restored, step = mgr.resume({"x": jnp.zeros(())})
    assert restored is None and step == 0


def _corrupt_leaf(tmp, step, idx=-1, *, truncate=None, flip=False):
    d = os.path.join(tmp, f"step_{step:08d}")
    leaf = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[idx]
    path = os.path.join(d, leaf)
    with open(path, "r+b") as f:
        if truncate is not None:
            f.truncate(truncate)
        if flip:
            f.seek(-1, 2)
            b = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([b[0] ^ 0xFF]))


def test_restore_detects_truncation(tmp):
    s = _state()
    ckpt.save(tmp, 5, s)
    _corrupt_leaf(tmp, 5, truncate=40)
    like = jax.eval_shape(lambda: s)
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(tmp, 5, like)


def test_restore_detects_bitflip(tmp):
    s = _state()
    ckpt.save(tmp, 5, s)
    _corrupt_leaf(tmp, 5, flip=True)
    like = jax.eval_shape(lambda: s)
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(tmp, 5, like)


def test_resume_falls_back_past_corrupt_newest(tmp):
    s = _state()
    ckpt.save(tmp, 10, s)
    ckpt.save(tmp, 20, s)
    _corrupt_leaf(tmp, 20, truncate=10)
    like = jax.eval_shape(lambda: s)
    mgr = ckpt.CheckpointManager(tmp)
    with pytest.warns(RuntimeWarning, match="corrupt"):
        restored, step = mgr.resume(like)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(s["a"]))


def test_list_steps_skips_unreadable_manifest(tmp):
    s = _state()
    ckpt.save(tmp, 1, s)
    ckpt.save(tmp, 2, s)
    with open(os.path.join(tmp, "step_00000002", "manifest.json"),
              "w") as f:
        f.write("{half-written")
    assert ckpt.list_steps(tmp) == [1]
    assert ckpt.latest_step(tmp) == 1
