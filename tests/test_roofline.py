"""Roofline machinery: HLO collective parser, byte model, depth-pair math."""
import numpy as np

from repro.roofline import analysis as RA

HLO_SAMPLE = """
HloModule test

%fused_computation (p: f32[16,4096]) -> f32[16,4096] {
  %p = f32[16,4096]{1,0} parameter(0)
  %big = f32[16,4096]{1,0} multiply(%p, %p)
  ROOT %r = f32[16,4096]{1,0} add(%big, %p)
}

ENTRY %main (a: f32[32,256], w: bf16[256,512]) -> f32[32,512] {
  %a = f32[32,256]{1,0} parameter(0)
  %w = bf16[256,512]{1,0} parameter(1)
  %ar = f32[32,256]{1,0} all-reduce(%a), replica_groups=[2,4]<=[8], to_apply=%x
  %ag = bf16[64,256]{1,0} all-gather(%w2), dimensions={0}
  %rs = f32[16,256]{1,0} reduce-scatter(%a), dimensions={0}
  %a2a = bf16[32,128]{1,0} all-to-all(%q), dimensions={1}
  %cp = f32[32,256]{1,0} collective-permute(%a), source_target_pairs={{0,1}}
  %dot.1 = f32[32,512]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %conv = bf16[32,512]{1,0} convert(%dot.1)
}
"""


def test_collective_parser():
    st = RA.collective_stats(HLO_SAMPLE)
    assert st["counts"] == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "all-to-all": 1,
                            "collective-permute": 1}
    ar = 32 * 256 * 4
    ag = 64 * 256 * 2
    rs = 16 * 256 * 4
    a2a = 32 * 128 * 2
    cp = 32 * 256 * 4
    assert st["bytes"] == ar + ag + rs + a2a + cp
    assert st["wire_bytes"] == 2 * ar + ag + rs + a2a + cp


def test_hbm_bytes_dot_convert_collapse():
    out = RA.hbm_bytes(HLO_SAMPLE)
    # the dot's f32 output is emitted at bf16 (sole consumer is a convert);
    # the convert itself is free; fusion-internal ops don't count
    assert out["bytes"] > 0
    # dot contributes: reads a (32*256*4) + w (256*512*2) + out bf16
    dot_io = 32 * 256 * 4 + 256 * 512 * 2 + 32 * 512 * 2
    assert out["bytes"] >= dot_io


def test_depth_pair_extrapolation():
    pair = RA.DepthPair(1, 2, {"flops": 110.0, "bytes": 60.0},
                        {"flops": 210.0, "bytes": 110.0})
    per = pair.per_layer()
    assert per["flops"] == 100.0 and per["bytes"] == 50.0
    at32 = pair.at(32)
    assert at32["flops"] == 10 + 32 * 100
    assert at32["bytes"] == 10 + 32 * 50


def test_roofline_terms_dominance():
    t = RA.roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 2.0) < 1e-9
    assert abs(t["collective_s"] - 0.5) < 1e-9
    assert t["dominant"] == "memory"


def test_model_flops():
    from repro.configs import get_config
    import jax
    from repro.models.factory import build_model

    cfg = get_config("llama3-8b")
    model = build_model(cfg)
    pc = RA.count_params(jax.eval_shape(model.init,
                                        jax.random.PRNGKey(0)))
    # 8B total, ~1.05B embeddings (in+out tables)
    assert 7.9e9 < pc["total"] < 8.3e9
    n_active = RA.active_params(cfg, pc)
    mf = RA.model_flops(cfg, pc, "train", 256, 4096)
    assert abs(mf - 6 * n_active * 256 * 4096) < 1e6
    # moe scaling: dbrx active << total
    dbrx = get_config("dbrx-132b")
    dm = build_model(dbrx)
    dpc = RA.count_params(jax.eval_shape(dm.init, jax.random.PRNGKey(0)))
    assert RA.active_params(dbrx, dpc) < 0.4 * dpc["total"]
