"""Distributed ETL on an 8-device SPMD mesh — the paper's Fig. 3 pipeline.

Each worker holds a partition; distributed join/union run as
hash-partition + AllToAll + local op in BSP lockstep (shard_map).

    PYTHONPATH=src python examples/distributed_etl.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402

from repro.core.context import DistContext  # noqa: E402
from repro.data.synthetic import random_table, zipf_table  # noqa: E402


def main():
    ctx = DistContext(axis_name="shuffle")
    print(f"workers: {ctx.num_shards}")

    # per-worker partitions (the paper's per-worker CSV files)
    orders = ctx.from_local_parts([
        random_table(4000, key_range=2000, seed=1, shard=i, key_name="k")
        for i in range(ctx.num_shards)])
    users = ctx.from_local_parts([
        zipf_table(4000, key_range=2000, seed=2, shard=i, key_name="k")
        for i in range(ctx.num_shards)])

    # distributed inner join (hash algorithm; skewed side stresses buckets)
    joined, (sl, sr) = ctx.join(orders, users, "k", algorithm="hash",
                                bucket_capacity=4096)
    print(f"distributed join: {int(joined.global_rows())} rows; "
          f"send overflow: {int(np.asarray(sl.overflow).sum())} "
          f"+ {int(np.asarray(sr.overflow).sum())}")

    # distributed union-distinct over the key column
    u, _ = ctx.union(ctx.project(orders, ["k"]), ctx.project(users, ["k"]),
                     bucket_capacity=4096)
    print(f"distributed union-distinct: {int(u.global_rows())} keys")

    # distributed sort -> globally ordered across shards
    s, _ = ctx.sort(ctx.project(orders, ["k"]), "k", bucket_capacity=8192)
    ks = s.to_table().to_numpy()["k"].astype(np.int64)
    assert np.all(np.diff(ks) >= 0), "global order violated"
    print(f"distributed sort ok over {len(ks)} rows "
          f"(min={ks[0]}, max={ks[-1]})")

    # pleasingly-parallel select (no network, paper §II-B-1)
    sel = ctx.select(orders, lambda c: c["d0"] > 1.0)
    print(f"select d0>1: {int(sel.global_rows())} rows")


if __name__ == "__main__":
    main()
