"""Distributed ETL on an 8-device SPMD mesh — the paper's Fig. 3 pipeline.

Each worker holds a partition; distributed join/union run as
hash-partition + AllToAll + local op in BSP lockstep (shard_map).

    PYTHONPATH=src python examples/distributed_etl.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402

from repro.core.context import DistContext  # noqa: E402
from repro.data.synthetic import random_table, zipf_table  # noqa: E402


def ctx_project_sample(t):
    """Keep the 1-D stat columns (tokens stay on their own pipeline path)."""
    from repro.core import ops_local as L
    return L.project(t, ["source", "quality"])


def main():
    ctx = DistContext(axis_name="shuffle")
    print(f"workers: {ctx.num_shards}")

    # per-worker partitions (the paper's per-worker CSV files)
    orders = ctx.from_local_parts([
        random_table(4000, key_range=2000, seed=1, shard=i, key_name="k")
        for i in range(ctx.num_shards)])
    users = ctx.from_local_parts([
        zipf_table(4000, key_range=2000, seed=2, shard=i, key_name="k")
        for i in range(ctx.num_shards)])

    # distributed inner join (hash algorithm; skewed side stresses buckets)
    joined, (sl, sr) = ctx.join(orders, users, "k", algorithm="hash",
                                bucket_capacity=4096)
    print(f"distributed join: {int(joined.global_rows())} rows; "
          f"send overflow: {int(np.asarray(sl.overflow).sum())} "
          f"+ {int(np.asarray(sr.overflow).sum())}")

    # distributed union-distinct over the key column
    u, _ = ctx.union(ctx.project(orders, ["k"]), ctx.project(users, ["k"]),
                     bucket_capacity=4096)
    print(f"distributed union-distinct: {int(u.global_rows())} keys")

    # distributed sort -> globally ordered across shards
    s, _ = ctx.sort(ctx.project(orders, ["k"]), "k", bucket_capacity=8192)
    ks = s.to_table().to_numpy()["k"].astype(np.int64)
    assert np.all(np.diff(ks) >= 0), "global order violated"
    print(f"distributed sort ok over {len(ks)} rows "
          f"(min={ks[0]}, max={ks[-1]})")

    # pleasingly-parallel select (no network, paper §II-B-1)
    sel = ctx.select(orders, lambda c: c["d0"] > 1.0)
    print(f"select d0>1: {int(sel.global_rows())} rows")

    # distributed groupby: per-key stats, both aggregation strategies.
    # two_phase shuffles <= cardinality partial rows per shard instead of
    # every raw row, so its AllToAll buckets can be ~rows/cardinality smaller.
    aggs = {"d0": ["mean", "var"], "d1": ["count", "min", "max"]}
    g_sh, (st_sh,) = ctx.groupby(orders, "k", aggs, strategy="shuffle",
                                 bucket_capacity=2048)
    g_tp, (st_tp,) = ctx.groupby(orders, "k", aggs, strategy="two_phase",
                                 bucket_capacity=640)
    rows_sh = int(np.asarray(st_sh.received).sum())
    rows_tp = int(np.asarray(st_tp.received).sum())
    a, b = g_sh.to_table().to_numpy(), g_tp.to_table().to_numpy()
    oa, ob = np.argsort(a["k"]), np.argsort(b["k"])
    assert np.array_equal(a["k"][oa], b["k"][ob])
    assert np.allclose(a["d0_mean"][oa], b["d0_mean"][ob], atol=1e-5)
    assert np.array_equal(a["d1_count"][oa], b["d1_count"][ob])
    print(f"distributed groupby: {int(g_tp.global_rows())} groups; "
          f"shuffled rows {rows_sh} (shuffle) vs {rows_tp} (two-phase, "
          f"{rows_sh / max(rows_tp, 1):.1f}x fewer)")

    # quality-bucket statistics stage (data/pipeline.py) on LM samples
    from repro.data.pipeline import SOURCE_STAT_AGGS
    from repro.data.synthetic import lm_samples_table
    samples = ctx.from_local_parts([
        ctx_project_sample(lm_samples_table(512, 8, 1000, seed=3, shard=i))
        for i in range(ctx.num_shards)])
    stats, _ = ctx.groupby(samples, "source", SOURCE_STAT_AGGS,
                           strategy="two_phase", bucket_capacity=64)
    d = stats.to_table().to_numpy()
    print("quality stats by source bucket:")
    for i in np.argsort(d["source"]):
        print(f"  source={d['source'][i]}: n={d['quality_count'][i]} "
              f"mean={d['quality_mean'][i]:.3f} var={d['quality_var'][i]:.3f}")


if __name__ == "__main__":
    main()
