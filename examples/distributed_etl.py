"""Distributed ETL on an 8-device SPMD mesh — the paper's Fig. 3 pipeline.

Each worker holds a partition; distributed join/union run as
hash-partition + AllToAll + local op in BSP lockstep (shard_map).

The headline here is the **LazyFrame** path: the whole
join -> select -> groupby ETL chain compiles into ONE fused shard_map
program whose optimizer pushes the filter and projections below the
AllToAll and elides the groupby's shuffle entirely (the join already
co-partitioned the rows on the key) — fewer dispatches, fewer shuffles,
fewer wire bytes, bit-identical results.

    PYTHONPATH=src python examples/distributed_etl.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402

from repro.core.context import DistContext  # noqa: E402
from repro.data.synthetic import random_table, zipf_table  # noqa: E402


def ctx_project_sample(t):
    """Keep the 1-D stat columns (tokens stay on their own pipeline path)."""
    from repro.core import ops_local as L
    return L.project(t, ["source", "quality"])


def main():
    ctx = DistContext(axis_name="shuffle")
    print(f"workers: {ctx.num_shards}")

    # per-worker partitions (the paper's per-worker CSV files)
    orders = ctx.from_local_parts([
        random_table(4000, key_range=8000, seed=1, shard=i, key_name="k")
        for i in range(ctx.num_shards)])
    users = ctx.from_local_parts([
        random_table(4000, key_range=8000, seed=2, shard=i, key_name="k")
        for i in range(ctx.num_shards)])

    # ---- fused LazyFrame ETL chain: ONE shard_map program ------------------
    aggs = {"d0": ["mean", "var"], "d1": ["count", "min", "max"]}
    chain = (ctx.frame(orders)
             .join(ctx.frame(users), "k", algorithm="hash",
                   bucket_capacity=4096)
             .select(lambda c: c["d0"] > 0.0, key="d0_positive")
             .groupby("k", aggs, strategy="shuffle"))
    print("\noptimized plan (note pushed-down Select/Project, elided "
          "groupby shuffle):")
    print(chain.explain())
    rep = chain.plan_report()
    fused_a2a = sum(not r["elided"] for r in rep)
    fused_mb = sum(r["wire_bytes"] for r in rep) / 1e6
    fused = chain.collect()
    print(f"fused chain: {int(fused.global_rows())} groups, "
          f"{fused_a2a} AllToAlls, {fused_mb:.2f} MB on the wire")

    # eager op-by-op chain: same semantics, one dispatch + shuffle per op
    erep: list = []
    j, (sl, sr) = ctx.join(orders, users, "k", algorithm="hash",
                           bucket_capacity=4096, report=erep)
    s = ctx.select(j, lambda c: c["d0"] > 0.0, key="d0_positive")
    g, _ = ctx.groupby(s, "k", aggs, strategy="shuffle", report=erep)
    eager_a2a = sum(not r["elided"] for r in erep)
    eager_mb = sum(r["wire_bytes"] for r in erep) / 1e6
    print(f"eager chain: {int(g.global_rows())} groups, "
          f"{eager_a2a} AllToAlls, {eager_mb:.2f} MB on the wire "
          f"(join overflow {int(np.asarray(sl.overflow).sum())}"
          f"+{int(np.asarray(sr.overflow).sum())})")
    from repro.testing.compare import tables_bitwise_equal
    assert tables_bitwise_equal(g, fused), "fused != eager"
    print(f"fused == eager (bit-identical), "
          f"{eager_a2a - fused_a2a} AllToAlls and "
          f"{eager_mb - fused_mb:.2f} MB saved")

    # ---- co-partitioned join fast path -------------------------------------
    dims, _ = ctx.partition_by(ctx.from_local_parts([
        random_table(1000, key_range=8000, seed=5, shard=i, num_payload=1,
                     key_name="k") for i in range(ctx.num_shards)]), "k")
    f2 = ctx.frame(g).join(ctx.frame(dims), "k")
    rep2 = f2.plan_report()
    assert all(r["elided"] for r in rep2), rep2
    print(f"co-partitioned join: both shuffles elided "
          f"({int(f2.collect().global_rows())} rows, zero wire bytes)")

    # ---- eager operators, unchanged API ------------------------------------
    # distributed union-distinct over the key column
    u, _ = ctx.union(ctx.project(orders, ["k"]), ctx.project(users, ["k"]),
                     bucket_capacity=4096)
    print(f"\ndistributed union-distinct: {int(u.global_rows())} keys")

    # distributed multi-key sort -> globally lex-ordered across shards
    s2, _ = ctx.sort(ctx.project(orders, ["k", "d0"]), ["k", "d0"],
                     bucket_capacity=8192)
    d = s2.to_table().to_numpy()
    ks = np.stack([d["k"].astype(np.int64), d["d0"]], axis=1)
    order_ok = all(
        (a[0], a[1]) <= (b[0], b[1]) for a, b in zip(ks[:-1], ks[1:]))
    assert order_ok, "global lexicographic order violated"
    print(f"distributed sort by (k, d0) ok over {len(ks)} rows")

    # distributed groupby: per-key stats, both aggregation strategies.
    # two_phase shuffles <= cardinality partial rows per shard instead of
    # every raw row, so its AllToAll buckets can be ~rows/cardinality smaller.
    small = ctx.from_local_parts([
        zipf_table(4000, key_range=2000, seed=3, shard=i, key_name="k")
        for i in range(ctx.num_shards)])
    g_sh, (st_sh,) = ctx.groupby(small, "k", aggs, strategy="shuffle",
                                 bucket_capacity=2048)
    g_tp, (st_tp,) = ctx.groupby(small, "k", aggs, strategy="two_phase",
                                 bucket_capacity=640)
    rows_sh = int(np.asarray(st_sh.received).sum())
    rows_tp = int(np.asarray(st_tp.received).sum())
    a, b = g_sh.to_table().to_numpy(), g_tp.to_table().to_numpy()
    oa, ob = np.argsort(a["k"]), np.argsort(b["k"])
    assert np.array_equal(a["k"][oa], b["k"][ob])
    assert np.allclose(a["d0_mean"][oa], b["d0_mean"][ob], atol=1e-5)
    assert np.array_equal(a["d1_count"][oa], b["d1_count"][ob])
    print(f"distributed groupby: {int(g_tp.global_rows())} groups; "
          f"shuffled rows {rows_sh} (shuffle) vs {rows_tp} (two-phase, "
          f"{rows_sh / max(rows_tp, 1):.1f}x fewer)")

    # quality-bucket statistics stage (data/pipeline.py) on LM samples,
    # via the same LazyFrame entry point
    from repro.data.pipeline import SOURCE_STAT_AGGS
    from repro.data.synthetic import lm_samples_table
    samples = ctx.from_local_parts([
        ctx_project_sample(lm_samples_table(512, 8, 1000, seed=3, shard=i))
        for i in range(ctx.num_shards)])
    stats = (ctx.frame(samples)
             .groupby("source", SOURCE_STAT_AGGS, strategy="two_phase",
                      bucket_capacity=64)
             .collect())
    d = stats.to_table().to_numpy()
    print("quality stats by source bucket:")
    for i in np.argsort(d["source"]):
        print(f"  source={d['source'][i]}: n={d['quality_count'][i]} "
              f"mean={d['quality_mean'][i]:.3f} var={d['quality_var'][i]:.3f}")


if __name__ == "__main__":
    main()
