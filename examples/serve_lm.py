"""Serve a small LM with batched requests: prefill + KV-cache decode.

Demonstrates the serving substrate the decode_32k/long_500k cells lower:
batched greedy decoding with a ragged-length request batch (shorter
prompts left-padded into the shared cache window).

    PYTHONPATH=src python examples/serve_lm.py [--batch 8 --gen 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.factory import build_model
from repro.train.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = ModelConfig(arch="serve-demo", family="dense", num_layers=8,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=8192, head_dim=32, rope_theta=1e4,
                      remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    prefill = jax.jit(make_prefill_step(model, max_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(out, axis=1)
    print(f"batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode  {t_decode/max(args.gen-1,1)*1e3:.1f} ms/step "
          f"({args.batch*(args.gen-1)/t_decode:.0f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[:4]:
        print("  ", row[:12], "...")

    # sanity: decode path == one-shot causal logits on the full sequence
    full = np.concatenate([prompts, gen[:, :-1]], axis=1)
    ref_logits, _, _ = model.forward(
        params, tokens=jnp.asarray(full), embeds=None, mode="causal",
        cache=None, pos=None)
    ref_tok = np.asarray(jnp.argmax(
        ref_logits[:, args.prompt_len - 1:], -1))[:, : args.gen]
    agree = (ref_tok == gen).mean()
    print(f"greedy agreement with one-shot forward: {100*agree:.1f}%")


if __name__ == "__main__":
    main()
