"""Quickstart: the paper's Fig. 4 program in this framework.

Cylon's C++ example loads two CSV partitions, distributed-joins them and
writes the result. Here: build two tables, run the relational operators
(local mode), and hand the result to JAX compute with zero copy.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops_local as L
from repro.core.table import Table
from repro.data.synthetic import random_table


def main():
    # "CSV read" — the paper's generated relations (int key + 3 doubles)
    left = random_table(1000, key_range=300, seed=1)
    right = random_table(800, key_range=300, seed=2)
    print("left:", left, " right:", right)

    # select -> join -> project, all jittable pure functions
    good = L.select(left, lambda c: c["d0"] > 0.0)
    joined = L.join(good, right, "k", how="inner", algorithm="hash",
                    out_capacity=8192)
    proj = L.project(joined, ["k", "d1", "d1_r"])
    print("join result rows:", int(proj.row_count))

    # set ops
    u = L.union(L.project(left, ["k"]), L.project(right, ["k"]))
    i = L.intersect(L.project(left, ["k"]), L.project(right, ["k"]))
    d = L.difference(L.project(left, ["k"]), L.project(right, ["k"]))
    print(f"union={int(u.row_count)} intersect={int(i.row_count)} "
          f"difference={int(d.row_count)}")

    # zero-copy hand-off into jitted compute (the paper's Fig. 5 story):
    # the table's columns ARE the device buffers the jit consumes
    @jax.jit
    def feature_stats(t: Table):
        m = t.valid_mask()
        x = jnp.where(m, t.columns["d1"], 0.0)
        return jnp.sum(x) / jnp.maximum(jnp.sum(m), 1)

    print("mean(d1) over joined rows:", float(feature_stats(proj)))

    # sorted view (bitonic kernel path for small single-key tables)
    s = L.sort_by(L.project(left, ["k"]), "k")
    ks = s.to_numpy()["k"]
    assert np.all(np.diff(ks) >= 0)
    print("sorted ok; head:", ks[:10])

    # groupby: per-key statistics (sort -> segment -> reduce, ops_agg.py)
    from repro.core import ops_agg as A
    g = A.groupby(left, "k", {"d0": ["count", "mean", "var"]})
    gd = g.to_numpy()
    print(f"groupby: {int(g.row_count)} keys; "
          f"k={gd['k'][0]} n={gd['d0_count'][0]} "
          f"mean={gd['d0_mean'][0]:.3f} var={gd['d0_var'][0]:.3f}")


if __name__ == "__main__":
    main()
