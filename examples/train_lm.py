"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the relational ETL pipeline feeding the jitted train step (the paper's
"data engineering everywhere" thesis, end to end).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--devices 8]

Note: on this 1-core CPU container a 113M model runs ~30-60 s/step — use
--steps 30 for a quick check (loss visibly decreases); "a few hundred
steps" is the real-hardware configuration.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.data.pipeline import PipelineConfig, RelationalTokenPipeline
    from repro.launch.mesh import make_local_mesh
    from repro.models.common import ModelConfig
    from repro.models.factory import build_model
    from repro.train.loop import LoopConfig, run
    from repro.train.optimizer import OptConfig

    # ~100M params: 12L x 512d x 8H, 32k vocab (llama3-family block)
    cfg = ModelConfig(arch="lm-100m", family="dense", num_layers=12,
                      d_model=512, num_heads=8, num_kv_heads=4, d_ff=2048,
                      vocab_size=32000, head_dim=64, rope_theta=1e4,
                      remat="none")
    mesh = make_local_mesh(model=args.model_axis) \
        if jax.device_count() > 1 else None
    model = build_model(cfg, mesh)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {n/1e6:.1f}M params; devices: {jax.device_count()}")

    pipe = RelationalTokenPipeline(PipelineConfig(
        seq_len=256, global_batch=16, vocab_size=cfg.vocab_size,
        quality_threshold=0.3, seed=0))
    ocfg = OptConfig(lr=6e-4, warmup_steps=min(30, args.steps // 3),
                     total_steps=args.steps,
                     weight_decay=0.01)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=20, microbatches=2)
    state, hist = run(model, pipe, ocfg, lcfg)
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{args.steps} steps")
    import math
    random_loss = math.log(cfg.vocab_size)   # ~10.4: untrained baseline
    # stability check at any length; learning checks need steps past warmup
    assert hist[-1]["loss"] < random_loss + 0.5, "training diverged"
    if args.steps >= 100:
        assert hist[-1]["loss"] < random_loss - 0.25, (
            "model should beat the random-init baseline")
        assert hist[-1]["loss"] < hist[0]["loss"], "loss should decrease"


if __name__ == "__main__":
    main()
