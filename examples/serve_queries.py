"""Concurrent-query serving on an 8-device mesh — the paper's "data
engineering everywhere" setting: the engine embedded in a live workload,
many clients issuing small relational queries over shared registered
tables, rather than one batch pipeline.

Shows the three pieces the serving path is built from:

* ``ServingSession.register`` — named shared tables (``analyze=True``
  attaches stats, so queries are cost-sized and overflow verification
  rides the deferred async path);
* ``collect_async`` / ``submit`` — dispatch returns a ``PlanFuture``
  immediately (no host sync, not even the overflow check); ``result()``
  verifies and materializes;
* ``run_open_loop`` — N clients round-robin a mixed-shape workload in
  ``sequential`` or ``async`` mode; the report carries p50/p99 latency,
  queries/sec, and the plan-cache counter deltas (0 compiles on a warm
  cache is the serving invariant).

    PYTHONPATH=src python examples/serve_queries.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402

from repro.core.context import DistContext  # noqa: E402
from repro.core.serving import ServingSession  # noqa: E402
from repro.core.table import Table  # noqa: E402
from repro.testing.compare import tables_bitwise_equal  # noqa: E402


def main():
    ctx = DistContext(axis_name="shuffle")
    print(f"workers: {ctx.num_shards}")

    rng = np.random.default_rng(11)
    n = 4000 * ctx.num_shards
    orders = Table.from_arrays({
        "k": rng.integers(0, 64, n).astype(np.int32),
        "d0": rng.integers(-50, 50, n).astype(np.float32)})
    dims = Table.from_arrays({
        "k": np.arange(64, dtype=np.int32),
        "w": rng.integers(0, 9, 64).astype(np.float32)})

    sess = ServingSession(ctx, max_in_flight=8)
    sess.register("orders", orders, analyze=True)
    sess.register("dims", dims, analyze=True)
    print(f"registered tables: {sess.table_names()}")

    # ---- one async query: dispatch now, verify at result() -----------------
    fut = sess.frame("orders").groupby("k", {"d0": ["sum"]}).collect_async()
    print(f"future returned (done={fut.done}); doing other host work ...")
    out = fut.result()  # deferred overflow check happens here
    print(f"groupby result: {int(out.global_rows())} groups "
          f"(done={fut.done})")

    # ---- the open loop: 4 clients x mixed shapes ---------------------------
    workload = [
        ("gb", lambda s: s.frame("orders")
            .groupby("k", (("d0", "sum"), ("d0", "count")))),
        ("topn", lambda s: s.frame("orders").sort("k").limit(32)),
        ("sel", lambda s: s.frame("orders")
            .select(lambda c: c["d0"] > 0.0)
            .groupby("k", (("d0", "mean"),))),
        ("join", lambda s: s.frame("orders").join(s.frame("dims"), "k")
            .groupby("k", (("w", "sum"),))),
    ]
    seq, seq_res = sess.run_open_loop(workload, num_clients=4,
                                      queries_per_client=3,
                                      mode="sequential")
    print(seq.summary())
    asy, asy_res = sess.run_open_loop(workload, num_clients=4,
                                      queries_per_client=3, mode="async")
    print(asy.summary())

    identical = all(tables_bitwise_equal(a.to_table(), b.to_table())
                    for a, b in zip(asy_res, seq_res))
    assert identical, "async results diverged from sequential"
    assert asy.compiles == 0 and asy.recompiles == 0, asy.to_dict()
    print(f"async == sequential per query (bit-identical), warm cache: "
          f"{ctx.cache_stats()}")


if __name__ == "__main__":
    main()
